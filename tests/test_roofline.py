"""hlo_cost analyzer: validated against XLA on unrolled graphs (where
XLA's own cost_analysis is correct) and against analytic counts on
scanned graphs (where XLA undercounts — the reason hlo_cost exists)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import (HloCost, analyze, parse_module,
                                   xla_cost_analysis)
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   roofline_terms)


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_unrolled_matches_xla():
    def g(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)
    comp = _compile(g, x, w)
    ours = analyze(comp.as_text())["flops"]
    xla = xla_cost_analysis(comp)["flops"]
    assert ours == pytest.approx(xla, rel=0.01)
    assert ours == pytest.approx(4 * 2 * 256**3, rel=0.01)


def test_scan_trip_count_applied():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    comp = _compile(f, x, w)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(12 * 2 * 256**3, rel=0.01)
    # and XLA undercounts — the bug this module works around
    assert xla_cost_analysis(comp)["flops"] < ours / 2


def test_nested_scan():
    def f2(x, w):
        def outer(c, _):
            def body(cc, wi):
                return jnp.tanh(cc @ wi), None
            y, _ = jax.lax.scan(body, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    comp = _compile(f2, x, w)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(15 * 2 * 128**3, rel=0.01)


def test_einsum_batched_dot():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)
    comp = _compile(f, a, b)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(2 * 8 * 64 * 32 * 16, rel=0.01)


def test_parse_tuple_shapes_and_comments():
    text = """
HloModule m

ENTRY %main (p: f32[4,4]) -> (s32[], f32[4,4]) {
  %p = f32[4,4]{1,0} parameter(0)
  %c = s32[] constant(7)
  ROOT %t = (s32[], f32[4,4]{1,0}, /*index=2*/f32[8]{0}) tuple(%c, %p)
}
"""
    comps = parse_module(text)
    assert "ENTRY" in comps
    root = comps["ENTRY"][-1]
    assert root.op == "tuple"
    assert [s.dims for s in root.shapes] == [(), (4, 4), (8,)]
    const = comps["ENTRY"][1]
    assert const.const_val == 7


def test_bytes_accessed_scales_with_trip():
    def f(x, w):
        def body(c, wi):
            return c + wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    n1 = analyze(_compile(
        f, x, jax.ShapeDtypeStruct((10, 1024), jnp.float32)).as_text())
    n2 = analyze(_compile(
        f, x, jax.ShapeDtypeStruct((40, 1024), jnp.float32)).as_text())
    assert n2["bytes_accessed"] > 2.5 * n1["bytes_accessed"]


def test_roofline_terms_dominance():
    rec = {"flops": PEAK_FLOPS, "bytes_accessed": HBM_BW / 10,
           "collective_bytes": {"total": ICI_BW / 100}, "n_chips": 1}

    class Cfg:
        pass
    from repro.configs import get_config, shape_by_name
    cfg = get_config("smollm_135m")
    shape = shape_by_name("train_4k")
    out = roofline_terms(rec, cfg, shape)
    assert out["dominant"] == "compute"
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(0.1)
    assert 0 < out["useful_flop_ratio"]

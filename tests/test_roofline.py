"""hlo_cost analyzer: validated against XLA on unrolled graphs (where
XLA's own cost_analysis is correct) and against analytic counts on
scanned graphs (where XLA undercounts — the reason hlo_cost exists)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import (HloCost, analyze, parse_module,
                                   xla_cost_analysis)
from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   roofline_terms)


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_unrolled_matches_xla():
    def g(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)
    comp = _compile(g, x, w)
    ours = analyze(comp.as_text())["flops"]
    xla = xla_cost_analysis(comp)["flops"]
    assert ours == pytest.approx(xla, rel=0.01)
    assert ours == pytest.approx(4 * 2 * 256**3, rel=0.01)


def test_scan_trip_count_applied():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    comp = _compile(f, x, w)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(12 * 2 * 256**3, rel=0.01)
    # and XLA undercounts — the bug this module works around
    assert xla_cost_analysis(comp)["flops"] < ours / 2


def test_nested_scan():
    def f2(x, w):
        def outer(c, _):
            def body(cc, wi):
                return jnp.tanh(cc @ wi), None
            y, _ = jax.lax.scan(body, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    comp = _compile(f2, x, w)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(15 * 2 * 128**3, rel=0.01)


def test_einsum_batched_dot():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)
    comp = _compile(f, a, b)
    ours = analyze(comp.as_text())["flops"]
    assert ours == pytest.approx(2 * 8 * 64 * 32 * 16, rel=0.01)


def test_parse_tuple_shapes_and_comments():
    text = """
HloModule m

ENTRY %main (p: f32[4,4]) -> (s32[], f32[4,4]) {
  %p = f32[4,4]{1,0} parameter(0)
  %c = s32[] constant(7)
  ROOT %t = (s32[], f32[4,4]{1,0}, /*index=2*/f32[8]{0}) tuple(%c, %p)
}
"""
    comps = parse_module(text)
    assert "ENTRY" in comps
    root = comps["ENTRY"][-1]
    assert root.op == "tuple"
    assert [s.dims for s in root.shapes] == [(), (4, 4), (8,)]
    const = comps["ENTRY"][1]
    assert const.const_val == 7


def test_bytes_accessed_scales_with_trip():
    def f(x, w):
        def body(c, wi):
            return c + wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    n1 = analyze(_compile(
        f, x, jax.ShapeDtypeStruct((10, 1024), jnp.float32)).as_text())
    n2 = analyze(_compile(
        f, x, jax.ShapeDtypeStruct((40, 1024), jnp.float32)).as_text())
    assert n2["bytes_accessed"] > 2.5 * n1["bytes_accessed"]


def test_roofline_terms_dominance():
    rec = {"flops": PEAK_FLOPS, "bytes_accessed": HBM_BW / 10,
           "collective_bytes": {"total": ICI_BW / 100}, "n_chips": 1}

    class Cfg:
        pass
    from repro.configs import get_config, shape_by_name
    cfg = get_config("smollm_135m")
    shape = shape_by_name("train_4k")
    out = roofline_terms(rec, cfg, shape)
    assert out["dominant"] == "compute"
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(0.1)
    assert 0 < out["useful_flop_ratio"]


# ---------------------------------------------------------------------------
# collective accounting (async pairs, new kinds, unknown dtypes)
# ---------------------------------------------------------------------------

_SYNC_COLL = """
HloModule sync

ENTRY %main (p0: f32[64,8]) -> (f32[512,8], f32[64,8]) {
  %p0 = f32[64,8]{1,0} parameter(0)
  %ag = f32[512,8]{1,0} all-gather(f32[64,8]{1,0} %p0), dimensions={0}
  %ar = f32[64,8]{1,0} all-reduce(f32[64,8]{1,0} %p0), to_apply=%sum
  ROOT %t = (f32[512,8]{1,0}, f32[64,8]{1,0}) tuple(%ag, %ar)
}
"""

# the same program as XLA emits it with async collectives: a -start
# whose tuple result aliases (operand, result), then a -done
_ASYNC_COLL = """
HloModule async

ENTRY %main (p0: f32[64,8]) -> (f32[512,8], f32[64,8]) {
  %p0 = f32[64,8]{1,0} parameter(0)
  %ags = (f32[64,8]{1,0}, f32[512,8]{1,0}) all-gather-start(f32[64,8]{1,0} %p0), dimensions={0}
  %ag = f32[512,8]{1,0} all-gather-done((f32[64,8]{1,0}, f32[512,8]{1,0}) %ags)
  %ars = f32[64,8]{1,0} all-reduce-start(f32[64,8]{1,0} %p0), to_apply=%sum
  %ar = f32[64,8]{1,0} all-reduce-done(f32[64,8]{1,0} %ars)
  ROOT %t = (f32[512,8]{1,0}, f32[64,8]{1,0}) tuple(%ag, %ar)
}
"""


def test_async_collectives_match_sync_lowering():
    """Regression: an async pair is ONE transfer.  The old analyzer
    charged the -start's aliased tuple at full size and the -done
    again, double-counting every overlapped collective."""
    sync, async_ = analyze(_SYNC_COLL), analyze(_ASYNC_COLL)
    assert sync == async_, (sync, async_)
    # and the numbers are the hand-computed ones, not merely equal
    ag_b, ar_b = 512 * 8 * 4, 64 * 8 * 4
    assert sync["collective_bytes"]["all-gather"] == ag_b
    assert sync["collective_bytes"]["all-reduce"] == 2 * ar_b
    assert sync["collective_bytes"]["total"] == ag_b + 2 * ar_b
    assert sync["bytes_accessed"] == ag_b + ar_b


def test_new_collective_kinds_counted():
    text = """
HloModule kinds

ENTRY %main (p0: f32[64,8]) -> f32[64,8] {
  %p0 = f32[64,8]{1,0} parameter(0)
  %cb = f32[64,8]{1,0} collective-broadcast(f32[64,8]{1,0} %p0)
  %ra = f32[64,8]{1,0} ragged-all-to-all(f32[64,8]{1,0} %cb)
  ROOT %o = f32[64,8]{1,0} add(f32[64,8]{1,0} %cb, f32[64,8]{1,0} %ra)
}
"""
    coll = analyze(text)["collective_bytes"]
    b = 64 * 8 * 4
    assert coll["collective-broadcast"] == b
    assert coll["ragged-all-to-all"] == b
    # and ragged-all-to-all is NOT misfiled under all-to-all
    assert coll["all-to-all"] == 0


def test_unknown_dtype_warns_once_and_counts_zero():
    text = """
HloModule weird

ENTRY %main (p: f4e2m1fnx[32]) -> f4e2m1fnx[32] {
  %p = f4e2m1fnx[32]{0} parameter(0)
  ROOT %n = f4e2m1fnx[32]{0} negate(f4e2m1fnx[32]{0} %p)
}
"""
    import warnings as _w
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        r1 = analyze(text)
        r2 = analyze(text)          # second pass must stay silent
    hits = [str(x.message) for x in rec if "f4e2m1fnx" in str(x.message)]
    assert len(hits) == 1, hits
    assert "unknown HLO dtype" in hits[0]
    assert r1["bytes_accessed"] == 0.0
    assert r1 == r2

"""Perf-variant policy: sharding rules and flags behave as specified.

These lock in the §Perf structural fixes: compound variant strings
parse correctly (the `variant == "dponly"` equality bug), EP engages
only when the expert count divides the model axis (the grok 606
GiB/dev fallback), and flash/chunked attention agree when the flag
flips the implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models import sharding as shd
from repro.models.layers import chunked_attention


def _mesh():
    return compat.abstract_mesh((1, 1), ("data", "model"))


def test_policy_flags_parse_compound():
    with shd.policy("dponly,flashvjp,bf16scores"):
        assert shd.flag("dponly")
        assert shd.flag("flashvjp")
        assert shd.flag("bf16scores")
        assert not shd.flag("ep")
    assert not shd.flag("dponly")   # reset on exit


def test_dponly_expands_dp_over_model_axis():
    mesh = _mesh()
    with shd.policy("dponly"):
        assert shd.dp_axes(mesh) == ("data", "model")
        assert shd._expand(shd.TP, mesh) is None
    assert shd.dp_axes(mesh) == ("data",)
    assert shd._expand(shd.TP, mesh) == "model"


def test_ep_requires_divisible_expert_count():
    mesh = compat.abstract_mesh((1, 2), ("data", "model"))
    shape_ok = (4, 8, 16)       # 4 experts % 2 == 0
    shape_bad = (3, 8, 16)      # 3 experts % 2 != 0
    with shd.policy("ep"):
        ok = shd.spec_for("layers/moe/experts_in/w", shape_ok, mesh,
                          scanned=False)
        bad = shd.spec_for("layers/moe/experts_in/w", shape_bad, mesh,
                           scanned=False)
    assert ok[0] == "model"          # EP rule engaged
    # fallback keeps the dense-style rule: expert dim unsharded but
    # d_ff still model-sharded
    assert bad[0] is None
    assert bad[-1] == "model"


def test_flashvjp_flag_switches_impl_same_result():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    base = chunked_attention(q, k, v, causal=True, chunk=32)
    with shd.policy("flashvjp"):
        fl = chunked_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


def test_bf16scores_numerics_close():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.bfloat16)
    base = chunked_attention(q, k, v, causal=True, chunk=32)
    with shd.policy("flashvjp,bf16scores"):
        fl = chunked_attention(q, k, v, causal=True, chunk=32)
    err = np.max(np.abs(np.asarray(fl, np.float32)
                        - np.asarray(base, np.float32)))
    assert err < 0.05, err

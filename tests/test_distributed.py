"""Distributed MF: the explicit shard_map Gibbs sweep on an 8-device
host mesh matches the single-device chain, and its compiled program
moves exactly one fixed-factor all-gather per half-sweep (eager
pipeline) or exactly ``n_shards - 1`` collective-permutes and ZERO
all-gathers per half-sweep (ring pipeline).

Ring contract (see core/distributed.py): the ring reassembles or
chunk-consumes the same bytes the all-gather moves, through pure data
movement (where/`dynamic_update_slice`) for every gather-indexed
consumer — so on sparse paths (gaussian, probit, macau, sparse-SnS)
the ring chain is BITWISE the eager chain, metrics included, asserted
below.  Dense blocks chunk-accumulate their Gram/RHS moments into the
circulating hops (the overlap that motivates the ring), which
reorders f32 summation: those chains are asserted at the same 2e-4 /
reduction-order tolerance as the distributed-vs-single-device
contract.

Agreement contract (see core/distributed.py): every per-row normal
draw is bit-identical to the single-device sweep (counter-based
``row_normals`` — asserted bitwise here), so the chains differ only by
reduction-order ULPs (K/K^2 moment psums, XLA batch-tiling of the
per-row solves) — asserted at 2e-4 over 3 sweeps, an order of
magnitude under a Gibbs chain's own step-to-step movement.

The same contract and tolerance cover the widened sharded subset:
probit noise (counter-based ``row_uniforms`` truncated-normal
augmentation), dense blocks (row-sharded stored orientations), and
spike-and-slab priors (counter-based ``row_bernoulli`` inclusions +
per-component-folded slab normals — the GFA composition), and the HLO
checks pin one fixed-factor all-gather per half-sweep for those paths
too, plus ZERO per-sweep Macau ``FtF`` psums (the (D, D) side-Gramian
is hoisted to placement time) and ZERO per-component SnS collectives
(two K-sized hyper psums per view are the entire SnS budget).

All HLO pins are expressed through ``repro.analysis.contract``: each
script derives a ``CommContract`` with ``contract_for(model,
mesh_shape, pipeline)`` and verifies StableHLO + compiled HLO with
``assert_contract`` — no per-script collective regexes.

Runs in subprocesses because the device count must be set before jax
initializes (the main pytest process keeps the default 1 CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import (FixedGaussian, MFData, init_state,
                            gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step,
                                        pad_rows_to)
    from repro.core.gibbs import row_normals
    from repro.core.priors import NormalPrior
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    # the mechanism: shard draws are bitwise slices of the global draws
    key = jax.random.PRNGKey(3)
    full = np.asarray(jax.jit(lambda: row_normals(key, 96, 8, 0))())
    for s in range(8):
        part = np.asarray(jax.jit(
            lambda s=s: row_normals(key, 12, 8, jnp.int32(12 * s)))())
        assert np.array_equal(part, full[12 * s:12 * (s + 1)]), s
    print("row draws bitwise")

    K = 8
    n_rows = pad_rows_to(96, 8)
    n_cols = pad_rows_to(48, 8)
    mat, test, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4)
    model = ModelDef(
        (EntityDef("rows", n_rows, NormalPrior(K)),
         EntityDef("cols", n_cols, NormalPrior(K))),
        (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),), K, False)
    data = MFData((mat,), (None, None))
    state = init_state(model, data, seed=0)

    # single-device chain
    st1 = state
    for _ in range(3):
        st1, m1 = gibbs_step(model, data, st1)

    # 8-device explicit shard_map chain
    mesh = make_mesh((4, 2), ("data", "model"))
    assert distributed_supported(model, mesh, data)
    step, ds, ss = make_distributed_step(model, mesh, data, state)
    pdata = jax.device_put(data, ds)
    pstate = jax.device_put(state, ss)
    st2 = pstate
    for _ in range(3):
        st2, m2 = step(pdata, st2)

    for a, b in zip(st1.factors, st2.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    print("rmse", float(m1["rmse_train_0"]), float(m2["rmse_train_0"]))
    np.testing.assert_allclose(float(m1["rmse_train_0"]),
                               float(m2["rmse_train_0"]), rtol=1e-3)

    # elastic shrink: 8 -> 6 devices, same chain continues
    mesh2 = make_mesh((6,), ("data",))
    assert distributed_supported(model, mesh2, data)
    step2, ds2, ss2 = make_distributed_step(model, mesh2, data, state)
    st3 = jax.device_put(st2, ss2)
    d3 = jax.device_put(data, ds2)
    st3, m3 = step2(d3, st3)
    st1b, m1b = gibbs_step(model, data, st1)
    np.testing.assert_allclose(float(m1b["rmse_train_0"]),
                               float(m3["rmse_train_0"]), rtol=1e-3)
    print("OK")
""")

_WIDENED_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import (FixedGaussian, MFData, ProbitNoise,
                            dense_block, init_state, gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.gibbs import row_uniforms
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import NormalPrior
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    # the mechanism: probit's truncated-normal uniforms are bitwise
    # shard slices, same contract as row_normals
    key = jax.random.PRNGKey(5)
    full = np.asarray(jax.jit(lambda: row_uniforms(key, 96, 16, 0))())
    for s in range(8):
        part = np.asarray(jax.jit(
            lambda s=s: row_uniforms(key, 12, 16, jnp.int32(12 * s)))())
        assert np.array_equal(part, full[12 * s:12 * (s + 1)]), s
    print("row uniforms bitwise")

    K = 8
    n_rows, n_cols = 96, 48
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)

    def two_entity(noise, sparse):
        return ModelDef((EntityDef("r", n_rows, NormalPrior(K)),
                         EntityDef("c", n_cols, NormalPrior(K))),
                        (BlockDef(0, 1, noise, sparse=sparse),), K,
                        False)

    def parity(name, model, data):
        state = init_state(model, data, seed=0)
        st1 = state
        for _ in range(3):
            st1, m1 = gibbs_step(model, data, st1)
        assert distributed_supported(model, mesh, data), name
        step, ds, ss = make_distributed_step(model, mesh, data, state)
        st2 = jax.device_put(state, ss)
        pdata = jax.device_put(data, ds)
        for _ in range(3):
            st2, m2 = step(pdata, st2)
        for a, b in zip(st1.factors, st2.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(m1["rmse_train_0"]),
                                   float(m2["rmse_train_0"]), rtol=1e-3)
        print(name, "parity ok", float(m2["rmse_train_0"]))

    # probit on sparse binary data (compound-activity classification)
    bmat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4,
                               binary=True)
    parity("probit", two_entity(ProbitNoise(), True),
           MFData((bmat,), (None, None)))

    # fully-observed dense block (shared-Gram path)
    R = rng.normal(size=(n_rows, n_cols)).astype(np.float32)
    parity("dense_full", two_entity(FixedGaussian(5.0), False),
           MFData((dense_block(R),), (None, None)))

    # masked dense block under probit (per-row-Gram path + augmentation)
    Xb = (R > 0).astype(np.float32)
    m = (rng.random((n_rows, n_cols)) < 0.6).astype(np.float32)
    parity("dense_masked_probit", two_entity(ProbitNoise(), False),
           MFData((dense_block(Xb, mask=m),), (None, None)))
    print("OK")
""")

_SNS_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import (AdaptiveGaussian, FixedGaussian, MFData,
                            dense_block, init_state, gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.gibbs import row_bernoulli
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import (FixedNormalPrior, NormalPrior,
                                   SpikeAndSlabPrior)
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    # the mechanism: SnS inclusion draws are bitwise shard slices,
    # the same counter-based contract as row_normals/row_uniforms
    key = jax.random.PRNGKey(7)
    p = jnp.asarray(np.random.default_rng(0).random(96), jnp.float32)
    full = np.asarray(jax.jit(lambda: row_bernoulli(key, p, 0))())
    for s in range(8):
        part = np.asarray(jax.jit(
            lambda s=s: row_bernoulli(key, p[12 * s:12 * (s + 1)],
                                      jnp.int32(12 * s)))())
        assert np.array_equal(part, full[12 * s:12 * (s + 1)]), s
    print("row bernoulli bitwise")

    K = 4
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)

    def parity(name, model, data, check_sns_hypers):
        state = init_state(model, data, seed=0)
        st1 = state
        for _ in range(3):
            st1, m1 = gibbs_step(model, data, st1)
        assert distributed_supported(model, mesh, data), name
        step, ds, ss = make_distributed_step(model, mesh, data, state)
        st2 = jax.device_put(state, ss)
        pdata = jax.device_put(data, ds)
        for _ in range(3):
            st2, m2 = step(pdata, st2)
        for a, b in zip(st1.factors, st2.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(m1["rmse_train_0"]),
                                   float(m2["rmse_train_0"]), rtol=1e-3)
        # the replicated rho/tau hyper-state is the single-device one
        for e in check_sns_hypers:
            for hk in ("rho", "tau"):
                np.testing.assert_allclose(
                    np.asarray(st1.hypers[e][hk]),
                    np.asarray(st2.hypers[e][hk]), rtol=2e-3, atol=2e-3)
        print(name, "parity ok", float(m2["rmse_train_0"]))
        return st2

    # the GFA composition (paper Table 1 "Normal + SnS"): shared Z
    # against 3 dense views, spike-and-slab loadings; every dim
    # divides BOTH the 8-device mesh and the 6-survivor re-mesh
    N, dims = 96, (72, 48, 24)
    Z = rng.normal(size=(N, K)).astype(np.float32)
    ents = [EntityDef("samples", N, FixedNormalPrior(K))]
    blocks, payloads = [], []
    for m, D in enumerate(dims):
        W = rng.normal(size=(D, K)).astype(np.float32)
        X = (Z @ W.T + 0.1 * rng.normal(size=(N, D))).astype(np.float32)
        ents.append(EntityDef(f"view{m}", D, SpikeAndSlabPrior(K)))
        blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                               sparse=False))
        payloads.append(dense_block(X))
    gfa_model = ModelDef(tuple(ents), tuple(blocks), K, False)
    gfa_data = MFData(tuple(payloads), tuple([None] * len(ents)))
    st = parity("gfa", gfa_model, gfa_data,
                check_sns_hypers=range(1, len(ents)))

    # elastic shrink carrying the rho/tau hyper-state: 8 -> 6 devices
    mesh6 = make_mesh((6,), ("data",))
    assert distributed_supported(gfa_model, mesh6, gfa_data)
    state0 = init_state(gfa_model, gfa_data, seed=0)
    step6, ds6, ss6 = make_distributed_step(gfa_model, mesh6, gfa_data,
                                            state0)
    st6, m6 = step6(jax.device_put(gfa_data, ds6),
                    jax.device_put(st, ss6))
    ref = state0
    for _ in range(4):
        ref, mref = gibbs_step(gfa_model, gfa_data, ref)
    np.testing.assert_allclose(float(mref["rmse_train_0"]),
                               float(m6["rmse_train_0"]), rtol=1e-3)
    print("gfa elastic remesh ok")

    # SnS on one axis of a sparse block (BMF + SnS, Table 1)
    smat, _, _ = random_sparse(0, (96, 48), 0.2, rank=4)
    sns_model = ModelDef(
        (EntityDef("r", 96, NormalPrior(K)),
         EntityDef("c", 48, SpikeAndSlabPrior(K))),
        (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),), K, False)
    parity("sparse_sns", sns_model, MFData((smat,), (None, None)),
           check_sns_hypers=(1,))
    print("OK")
""")

_HLO_SNS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.analysis.contract import assert_contract, contract_for
    from repro.core import (AdaptiveGaussian, MFData, dense_block,
                            init_state)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import FixedNormalPrior, SpikeAndSlabPrior
    from repro.launch.mesh import make_mesh

    K = 8
    N, dims = 96, (48, 24)
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    ents = [EntityDef("samples", N, FixedNormalPrior(K))]
    blocks, payloads = [], []
    for m, D in enumerate(dims):
        X = rng.normal(size=(N, D)).astype(np.float32)
        ents.append(EntityDef(f"view{m}", D, SpikeAndSlabPrior(K)))
        blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                               sparse=False))
        payloads.append(dense_block(X))
    model = ModelDef(tuple(ents), tuple(blocks), K, False)
    data = MFData(tuple(payloads), tuple([None] * len(ents)))
    assert distributed_supported(model, mesh, data)
    state = init_state(model, data, seed=0)
    # the EAGER exchange contract is pinned explicitly (the ring
    # pipeline has its own HLO script and the env default may be ring)
    step, ds, ss = make_distributed_step(model, mesh, data, state,
                                         pipeline="eager")
    lowered = step.lower(data, state)

    # the derived contract IS the old hand-pins: one fixed-factor
    # all-gather per half-sweep (E entities -> E gathers); hyper/noise
    # psums only — 2 K-sized SnS moments per view + 2 scalar SSE/nnz
    # per block, so 4 per view and ZERO per-component collectives
    # (the K-unrolled coordinate loop would add ~K more each); and
    # every backend all-reduce payload at most K-sized (the gathered
    # factors are consumed, not reduced)
    M = len(dims)
    c = contract_for(model, (8,), "eager")
    assert c.all_gathers == len(model.entities), c
    assert c.all_reduces == 4 * M, c
    assert c.max_reduce_elems == K, c
    assert_contract(c, lowered_text=lowered.as_text(),
                    compiled_text=lowered.compile().as_text(),
                    where="gfa/eager")
    print("all-gathers", c.all_gathers, "all-reduces", c.all_reduces)
    print("OK")
""")

_RING_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import (AdaptiveGaussian, FixedGaussian, MFData,
                            ProbitNoise, dense_block, init_state,
                            gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core import distributed as D
    from repro.core.priors import (FixedNormalPrior, MacauPrior,
                                   NormalPrior, SpikeAndSlabPrior)
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    K = 8
    n_rows, n_cols = 96, 48
    # the flattened two-axis mesh: the ring permutes over ("data",
    # "model") jointly, the hardest routing case
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)

    def run(model, data, pipeline, sweeps=3):
        state = init_state(model, data, seed=0)
        assert D.distributed_supported(model, mesh, data)
        step, ds, ss = D.make_distributed_step(model, mesh, data, state,
                                               pipeline=pipeline)
        st = jax.device_put(state, ss)
        pdata = jax.device_put(data, ds)
        for _ in range(sweeps):
            st, m = step(pdata, st)
        return st, m

    def parity(name, model, data, bitwise):
        st1 = init_state(model, data, seed=0)
        for _ in range(3):
            st1, m1 = gibbs_step(model, data, st1)
        ste, me = run(model, data, "eager")
        str_, mr = run(model, data, "ring")
        # ring matches the single-device chain at the distributed
        # contract tolerance for every family...
        for a, b in zip(st1.factors, str_.factors):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4, err_msg=name)
        # ...and matches the eager sharded chain BITWISE on sparse
        # paths (the ring reassembles the exact gather operands:
        # data movement only, no re-summation), metrics included
        for a, b in zip(ste.factors, str_.factors):
            if bitwise:
                assert np.array_equal(np.asarray(a), np.asarray(b)), name
            else:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-4, atol=2e-4,
                                           err_msg=name)
        for k in me:
            if bitwise:
                assert float(me[k]) == float(mr[k]), (name, k)
            else:
                np.testing.assert_allclose(float(me[k]), float(mr[k]),
                                           rtol=1e-4, err_msg=(name, k))
        print(name, "ring parity ok", "bitwise" if bitwise else "2e-4",
              float(mr["rmse_train_0"]))

    def two_entity(noise, sparse, row_prior=None):
        return ModelDef(
            (EntityDef("r", n_rows, row_prior or NormalPrior(K)),
             EntityDef("c", n_cols, NormalPrior(K))),
            (BlockDef(0, 1, noise, sparse=sparse),), K, False)

    mat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4)
    parity("gaussian", two_entity(FixedGaussian(5.0), True),
           MFData((mat,), (None, None)), bitwise=True)

    bmat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4,
                               binary=True)
    parity("probit", two_entity(ProbitNoise(), True),
           MFData((bmat,), (None, None)), bitwise=True)

    Dside = 12
    side = jnp.asarray(rng.normal(size=(n_rows, Dside)), jnp.float32)
    parity("macau",
           two_entity(FixedGaussian(5.0), True,
                      row_prior=MacauPrior(K, Dside)),
           MFData((mat,), (side, None)), bitwise=True)

    # dense blocks chunk-accumulate their moments into the ring hops
    # (the overlap), which reorders the f32 sums -> 2e-4, not bitwise
    R = rng.normal(size=(n_rows, n_cols)).astype(np.float32)
    parity("dense_full", two_entity(FixedGaussian(5.0), False),
           MFData((dense_block(R),), (None, None)), bitwise=False)

    # the GFA composition: FixedNormal Z + SnS loadings on 3 views
    N, dims = 96, (72, 48, 24)
    Z = rng.normal(size=(N, K)).astype(np.float32)
    ents = [EntityDef("samples", N, FixedNormalPrior(K))]
    blocks, payloads = [], []
    for m, Dm in enumerate(dims):
        W = rng.normal(size=(Dm, K)).astype(np.float32)
        X = (Z @ W.T + 0.1 * rng.normal(size=(N, Dm))).astype(np.float32)
        ents.append(EntityDef(f"view{m}", Dm, SpikeAndSlabPrior(K)))
        blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                               sparse=False))
        payloads.append(dense_block(X))
    parity("gfa", ModelDef(tuple(ents), tuple(blocks), K, False),
           MFData(tuple(payloads), tuple([None] * len(ents))),
           bitwise=False)

    # SnS on the sparse block's column axis (BMF + SnS): the SnS
    # coordinate loop reads the ring-reassembled view -> bitwise
    parity("sparse_sns",
           ModelDef((EntityDef("r", n_rows, NormalPrior(K)),
                     EntityDef("c", n_cols, SpikeAndSlabPrior(K))),
                    (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),),
                    K, False),
           MFData((mat,), (None, None)), bitwise=True)

    # the scan-rolled ring (production shard counts): force the rolled
    # form on this 8-device mesh and pin it to the same bitwise chain
    D.RING_UNROLL_MAX = 4
    ste, me = run(two_entity(FixedGaussian(5.0), True),
                  MFData((mat,), (None, None)), "eager")
    str_, mr = run(two_entity(FixedGaussian(5.0), True),
                   MFData((mat,), (None, None)), "ring")
    for a, b in zip(ste.factors, str_.factors):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "scan ring"
    print("scan-rolled ring bitwise ok")
    print("OK")
""")

_RING_HLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.analysis.contract import assert_contract, contract_for
    from repro.core import (AdaptiveGaussian, FixedGaussian, MFData,
                            ProbitNoise, dense_block, init_state)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import (FixedNormalPrior, MacauPrior,
                                   NormalPrior, SpikeAndSlabPrior)
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    K, Dside = 8, 12
    n_rows, n_cols = 96, 48
    S = 8
    rng = np.random.default_rng(0)
    mat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4)
    bmat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4,
                               binary=True)
    R = rng.normal(size=(n_rows, n_cols)).astype(np.float32)
    side = jnp.asarray(rng.normal(size=(n_rows, Dside)), jnp.float32)

    def ents(row_prior):
        return (EntityDef("r", n_rows, row_prior),
                EntityDef("c", n_cols, NormalPrior(K)))

    gfa_ents = [EntityDef("samples", 96, FixedNormalPrior(K)),
                EntityDef("view0", 48, SpikeAndSlabPrior(K)),
                EntityDef("view1", 24, SpikeAndSlabPrior(K))]
    gfa_blocks = [BlockDef(0, 1, AdaptiveGaussian(), sparse=False),
                  BlockDef(0, 2, AdaptiveGaussian(), sparse=False)]
    gfa_payloads = tuple(
        dense_block(rng.normal(size=(96, Dm)).astype(np.float32))
        for Dm in (48, 24))

    cases = {
        "gaussian": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),),
                     K),
            MFData((mat,), (None, None))),
        "gaussian_bf16": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),),
                     K, use_pallas=False, bf16_gather=True),
            MFData((mat,), (None, None))),
        "probit": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, ProbitNoise(), sparse=True),), K),
            MFData((bmat,), (None, None))),
        "macau": (
            ModelDef(ents(MacauPrior(K, Dside)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),),
                     K),
            MFData((mat,), (side, None))),
        "dense_full": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=False),),
                     K),
            MFData((dense_block(R),), (None, None))),
        "gfa": (
            ModelDef(tuple(gfa_ents), tuple(gfa_blocks), K, False),
            MFData(gfa_payloads, (None, None, None))),
    }

    # both mesh layouts: single axis and the flattened two-axis ring
    for mesh_shape, mesh_axes in (((8,), ("data",)),
                                  ((4, 2), ("data", "model"))):
        mesh = make_mesh(mesh_shape, mesh_axes)
        for name, (model, data) in cases.items():
            assert distributed_supported(model, mesh, data), name
            state = init_state(model, data, seed=0)
            step, ds, ss = make_distributed_step(model, mesh, data,
                                                 state, pipeline="ring")
            lowered = step.lower(data, state)
            E = len(model.entities)

            # the ring communication contract, derived not hand-pinned:
            # ZERO full-factor all-gathers anywhere in the program and
            # exactly n_shards - 1 collective-permutes per half-sweep
            # (one circulation per entity per sweep — the metrics
            # reuse the final half-sweep's reassembled view, exactly
            # like eager reuses its gather), bf16 on the wire when the
            # model flags it; checked on StableHLO AND the backend
            c = contract_for(model, mesh_shape, "ring")
            assert c.all_gathers == 0, c
            assert c.collective_permutes == E * (S - 1), c
            assert c.wire_dtype == \\
                ("bf16" if model.bf16_gather else "f32"), c
            assert_contract(c, lowered_text=lowered.as_text(),
                            compiled_text=lowered.compile().as_text(),
                            where=f"{name}/{mesh_shape}/ring")
            print(name, "x".join(map(str, mesh_shape)),
                  "collective-permutes", c.collective_permutes,
                  "all-gathers 0")
    print("OK")
""")

_HLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax

    from repro.analysis.contract import assert_contract, contract_for
    from repro.core import FixedGaussian, MFData, init_state
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import make_distributed_step
    from repro.core.priors import NormalPrior
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    mat, _, _ = random_sparse(0, (96, 48), 0.2, rank=4)
    data = MFData((mat,), (None, None))
    mesh = make_mesh((8,), ("data",))

    for bf16 in (False, True):
        model = ModelDef(
            (EntityDef("rows", 96, NormalPrior(8)),
             EntityDef("cols", 48, NormalPrior(8))),
            (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),), 8,
            use_pallas=False, bf16_gather=bf16)
        state = init_state(model, data, seed=0)
        step, ds, ss = make_distributed_step(model, mesh, data, state,
                                             pipeline="eager")
        lowered = step.lower(data, state)

        # the communication contract, derived from the ModelDef: one
        # all-gather of the fixed factor per half-sweep (2 entities ->
        # exactly 2), carried in bf16 when the model flags it — checked
        # on StableHLO and on the backend (XLA:CPU normalizes bf16
        # collectives to convert-gather-convert but must not duplicate
        # or split them)
        c = contract_for(model, (8,), "eager")
        assert c.all_gathers == len(model.entities), c
        assert c.wire_dtype == ("bf16" if bf16 else "f32"), c
        assert_contract(c, lowered_text=lowered.as_text(),
                        compiled_text=lowered.compile().as_text(),
                        where="bf16" if bf16 else "f32")
        print("variant", "bf16" if bf16 else "f32",
              "all-gathers", c.all_gathers)
    print("OK")
""")

_HLO_WIDENED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.analysis.contract import assert_contract, contract_for
    from repro.core import (FixedGaussian, MFData, ProbitNoise,
                            dense_block, init_state)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import MacauPrior, NormalPrior
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    K, D = 8, 12          # D != K so the FtF shape is unambiguous
    n_rows, n_cols = 96, 48
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    bmat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4,
                               binary=True)
    smat, _, _ = random_sparse(1, (n_rows, n_cols), 0.2, rank=4)
    R = rng.normal(size=(n_rows, n_cols)).astype(np.float32)
    side = jnp.asarray(rng.normal(size=(n_rows, D)).astype(np.float32))

    def ents(row_prior):
        return (EntityDef("r", n_rows, row_prior),
                EntityDef("c", n_cols, NormalPrior(K)))

    cases = {
        "probit_sparse": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, ProbitNoise(), sparse=True),), K),
            MFData((bmat,), (None, None))),
        "probit_sparse_bf16": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, ProbitNoise(), sparse=True),), K,
                     use_pallas=False, bf16_gather=True),
            MFData((bmat,), (None, None))),
        "dense_full": (
            ModelDef(ents(NormalPrior(K)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=False),),
                     K),
            MFData((dense_block(R),), (None, None))),
        "macau": (
            ModelDef(ents(MacauPrior(K, D)),
                     (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),),
                     K),
            MFData((smat,), (side, None))),
    }

    for name, (model, data) in cases.items():
        assert distributed_supported(model, mesh, data), name
        state = init_state(model, data, seed=0)
        step, ds, ss = make_distributed_step(model, mesh, data, state,
                                             pipeline="eager")
        lowered = step.lower(data, state)

        # communication contract, derived from the ModelDef: ONE
        # all-gather of the fixed factor per half-sweep, bf16 on the
        # wire when flagged.  The Macau FtF hoist is subsumed by the
        # payload bound: the contract's max all-reduce payload
        # (max(K^2, D*K) for Macau) is strictly below the D*D
        # side-Gramian, so a per-sweep FtF psum would violate it.
        c = contract_for(model, (8,), "eager")
        assert c.all_gathers == len(model.entities), (name, c)
        assert c.wire_dtype == \\
            ("bf16" if model.bf16_gather else "f32"), (name, c)
        if name == "macau":
            assert c.max_reduce_elems == max(K * K, D * K) < D * D, c
        assert_contract(c, lowered_text=lowered.as_text(),
                        compiled_text=lowered.compile().as_text(),
                        where=name)
        print(name, "all-gathers", c.all_gathers,
              "max psum elems", c.max_reduce_elems)
    print("OK")
""")


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_resolve_pipeline_validates_choices(monkeypatch):
    """The pipeline knob fails fast with the valid choices (the
    ``_PRIORS`` ValueError contract) and defers to REPRO_PIPELINE —
    the env hook the CI ring leg rides — only when unset."""
    from repro.core.distributed import resolve_pipeline

    monkeypatch.delenv("REPRO_PIPELINE", raising=False)
    assert resolve_pipeline() == "eager"
    assert resolve_pipeline("ring") == "ring"
    monkeypatch.setenv("REPRO_PIPELINE", "ring")
    assert resolve_pipeline() == "ring"
    assert resolve_pipeline("eager") == "eager"   # explicit wins
    with pytest.raises(ValueError, match="valid pipelines.*eager.*ring"):
        resolve_pipeline("warp")
    monkeypatch.setenv("REPRO_PIPELINE", "warp")
    with pytest.raises(ValueError, match="REPRO_PIPELINE"):
        resolve_pipeline()


@pytest.mark.slow
def test_distributed_gibbs_matches_single_device():
    _run(_PARITY_SCRIPT)


@pytest.mark.slow
def test_distributed_widened_subset_matches_single_device():
    """Probit noise + dense blocks ride the explicit sweep at the
    same 2e-4 parity as the Gaussian sparse path."""
    _run(_WIDENED_PARITY_SCRIPT)


@pytest.mark.slow
def test_distributed_hlo_one_allgather_per_halfsweep():
    _run(_HLO_SCRIPT)


@pytest.mark.slow
def test_distributed_hlo_widened_paths_and_ftf_hoist():
    """One all-gather per half-sweep holds for probit/dense/Macau, and
    the Macau side-Gramian psum is gone from the per-sweep program."""
    _run(_HLO_WIDENED_SCRIPT)


@pytest.mark.slow
def test_distributed_sns_gfa_matches_single_device():
    """Spike-and-slab (GFA multi-view + sparse BMF+SnS) rides the
    explicit sweep at the same 2e-4 parity, carries replicated rho/tau
    hyper-state, and survives an 8 -> 6 re-mesh mid-chain."""
    _run(_SNS_PARITY_SCRIPT)


@pytest.mark.slow
def test_distributed_hlo_sns_collective_contract():
    """GFA HLO: one fixed-factor all-gather per half-sweep, exactly
    two K-sized hyper psums per SnS view plus the scalar noise psums,
    and ZERO per-component collectives."""
    _run(_HLO_SNS_SCRIPT)


@pytest.mark.slow
def test_distributed_ring_matches_eager():
    """The ring-pipelined sweep (S-1 double-buffered ppermute hops per
    half-sweep) matches the eager all-gather sweep: bitwise — metrics
    included — on every sparse path (gaussian, probit, macau,
    sparse-SnS), at the 2e-4 reduction-order tolerance on the
    chunk-accumulated dense/GFA paths, and within 2e-4 of the
    single-device chain for all of them.  Also pins the scan-rolled
    ring (production shard counts) to the same bitwise chain."""
    _run(_RING_PARITY_SCRIPT)


@pytest.mark.slow
def test_distributed_ring_hlo_collective_contract():
    """Ring HLO across the model zoo on both mesh layouts: exactly
    n_shards - 1 collective-permutes per half-sweep (one circulation
    per entity per sweep, bf16 on the wire when flagged) and ZERO
    full-factor all-gathers anywhere in the program."""
    _run(_RING_HLO_SCRIPT)

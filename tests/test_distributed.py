"""Distributed MF: the sharded Gibbs step on an 8-device host mesh
equals the single-device chain bit-for-bit (counter-based RNG), and the
elastic re-mesh path re-shards without changing results.

Runs in a subprocess because the device count must be set before jax
initializes (the main pytest process keeps the default 1 CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import (FixedGaussian, MFData, init_state,
                            gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (make_distributed_step,
                                        pad_rows_to, row_sharding)
    from repro.core.priors import NormalPrior
    from repro.core.sparse import random_sparse
    from repro.launch.mesh import make_mesh

    K = 8
    n_rows = pad_rows_to(96, 8)
    n_cols = pad_rows_to(48, 8)
    mat, test, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4)
    model = ModelDef(
        (EntityDef("rows", n_rows, NormalPrior(K)),
         EntityDef("cols", n_cols, NormalPrior(K))),
        (BlockDef(0, 1, FixedGaussian(5.0), sparse=True),), K, False)
    data = MFData((mat,), (None, None))
    state = init_state(model, data, seed=0)

    # single-device chain
    st1 = state
    for _ in range(3):
        st1, m1 = gibbs_step(model, data, st1)

    # 8-device sharded chain
    mesh = make_mesh((4, 2), ("data", "model"))
    step, ds, ss = make_distributed_step(model, mesh, data, state)
    pdata = jax.device_put(data, ds)
    pstate = jax.device_put(state, ss)
    st2 = pstate
    for _ in range(3):
        st2, m2 = step(pdata, st2)

    for a, b in zip(st1.factors, st2.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    print("rmse", float(m1["rmse_train_0"]), float(m2["rmse_train_0"]))
    np.testing.assert_allclose(float(m1["rmse_train_0"]),
                               float(m2["rmse_train_0"]), rtol=1e-3)

    # elastic shrink: 8 -> 6 devices, same chain continues
    mesh2 = make_mesh((6,), ("data",))
    step2, ds2, ss2 = make_distributed_step(model, mesh2, data, state)
    st3 = jax.device_put(st2, ss2)
    d3 = jax.device_put(data, ds2)
    st3, m3 = step2(d3, st3)
    st1b, m1b = gibbs_step(model, data, st1)
    np.testing.assert_allclose(float(m1b["rmse_train_0"]),
                               float(m3["rmse_train_0"]), rtol=1e-3)
    print("OK")
""")


@pytest.mark.slow
def test_distributed_gibbs_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout

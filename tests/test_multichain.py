"""Vectorized multi-chain sampling (``chains=C``) and everything it
gates: bitwise chain independence, the multi-chain sample store,
``PredictSession`` pooling + the R-hat convergence gate, and the
session/serving correctness fixes that rode along (per-axis side-info
precisions, single-query exclude normalization, background checkpoint
error propagation, resume bookkeeping).

The reproducibility contract (see ``gibbs.multi_chain_step``): chains
map over the leading axis with ``lax.map`` — each chain runs the
IDENTICAL per-chain subgraph, so chain c of a C-chain run is BITWISE
the single-chain run keyed ``chain_keys(seed, C)[c]``, and chain 0
(keyed with the unfolded base key) IS the golden single-chain run for
the same seed.  ``vmap`` would batch the per-chain reductions and
drift ~1e-6 — that is why the engine does not use it.

The 8-device shard_map side of the same contract (eager + ring, and
the ``chain_axis`` mesh layout) runs in a subprocess (slow marker) —
the device count must be set before jax initializes.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import gibbs
from repro.core.session import (GFASession, ModelBuilder, Session,
                                SweepInfo, TrainSession, resolve_chains)
from repro.core.sparse import from_coo


def _bmf_data(seed=0, shape=(30, 20), rank=3, density=0.6):
    rng = np.random.default_rng(seed)
    U = rng.normal(size=(shape[0], rank))
    V = rng.normal(size=(shape[1], rank))
    R = (U @ V.T + 0.1 * rng.normal(size=shape)).astype(np.float32)
    i, j = np.nonzero(rng.random(shape) < density)
    v = R[i, j]
    n_tr = int(0.8 * len(v))
    perm = rng.permutation(len(v))
    tr, te = perm[:n_tr], perm[n_tr:]
    train = from_coo(i[tr], j[tr], v[tr], shape)
    return train, (i[te], j[te], v[te])


def _leaves_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# bitwise chain independence (single device)
# ---------------------------------------------------------------------------

def test_multi_chain_step_bitwise_vs_independent_runs():
    """C stacked chains advanced by ``multi_chain_step`` equal C
    independent single-chain runs keyed ``chain_keys(seed, C)`` —
    BITWISE, every leaf, every metric, over multiple sweeps."""
    b = ModelBuilder(num_latent=4)
    b.add_entity("u", 24)
    b.add_entity("v", 16)
    train, _ = _bmf_data(3, (24, 16))
    b.add_block("u", "v", train)
    model, data, _ = b.build()

    C, sweeps = 3, 3
    keys = gibbs.chain_keys(11, C)
    # chain 0 uses the UNFOLDED base key: the golden single-chain run
    assert np.array_equal(np.asarray(keys[0]),
                          np.asarray(jax.random.PRNGKey(11)))
    step1 = jax.jit(gibbs.gibbs_step, static_argnums=0)
    indep, indep_metrics = [], []
    for k in keys:
        st = gibbs.init_state(model, data, 11, key=k)
        for _ in range(sweeps):
            st, m = step1(model, data, st)
        indep.append(st)
        indep_metrics.append(m)

    stacked = gibbs.stack_states(
        gibbs.init_chain_states(model, data, 11, C))
    for _ in range(sweeps):
        stacked, sm = gibbs.multi_chain_step_jit(model, data, stacked)

    for c in range(C):
        assert _leaves_equal(gibbs.unstack_state(stacked, c), indep[c]), c
        for name, v in sm.items():
            assert np.asarray(v)[c] == np.asarray(
                indep_metrics[c][name]), (c, name)


def test_session_chain_zero_is_the_single_chain_run():
    """A ``chains=3`` session's chain 0 replays the ``chains=1`` run
    unchanged: train trace bitwise, final state bitwise — the
    golden-chain guarantee that multi-chain is purely additive."""
    train, test = _bmf_data(1)
    infos = []
    # chains=1 explicitly: this baseline must stay single-chain even
    # under the CI leg's REPRO_CHAINS=4 env default
    single = TrainSession(num_latent=4, burnin=3, nsamples=4, seed=5,
                          chains=1)
    single.add_train_and_test(train, test)
    r1 = single.run()

    multi = TrainSession(num_latent=4, burnin=3, nsamples=4, seed=5,
                         chains=3, callbacks=[infos.append])
    multi.add_train_and_test(train, test)
    r3 = multi.run()

    assert r3.n_chains == 3
    assert r3.chain_blocks is not None and len(r3.chain_blocks) == 3
    # chain-0 trace IS the single-chain trace (and the back-compat
    # top-level trace follows chain 0)
    assert r3.chain_blocks[0][0].rmse_train_trace == r1.rmse_train_trace
    assert r3.rmse_train_trace == r1.rmse_train_trace
    assert _leaves_equal(gibbs.unstack_state(r3.state, 0), r1.state)
    # chains 1..C-1 are genuinely different chains
    assert r3.chain_blocks[1][0].rmse_train_trace \
        != r1.rmse_train_trace
    # callbacks: metrics stay chain-0 scalars, chain_metrics stacks C
    assert all(isinstance(i, SweepInfo) for i in infos)
    last = infos[-1]
    assert np.ndim(last.metrics["rmse_train_0"]) == 0
    assert np.asarray(last.chain_metrics["rmse_train_0"]).shape == (3,)
    assert float(last.metrics["rmse_train_0"]) == float(
        np.asarray(last.chain_metrics["rmse_train_0"])[0])
    # diagnostics computed over the post-burnin per-chain traces
    assert r3.diagnostics is not None
    assert r3.diagnostics.n_chains == 3
    assert r3.diagnostics.n_draws == 4
    assert "rmse_train_0" in r3.diagnostics.rhat
    assert any(k.startswith("factor_rms_") for k in r3.diagnostics.rhat)
    # a single-chain run records no cross-chain evidence fields
    assert r1.n_chains == 1 and r1.chain_blocks is None


def test_recorder_noninterference_multichain_bitwise(tmp_path):
    """The ``repro.obs`` contract at ``chains=3`` with streaming
    checkpoints: recorder-on and recorder-off runs are bitwise
    identical — train traces, every stacked-state leaf, diagnostics,
    and the bytes of every checkpointed sample file.  The recorder
    threads through the session INTO the CheckpointManager savers,
    so this also pins that ckpt instrumentation is report-only."""
    from repro.obs import Recorder

    train, test = _bmf_data(2)

    def run(recorder, sub):
        s = TrainSession(num_latent=4, burnin=2, nsamples=3, seed=9,
                         chains=3, save_freq=1,
                         save_dir=str(tmp_path / sub),
                         recorder=recorder)
        s.add_train_and_test(train, test)
        return s.run()

    off = run(Recorder(enabled=False), "off")
    rec = Recorder(enabled=True)
    on = run(rec, "on")

    assert on.rmse_train_trace == off.rmse_train_trace
    assert on.rmse_test_trace == off.rmse_test_trace
    assert _leaves_equal(on.state, off.state)
    for c in range(3):
        assert on.chain_blocks[c][0].rmse_train_trace == \
            off.chain_blocks[c][0].rmse_train_trace
    assert set(on.diagnostics.rhat) == set(off.diagnostics.rhat)
    for k in on.diagnostics.rhat:   # nan-aware: few draws => nan rhat
        np.testing.assert_array_equal(on.diagnostics.rhat[k],
                                      off.diagnostics.rhat[k])
        np.testing.assert_array_equal(on.diagnostics.ess[k],
                                      off.diagnostics.ess[k])
    # checkpointed sample stores identical array-for-array (zip
    # timestamps inside npz differ by nature; every stored value must
    # not — ckpt spans/counters never touch what gets written)
    on_files = sorted(p.relative_to(tmp_path / "on")
                      for p in (tmp_path / "on").rglob("*.npz"))
    off_files = sorted(p.relative_to(tmp_path / "off")
                       for p in (tmp_path / "off").rglob("*.npz"))
    assert on_files and on_files == off_files
    for rel in on_files:
        with np.load(tmp_path / "on" / rel) as a, \
                np.load(tmp_path / "off" / rel) as b:
            assert sorted(a.files) == sorted(b.files)
            for k in a.files:
                np.testing.assert_array_equal(a[k], b[k])
    # the enabled recorder saw both the session and the ckpt layer
    m = rec.metrics()
    assert m["counters"]["session.sweeps"] == 5.0
    assert m["counters"]["ckpt.saves"] >= 1.0
    assert "session.sweep_s" in m["histograms"]
    assert "ckpt.save_s" in m["histograms"]


def test_resolve_chains_env_and_validation(monkeypatch):
    monkeypatch.delenv("REPRO_CHAINS", raising=False)
    assert resolve_chains() == 1
    assert resolve_chains(4) == 4
    monkeypatch.setenv("REPRO_CHAINS", "3")
    assert resolve_chains() == 3          # the CI smoke-leg hook
    assert resolve_chains(2) == 2         # explicit beats env
    with pytest.raises(ValueError, match="chains"):
        resolve_chains(0)


def test_chain_axis_requires_mesh():
    train, _ = _bmf_data(2)
    b = ModelBuilder(num_latent=4)
    b.add_entity("u", 30)
    b.add_entity("v", 20)
    b.add_block("u", "v", train)
    model, data, _ = b.build()
    with pytest.raises(ValueError, match="mesh"):
        Session(model, data, chains=2, chain_axis="chain")


# ---------------------------------------------------------------------------
# the multi-chain store + PredictSession pooling + convergence gate
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mc_store(tmp_path_factory):
    """One chains=3 Macau run streaming every post-burnin sample."""
    d = str(tmp_path_factory.mktemp("mc_store"))
    train, test = _bmf_data(7)
    rng = np.random.default_rng(8)
    F = rng.normal(size=(30, 5)).astype(np.float32)
    s = TrainSession(num_latent=4, burnin=4, nsamples=5, seed=9,
                     chains=3, save_freq=1, save_dir=d)
    s.add_train_and_test(train, test)
    s.add_side_info(0, F)
    r = s.run()
    return d, r, test, F


def test_store_layout_and_standalone_chain_stores(mc_store):
    from repro.core.modelspec import (chain_count_on_disk,
                                      load_model_spec)
    from repro.core.predict import PredictSession
    d, r, test, _ = mc_store
    assert chain_count_on_disk(d) == 3
    top = load_model_spec(os.path.join(d, "model.json"))
    assert top["run"]["chains"] == 3
    assert os.path.exists(os.path.join(d, "diagnostics.json"))
    # every chain_<c>/ is a complete SINGLE-chain store on its own
    sub = PredictSession(os.path.join(d, "chain_1"))
    assert sub.n_chains == 1
    assert sub.num_samples == 5
    spec = load_model_spec(os.path.join(d, "chain_1", "model.json"))
    assert spec["run"]["chain"] == 1


def test_predict_session_pools_all_chains_in_session_order(mc_store):
    from repro.core.predict import PredictSession
    d, r, test, _ = mc_store
    p = PredictSession(d)
    assert p.n_chains == 3
    assert p.num_samples == 15            # 3 chains x 5 samples
    assert p.steps == [5, 6, 7, 8, 9]
    # pooled ids are step-major chain-minor — the in-session
    # accumulation order, so the reload replays the same summation
    assert p.chain_steps[:4] == [(5, 0), (5, 1), (5, 2), (6, 0)]
    pm = p.predict(test[0], test[1])
    assert np.allclose(np.asarray(pm), r.predictions, atol=1e-5)
    rmse = float(np.sqrt(np.mean((np.asarray(pm) - test[2]) ** 2)))
    assert rmse == pytest.approx(r.rmse_test, abs=1e-5)
    # chain addressing validates both coordinates
    p.load_sample(5, chain=2)
    with pytest.raises(ValueError, match="chain"):
        p.load_sample(5, chain=3)
    with pytest.raises(ValueError, match="saved steps"):
        p.load_sample(4, chain=0)


def test_predict_session_convergence_gate(mc_store, tmp_path):
    import shutil
    import warnings
    from repro.core.predict import PredictSession
    d, r, _, _ = mc_store
    # refuse below the recorded worst R-hat, naming the offenders
    worst_k = max((k for k, v in r.diagnostics.rhat.items()
                   if np.isfinite(v)), key=r.diagnostics.rhat.get)
    thr_fail = float(r.diagnostics.rhat[worst_k]) - 1e-6
    with pytest.raises(ValueError, match="NOT converged") as ei:
        PredictSession(d, require_converged=True,
                       rhat_threshold=thr_fail)
    assert worst_k in str(ei.value)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        PredictSession(d, require_converged="warn",
                       rhat_threshold=thr_fail)
    assert any("NOT converged" in str(x.message) for x in w)
    # a converged store serves: threshold above the recorded worst
    thr = float(r.diagnostics.max_rhat) + 0.1
    p = PredictSession(d, require_converged=True, rhat_threshold=thr)
    assert p.diagnostics.converged(thr)
    # a store with NO recorded diagnostics must refuse too — absence
    # of evidence is not convergence evidence
    d2 = tmp_path / "nodiag"
    shutil.copytree(d, d2)
    os.remove(d2 / "diagnostics.json")
    with pytest.raises(ValueError, match="diagnostics"):
        PredictSession(str(d2), require_converged=True)
    assert PredictSession(str(d2)).diagnostics is None   # ungated ok


def test_recommend_single_query_exclude_normalization(mc_store):
    """``recommend(user=3, exclude=[])`` must mean "exclude nothing",
    not "you passed 0 exclude lists for 1 query" — plus the flat-list
    convenience for warm AND cold single queries."""
    from repro.core.predict import PredictSession
    d, _, _, F = mc_store
    p = PredictSession(d)
    # warm, empty exclude
    r0 = p.recommend(user=3, k=5, exclude=[])
    assert r0.ids.shape == (1, 5)
    # warm, flat id list
    r1 = p.recommend(user=3, k=5, exclude=[1, 2])
    assert 1 not in r1.ids[0] and 2 not in r1.ids[0]
    # flat numpy ids behave like the list
    r2 = p.recommend(user=3, k=5, exclude=np.array([1, 2]))
    assert np.array_equal(r1.ids, r2.ids)
    # cold single query through the Macau link, flat + empty excludes
    f_new = F[:1] + 0.01
    rc = p.recommend(features=f_new, k=5, exclude=[7])
    assert rc.ids.shape == (1, 5) and 7 not in rc.ids[0]
    assert p.recommend(features=f_new, k=5,
                       exclude=[]).ids.shape == (1, 5)
    # multi-query still demands one sequence per query
    r3 = p.recommend(user=[0, 1], k=5, exclude=[[1], []])
    assert r3.ids.shape == (2, 5) and 1 not in r3.ids[0]
    with pytest.raises(ValueError, match="per query"):
        p.recommend(user=[0, 1], k=5, exclude=[[1]])


# ---------------------------------------------------------------------------
# satellite regressions: side-info axes, checkpoint errors, resume
# ---------------------------------------------------------------------------

def test_add_side_info_keeps_per_axis_precisions():
    """A second ``add_side_info`` call must not clobber the first
    axis's ``beta_precision`` / ``sample_beta_precision``."""
    from repro.core.priors import MacauPrior
    train, _ = _bmf_data(4)
    rng = np.random.default_rng(5)
    s = TrainSession(num_latent=4, burnin=1, nsamples=1)
    s.add_train_and_test(train)
    s.add_side_info(0, rng.normal(size=(30, 6)).astype(np.float32),
                    beta_precision=2.5, sample_beta_precision=False)
    s.add_side_info(1, rng.normal(size=(20, 3)).astype(np.float32),
                    beta_precision=7.0, sample_beta_precision=True)
    model, _, _ = s._builder().build()
    rows, cols = model.entities
    assert isinstance(rows.prior, MacauPrior)
    assert isinstance(cols.prior, MacauPrior)
    assert rows.prior.beta_precision == 2.5
    assert rows.prior.sample_beta_precision is False
    assert cols.prior.beta_precision == 7.0
    assert cols.prior.sample_beta_precision is True
    with pytest.raises(ValueError, match=r"\(0, 1\)"):
        s.add_side_info(2, rng.normal(size=(9, 2)))


def test_background_checkpoint_error_surfaces(tmp_path, monkeypatch):
    """A failed background save re-raises from the next ``save()`` /
    ``wait()`` on the training thread instead of dying silently (an
    incomplete posterior store nobody notices is worse than a crash),
    and a handled failure does not re-raise forever."""
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint import ckpt as ckpt_mod

    mgr = CheckpointManager(str(tmp_path / "s"), keep=None)
    tree = {"x": np.arange(3.0)}

    def boom(tree, path):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod, "save_pytree", boom)
    mgr.save(1, tree)                      # background thread fails
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.wait()
    mgr.wait()                             # cleared after the raise
    monkeypatch.undo()
    mgr.save(2, tree)                      # manager still usable
    mgr.wait()
    assert mgr.all_steps() == [2]
    # the re-raise also fires from the next save() call
    monkeypatch.setattr(ckpt_mod, "save_pytree", boom)
    mgr.save(3, tree)
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.save(4, tree)


@pytest.mark.parametrize("chains", [1, 3])
def test_resume_records_resumed_from(tmp_path, chains):
    train, test = _bmf_data(6)
    d = str(tmp_path / f"store{chains}")
    kw = dict(num_latent=3, burnin=2, seed=2, chains=chains,
              save_freq=1, save_dir=d)
    s = TrainSession(nsamples=3, **kw)
    s.add_train_and_test(train, test)
    r = s.run()
    assert r.resumed_from is None
    # extend the schedule and resume: picks up at the saved sweep count
    s2 = TrainSession(nsamples=6, **kw)
    s2.add_train_and_test(train, test)
    r2 = s2.run(resume=True)
    assert r2.resumed_from == 5            # burnin 2 + 3 saved draws
    assert len(r2.rmse_train_trace) == 3   # only post-resume sweeps
    assert r2.rmse_test is not None


def test_gfa_resume_past_end_raises_instead_of_zero_means(tmp_path):
    rng = np.random.default_rng(0)
    views = [rng.normal(size=(16, 6)).astype(np.float32),
             rng.normal(size=(16, 4)).astype(np.float32)]
    d = str(tmp_path / "gfa")
    kw = dict(num_latent=3, burnin=2, nsamples=3, seed=1,
              save_freq=1, save_dir=d)
    GFASession(views, **kw).run()
    with pytest.raises(ValueError, match="ZERO posterior draws"):
        GFASession(views, **kw).run(resume=True)


def test_gfa_multichain_follows_chain_zero():
    rng = np.random.default_rng(1)
    views = [rng.normal(size=(16, 6)).astype(np.float32),
             rng.normal(size=(16, 4)).astype(np.float32)]
    kw = dict(num_latent=3, burnin=3, nsamples=3, seed=4)
    single = GFASession(views, chains=1, **kw).run()
    multi = GFASession(views, chains=2, **kw).run()
    # rotation indeterminacy forbids pooling loadings across chains:
    # Z/W follow chain 0 — bitwise the single-chain run
    assert np.array_equal(multi["Z"], single["Z"])
    for wm, ws in zip(multi["W"], single["W"]):
        assert np.array_equal(wm, ws)
    assert multi["Z_chains"].shape == (2,) + single["Z"].shape
    assert multi["diagnostics"] is not None
    assert multi["diagnostics"].n_chains == 2
    # a single chain still gets split-R-hat (catches drift within it)
    assert single["diagnostics"].n_chains == 1


# ---------------------------------------------------------------------------
# contract arithmetic (no devices needed)
# ---------------------------------------------------------------------------

def test_contract_for_chain_census_arithmetic():
    from repro.analysis.contract import contract_for
    train, _ = _bmf_data(2)
    b = ModelBuilder(num_latent=4)
    b.add_entity("u", 30)
    b.add_entity("v", 20)
    b.add_block("u", "v", train)
    model, _, _ = b.build()

    base = contract_for(model, (8,), "eager")
    c3 = contract_for(model, (8,), "eager", chains=3)
    # no chain axis: every shard sweeps all C chains serially — counts
    # scale by C, per-op payloads do not
    assert c3.chains == 3
    assert c3.n_shards == 8
    assert c3.all_gathers == 3 * base.all_gathers
    assert c3.all_reduces == 3 * base.all_reduces
    assert c3.max_reduce_elems == base.max_reduce_elems
    # chain axis: chains spread over it — the per-group census equals
    # the single-chain census on the SMALLER shard group
    cx = contract_for(model, (2, 4), "ring", chains=2,
                      chain_axis_size=2)
    assert cx.chains == 1
    assert cx.n_shards == 4
    assert cx.collective_permutes == 2 * (4 - 1)   # E * (S-1), S=4
    with pytest.raises(ValueError, match="divide"):
        contract_for(model, (2, 4), "eager", chains=3,
                     chain_axis_size=2)
    with pytest.raises(ValueError, match="chains"):
        contract_for(model, (8,), "eager", chains=0)


# ---------------------------------------------------------------------------
# 8-device shard_map parity + census (subprocess; slow)
# ---------------------------------------------------------------------------

_MC_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import gibbs
    from repro.core.distributed import (make_distributed_step,
                                        make_multi_chain_step)
    from repro.launch.mesh import make_mesh
    from repro.core.session import ModelBuilder
    from repro.core.sparse import from_coo
    from repro.analysis.contract import assert_contract, contract_for

    rng = np.random.default_rng(3)
    b = ModelBuilder(num_latent=4)
    b.add_entity("u", 48); b.add_entity("v", 32)
    i = rng.integers(0, 48, 300); j = rng.integers(0, 32, 300)
    v = rng.normal(size=300).astype(np.float32)
    b.add_block("u", "v", from_coo(i, j, v, (48, 32)))
    model, data, _ = b.build()

    def leaves_equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a),
                                   jax.tree.leaves(b)))

    C, SW = 3, 2
    for pipeline in ("eager", "ring"):
        mesh = make_mesh((8,), ("data",))
        # C independent distributed chains through ONE compiled step
        keys = gibbs.chain_keys(11, C)
        st0 = gibbs.init_state(model, data, 11, key=keys[0])
        fn, ds, ss = make_distributed_step(model, mesh, data, st0,
                                           pipeline)
        dd = jax.device_put(data, ds)
        indep = []
        for k in keys:
            st = jax.device_put(
                gibbs.init_state(model, data, 11, key=k), ss)
            for _ in range(SW):
                st, m = fn(dd, st)
            indep.append(jax.tree.map(np.asarray, st))
        # the stacked multi-chain program, same mesh
        stacked = gibbs.stack_states(
            gibbs.init_chain_states(model, data, 11, C))
        mfn, mds, mss = make_multi_chain_step(model, mesh, data,
                                              stacked, pipeline,
                                              chains=C)
        stk = jax.device_put(stacked, mss)
        for _ in range(SW):
            stk, mm = mfn(jax.device_put(data, mds), stk)
        stk = jax.tree.map(np.asarray, stk)
        for c in range(C):
            assert leaves_equal(gibbs.unstack_state(stk, c),
                                indep[c]), (pipeline, c)
        assert np.asarray(mm["rmse_train_0"]).shape == (C,)
        # the census: contract verified on THIS program's StableHLO
        # and compiled HLO, counts scaled by C
        low = mfn.lower(data, stacked)
        contract = contract_for(model, (8,), pipeline, chains=C)
        assert_contract(contract, lowered_text=low.as_text(),
                        compiled_text=low.compile().as_text(),
                        where=f"{pipeline} no-chain-axis")
        print(pipeline, "bitwise + census ok")

    # chain mesh axis: ("chain", 2) x ("data", 4) — each 4-shard group
    # sweeps ONE local chain, bitwise the 4-shard single-chain run
    C = 2
    mesh = make_mesh((2, 4), ("chain", "data"))
    m4 = make_mesh((4,), ("data",))
    keys = gibbs.chain_keys(11, C)
    st0 = gibbs.init_state(model, data, 11, key=keys[0])
    fn, ds, ss = make_distributed_step(model, m4, data, st0, "eager")
    dd = jax.device_put(data, ds)
    indep = []
    for k in keys:
        st = jax.device_put(
            gibbs.init_state(model, data, 11, key=k), ss)
        for _ in range(SW):
            st, m = fn(dd, st)
        indep.append(jax.tree.map(np.asarray, st))
    stacked = gibbs.stack_states(
        gibbs.init_chain_states(model, data, 11, C))
    mfn, mds, mss = make_multi_chain_step(model, mesh, data, stacked,
                                          "eager", chains=C,
                                          chain_axis="chain")
    stk = jax.device_put(stacked, mss)
    for _ in range(SW):
        stk, mm = mfn(jax.device_put(data, mds), stk)
    stk = jax.tree.map(np.asarray, stk)
    for c in range(C):
        assert leaves_equal(gibbs.unstack_state(stk, c), indep[c]), c
    low = mfn.lower(data, stacked)
    contract = contract_for(model, (2, 4), "eager", chains=C,
                            chain_axis_size=2)
    assert contract.n_shards == 4 and contract.chains == 1
    assert_contract(contract, lowered_text=low.as_text(),
                    compiled_text=low.compile().as_text(),
                    where="chain-axis")
    print("chain-axis bitwise + census ok")
    print("OK")
""")


@pytest.mark.slow
def test_multi_chain_distributed_bitwise_and_census_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MC_DISTRIBUTED_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout

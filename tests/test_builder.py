"""ModelBuilder: compositional multi-relation models, validated eagerly.

The paper's framework claim (Table 1: priors x noise x matrix types x
side info compose freely) through the declarative builder: a
two-relation graph sharing an entity (compound x target AND
compound x cell-line) runs end to end — single-device, and through the
explicit distributed sweep on a mesh under BOTH exchange pipelines —
and every construction mistake raises a ValueError naming the valid
choices at ``add_*`` time, not a shape error inside jit.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import (AdaptiveGaussian, FixedGaussian, ModelBuilder,
                        ProbitNoise, SparseMatrix, from_coo)


def _two_relation_data(seed=0, n_c=48, n_t=32, n_l=16, n_feat=8,
                       rank=3, noise=0.1):
    """Planted two-relation data sharing the compound entity, with a
    linear feature->latent link so the Macau prior has signal."""
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(n_c, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    U = F @ B
    T = rng.normal(size=(n_t, rank)).astype(np.float32)
    L = rng.normal(size=(n_l, rank)).astype(np.float32)
    act = (U @ T.T + noise * rng.normal(size=(n_c, n_t))) \
        .astype(np.float32)
    via = (U @ L.T + noise * rng.normal(size=(n_c, n_l))) \
        .astype(np.float32)
    obs = rng.random((n_c, n_t)) < 0.4
    i, j = np.nonzero(obs)
    perm = rng.permutation(len(i))
    i, j = i[perm], j[perm]
    v = act[i, j]
    n_test = len(i) // 5
    mat = from_coo(i[n_test:], j[n_test:], v[n_test:], (n_c, n_t))
    test = (i[:n_test], j[:n_test], v[:n_test])
    return F, mat, test, via, act


def _builder(F, mat, test, via, num_latent=4):
    n_c, n_feat = F.shape
    b = ModelBuilder(num_latent=num_latent)
    b.add_entity("compound", n_c, side_info=F)
    b.add_entity("target", mat.shape[1])
    b.add_entity("cellline", via.shape[1])
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian(),
                test=test)
    b.add_block("compound", "cellline", via, noise=AdaptiveGaussian())
    return b


# ---------------------------------------------------------------------------
# eager validation: every mistake names the valid choices
# ---------------------------------------------------------------------------

def test_unknown_entity_names_choices():
    b = ModelBuilder(4).add_entity("rows", 8).add_entity("cols", 4)
    with pytest.raises(ValueError) as ei:
        b.add_block("rows", "bogus", np.zeros((8, 4), np.float32))
    msg = str(ei.value)
    assert "bogus" in msg and "rows" in msg and "cols" in msg


def test_unknown_entity_before_any_entities():
    with pytest.raises(ValueError, match="add_entity first"):
        ModelBuilder(4).add_block("a", "b", np.zeros((2, 2), np.float32))


def test_duplicate_entity_rejected():
    b = ModelBuilder(4).add_entity("rows", 8)
    with pytest.raises(ValueError, match="duplicate entity 'rows'"):
        b.add_entity("rows", 9)


def test_shape_mismatch_names_expected():
    b = ModelBuilder(4).add_entity("rows", 8).add_entity("cols", 4)
    with pytest.raises(ValueError) as ei:
        b.add_block("rows", "cols", np.zeros((8, 5), np.float32))
    msg = str(ei.value)
    assert "(8, 5)" in msg and "(8, 4)" in msg


def test_duplicate_block_rejected_both_orientations():
    X = np.zeros((8, 4), np.float32)
    b = ModelBuilder(4).add_entity("rows", 8).add_entity("cols", 4)
    b.add_block("rows", "cols", X)
    with pytest.raises(ValueError, match="duplicate block"):
        b.add_block("rows", "cols", X)
    with pytest.raises(ValueError, match="duplicate block"):
        b.add_block("cols", "rows", X.T)   # same pair, transposed


def test_self_block_rejected():
    b = ModelBuilder(4).add_entity("rows", 8)
    with pytest.raises(ValueError, match="distinct entities"):
        b.add_block("rows", "rows", np.zeros((8, 8), np.float32))


def test_unknown_prior_name_lists_registry():
    b = ModelBuilder(4)
    with pytest.raises(ValueError) as ei:
        b.add_entity("rows", 8, prior="bogus")
    msg = str(ei.value)
    for name in ("normal", "spikeandslab", "fixednormal"):
        assert name in msg


def test_prior_and_side_info_conflict():
    with pytest.raises(ValueError, match="side information selects"):
        ModelBuilder(4).add_entity(
            "rows", 8, prior="spikeandslab",
            side_info=np.zeros((8, 2), np.float32))


def test_side_info_shape_checked():
    with pytest.raises(ValueError, match=r"\(8, D\)"):
        ModelBuilder(4).add_entity(
            "rows", 8, side_info=np.zeros((9, 2), np.float32))


def test_empty_model_rejected():
    with pytest.raises(ValueError, match="empty model"):
        ModelBuilder(4).build()
    with pytest.raises(ValueError, match="no blocks"):
        ModelBuilder(4).add_entity("rows", 8).build()


def test_test_set_block_index_checked():
    from repro.core import Session
    b = ModelBuilder(4).add_entity("r", 8).add_entity("c", 4)
    b.add_block("r", "c", np.zeros((8, 4), np.float32))
    model, data, _ = b.build()
    from repro.core.predict import make_test_set
    ts = make_test_set([0], [0], [0.0])
    with pytest.raises(ValueError, match="blocks 0..0"):
        Session(model, data, tests={3: ts})


# ---------------------------------------------------------------------------
# end-to-end: two relations sharing an entity
# ---------------------------------------------------------------------------

def test_two_relation_shared_entity_end_to_end():
    F, mat, test, via, _ = _two_relation_data()
    sweeps = []
    res = _builder(F, mat, test, via).session(
        burnin=20, nsamples=20, seed=0,
        callbacks=[lambda info: sweeps.append(info.phase)]).run()
    # both relations converge toward the planted noise floor
    assert res.blocks[0].entities == ("compound", "target")
    assert res.blocks[1].entities == ("compound", "cellline")
    assert res.blocks[0].rmse_train_trace[-1] < 0.3
    assert res.blocks[1].rmse_train_trace[-1] < 0.3
    assert res.rmse_test is not None and res.rmse_test < 0.5
    # the shared compound factor serves BOTH blocks: traces exist for
    # both and the callback saw every sweep with the right phase
    assert len(res.blocks[1].rmse_train_trace) == 40
    assert sweeps == ["burnin"] * 20 + ["sample"] * 20


def test_builder_probit_block_auc():
    rng = np.random.default_rng(3)
    U = rng.normal(size=(120, 4)).astype(np.float32)
    V = rng.normal(size=(40, 4)).astype(np.float32)
    P = (U @ V.T + 0.3 * rng.normal(size=(120, 40)) > 0)
    obs = rng.random((120, 40)) < 0.5
    i, j = np.nonzero(obs)
    perm = rng.permutation(len(i))
    i, j = i[perm], j[perm]
    v = P[i, j].astype(np.float32)
    n_test = len(i) // 5
    mat = from_coo(i[n_test:], j[n_test:], v[n_test:], (120, 40))
    b = ModelBuilder(4).add_entity("u", 120).add_entity("v", 40)
    b.add_block("u", "v", mat, noise=ProbitNoise(),
                test=(i[:n_test], j[:n_test], v[:n_test]))
    res = b.session(burnin=60, nsamples=60, seed=0).run()
    assert res.auc_test is not None and res.auc_test > 0.8


def test_builder_mesh_pipelines_match_single_device():
    """The two-relation model routes through the explicit distributed
    sweep: on the degenerate 1-device mesh both exchange pipelines
    reproduce the plain single-device chain (the knob may not change
    the SAMPLED chain)."""
    from repro.launch.mesh import make_mesh
    F, mat, test, via, _ = _two_relation_data()

    def run(**kw):
        return _builder(F, mat, test, via).session(
            burnin=4, nsamples=4, seed=0, **kw).run()

    ref = run()
    mesh = make_mesh((1,), ("data",))
    from repro.core.distributed import distributed_supported
    model, data, _ = _builder(F, mat, test, via).build()
    assert distributed_supported(model, mesh, data)
    for pipe in ("eager", "ring"):
        res = run(mesh=mesh, pipeline=pipe)
        np.testing.assert_allclose(res.rmse_train_trace,
                                   ref.rmse_train_trace, rtol=1e-5,
                                   err_msg=pipe)
        np.testing.assert_allclose(res.blocks[1].rmse_train_trace,
                                   ref.blocks[1].rmse_train_trace,
                                   rtol=1e-5, err_msg=pipe)
        np.testing.assert_allclose(res.rmse_test, ref.rmse_test,
                                   rtol=1e-5, err_msg=pipe)


def test_fallback_reason_names_offending_piece():
    """``distributed_unsupported_reason`` pinpoints WHY a model misses
    the explicit sweep — the session fallback warning surfaces it."""
    import dataclasses

    from repro.core import EntityDef, Session
    from repro.core.distributed import distributed_unsupported_reason
    from repro.launch.mesh import make_mesh
    b = ModelBuilder(3).add_entity("r", 8).add_entity("c", 4)
    b.add_block("r", "c", np.ones((8, 4), np.float32),
                noise=FixedGaussian(10.0))
    model, data, _ = b.build()
    mesh = make_mesh((1,), ("data",))
    assert distributed_unsupported_reason(model, mesh, data) is None

    class WeirdPrior:
        """Delegates to NormalPrior but is NOT one of the whitelisted
        types — the single-device sweep runs it, the sharded moment
        algebra cannot admit it."""

        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, a):
            return getattr(self._inner, a)

    from repro.core import NormalPrior
    model2 = dataclasses.replace(
        model, entities=(EntityDef("r", 8, WeirdPrior(NormalPrior(3))),
                         model.entities[1]))
    reason = distributed_unsupported_reason(model2, mesh, data)
    assert reason is not None and "WeirdPrior" in reason \
        and "'r'" in reason
    # the session-layer fallback WARNS with that reason and the pjit
    # fallback still samples a chain
    sess = Session(model2, data, burnin=1, nsamples=1, seed=0,
                   mesh=mesh)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res = sess.run()
    assert any("WeirdPrior" in str(x.message) for x in w)
    assert np.isfinite(res.rmse_train_trace[-1])


_MULTI_RELATION_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.core import AdaptiveGaussian, ModelBuilder, from_coo
    from repro.core.distributed import distributed_supported
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(0)
    n_c, n_t, n_l, n_feat, rank = 64, 32, 16, 8, 3
    F = rng.normal(size=(n_c, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \\
        .astype(np.float32)
    U = F @ B
    T = rng.normal(size=(n_t, rank)).astype(np.float32)
    L = rng.normal(size=(n_l, rank)).astype(np.float32)
    act = (U @ T.T + 0.1 * rng.normal(size=(n_c, n_t))) \\
        .astype(np.float32)
    via = (U @ L.T + 0.1 * rng.normal(size=(n_c, n_l))) \\
        .astype(np.float32)
    obs = rng.random((n_c, n_t)) < 0.4
    i, j = np.nonzero(obs)
    v = act[i, j]
    n_test = len(i) // 5
    mat = from_coo(i[n_test:], j[n_test:], v[n_test:], (n_c, n_t))
    test = (i[:n_test], j[:n_test], v[:n_test])

    def build():
        b = ModelBuilder(num_latent=4)
        b.add_entity("compound", n_c, side_info=F)
        b.add_entity("target", n_t)
        b.add_entity("cellline", n_l)
        b.add_block("compound", "target", mat,
                    noise=AdaptiveGaussian(), test=test)
        b.add_block("compound", "cellline", via,
                    noise=AdaptiveGaussian())
        return b

    model, data, _ = build().build()
    mesh = make_mesh((8,), ("data",))
    assert distributed_supported(model, mesh, data), \\
        "two-relation Macau graph must be in the sharded subset"

    ref = build().session(burnin=3, nsamples=3, seed=0).run()
    for pipe in ("eager", "ring"):
        res = build().session(burnin=3, nsamples=3, seed=0,
                              mesh=mesh, pipeline=pipe).run()
        for bi in range(2):
            np.testing.assert_allclose(
                res.blocks[bi].rmse_train_trace,
                ref.blocks[bi].rmse_train_trace,
                rtol=2e-4, atol=2e-4, err_msg=f"{pipe} block {bi}")
        np.testing.assert_allclose(res.rmse_test, ref.rmse_test,
                                   rtol=2e-4, atol=2e-4, err_msg=pipe)
        print(pipe, "8-dev ==", res.rmse_test)
    print("OK")
""")


@pytest.mark.slow
def test_two_relation_model_8dev_parity():
    """The builder-composed two-relation shared-entity model (Macau
    compound prior, sparse + dense blocks) runs the explicit 8-shard
    sweep under BOTH exchange pipelines and matches the single-device
    chain at reduction-order tolerance."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c",
                          _MULTI_RELATION_MESH_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout

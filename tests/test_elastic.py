"""Elastic checkpoint/re-mesh round-trip: state on DISK, not just
in-process.

The in-process 8 -> 6 shrink in tests/test_distributed.py proves the
sweep tolerates a survivor-count change; this harness proves the full
production failure path (ROADMAP "elastic re-mesh test at scale"):

  1. run a sharded chain on an 8-device mesh,
  2. checkpoint it through ``checkpoint/ckpt.py`` (atomic npz-on-disk,
     the same manager the train loop uses),
  3. simulate a device loss (``runtime/fault.FailureSim``),
  4. rebuild a mesh over the 6 survivors with ``ElasticMesh``,
  5. restore the checkpoint from disk into the new shardings and
     continue the chain,

and asserts the restored chain matches the single-device reference at
the SAME 2e-4 tolerance as tests/test_distributed.py — possible only
because every per-row draw (factor normals AND probit truncated-normal
uniforms) is counter-based on the global row index, so neither the
mesh shape nor the host round-trip perturbs the sampled bits.

Runs on the paper's headline classification workload (probit noise),
exercising the widened sharded subset end to end.  Subprocess because
the device count locks at jax init.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ELASTIC_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.core import MFData, ProbitNoise, init_state, gibbs_step
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import NormalPrior
    from repro.core.sparse import random_sparse
    from repro.runtime.fault import ElasticMesh, FailureSim

    K = 8
    n_rows, n_cols = 96, 48
    mat, _, _ = random_sparse(0, (n_rows, n_cols), 0.2, rank=4,
                              binary=True)
    model = ModelDef((EntityDef("r", n_rows, NormalPrior(K)),
                      EntityDef("c", n_cols, NormalPrior(K))),
                     (BlockDef(0, 1, ProbitNoise(), sparse=True),), K,
                     False)
    data = MFData((mat,), (None, None))
    state0 = init_state(model, data, seed=0)

    TOTAL, FAIL_AT = 4, 2
    # single-device reference chain, uninterrupted
    ref = state0
    for _ in range(TOTAL):
        ref, mref = gibbs_step(model, data, ref)

    ckpt = CheckpointManager(tempfile.mkdtemp(), keep=2)
    sim = FailureSim(fail_at=[FAIL_AT], lose_devices=2)
    elastic = ElasticMesh(model_parallel=1)
    devices = list(jax.devices())            # 8 healthy to start

    mesh = elastic.build(devices)
    assert mesh.devices.size == 8
    assert distributed_supported(model, mesh, data)
    step, ds, ss = make_distributed_step(model, mesh, data, state0)
    pdata = jax.device_put(data, ds)
    st = jax.device_put(state0, ss)

    sweep, resumed_on = 0, None
    while sweep < TOTAL:
        try:
            sim.check(sweep)
            st, m = step(pdata, st)
            sweep += 1
            ckpt.save(sweep, st, blocking=True)   # host npz on disk
        except FailureSim.DeviceLost:
            # lose two chips -> rebuild mesh over the 6 survivors,
            # restore the LAST COMPLETE on-disk checkpoint into the
            # new shardings, and continue the same chain
            devices = devices[:len(devices) - sim.lose]
            mesh = elastic.build(devices)
            assert mesh.devices.size == 6
            assert distributed_supported(model, mesh, data)
            step, ds, ss = make_distributed_step(model, mesh, data,
                                                 state0)
            pdata = jax.device_put(data, ds)
            restored = ckpt.restore_latest(state0)
            assert restored is not None, "no complete checkpoint"
            sweep, host_state = restored
            resumed_on = sweep
            st = jax.device_put(host_state, ss)

    assert sim.failures == 1 and resumed_on == FAIL_AT
    assert int(st.step) == TOTAL

    # the re-meshed, disk-round-tripped chain IS the reference chain
    for a, b in zip(ref.factors, st.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(mref["rmse_train_0"]),
                               float(m["rmse_train_0"]), rtol=1e-3)
    print("resumed on sweep", resumed_on, "final rmse",
          float(m["rmse_train_0"]))
    print("OK")
""")


_ELASTIC_SNS_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.core import (AdaptiveGaussian, MFData, dense_block,
                            init_state, gibbs_step)
    from repro.core.blocks import BlockDef, EntityDef, ModelDef
    from repro.core.distributed import (distributed_supported,
                                        make_distributed_step)
    from repro.core.priors import FixedNormalPrior, SpikeAndSlabPrior
    from repro.runtime.fault import ElasticMesh, FailureSim

    # GFA (Normal + SnS): every entity dim divides both the 8-device
    # mesh and the 6-survivor re-mesh
    K, N, dims = 4, 96, (72, 24)
    rng = np.random.default_rng(0)
    Z = rng.normal(size=(N, K)).astype(np.float32)
    ents = [EntityDef("samples", N, FixedNormalPrior(K))]
    blocks, payloads = [], []
    for m, D in enumerate(dims):
        W = rng.normal(size=(D, K)).astype(np.float32)
        X = (Z @ W.T + 0.1 * rng.normal(size=(N, D))).astype(np.float32)
        ents.append(EntityDef(f"view{m}", D, SpikeAndSlabPrior(K)))
        blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                               sparse=False))
        payloads.append(dense_block(X))
    model = ModelDef(tuple(ents), tuple(blocks), K, False)
    data = MFData(tuple(payloads), tuple([None] * len(ents)))
    state0 = init_state(model, data, seed=0)

    TOTAL, FAIL_AT = 4, 2
    ref = state0
    for _ in range(TOTAL):
        ref, mref = gibbs_step(model, data, ref)

    ckpt = CheckpointManager(tempfile.mkdtemp(), keep=2)
    sim = FailureSim(fail_at=[FAIL_AT], lose_devices=2)
    elastic = ElasticMesh(model_parallel=1)
    devices = list(jax.devices())

    mesh = elastic.build(devices)
    assert distributed_supported(model, mesh, data)
    step, ds, ss = make_distributed_step(model, mesh, data, state0)
    pdata = jax.device_put(data, ds)
    st = jax.device_put(state0, ss)

    sweep, resumed_on = 0, None
    while sweep < TOTAL:
        try:
            sim.check(sweep)
            st, m = step(pdata, st)
            sweep += 1
            ckpt.save(sweep, st, blocking=True)
        except FailureSim.DeviceLost:
            devices = devices[:len(devices) - sim.lose]
            mesh = elastic.build(devices)
            assert mesh.devices.size == 6
            assert distributed_supported(model, mesh, data)
            step, ds, ss = make_distributed_step(model, mesh, data,
                                                 state0)
            pdata = jax.device_put(data, ds)
            restored = ckpt.restore_latest(state0)
            assert restored is not None, "no complete checkpoint"
            sweep, host_state = restored
            resumed_on = sweep
            st = jax.device_put(host_state, ss)

    assert sim.failures == 1 and resumed_on == FAIL_AT
    assert int(st.step) == TOTAL

    # factors AND the SnS rho/tau hyper-state ride the npz round-trip
    # + re-mesh and land back on the single-device chain
    for a, b in zip(ref.factors, st.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    for e in range(1, len(ents)):
        for hk in ("rho", "tau"):
            np.testing.assert_allclose(
                np.asarray(ref.hypers[e][hk]),
                np.asarray(st.hypers[e][hk]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(mref["rmse_train_0"]),
                               float(m["rmse_train_0"]), rtol=1e-3)
    print("resumed on sweep", resumed_on, "final rmse",
          float(m["rmse_train_0"]))
    print("OK")
""")


def _run(script, pipeline=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    if pipeline is not None:
        # the scripts build their steps through make_distributed_step's
        # env default, so the same harness runs both exchange pipelines
        env["REPRO_PIPELINE"] = pipeline
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


@pytest.mark.slow
def test_elastic_checkpoint_remesh_roundtrip():
    _run(_ELASTIC_SCRIPT)


@pytest.mark.slow
def test_elastic_sns_hyper_state_roundtrip():
    """The GFA chain (spike-and-slab rho/tau hyper-state) checkpoints
    to disk, re-meshes 8 -> 6, restores, and rejoins the single-device
    chain at the same 2e-4 tolerance."""
    _run(_ELASTIC_SNS_SCRIPT)


@pytest.mark.slow
def test_elastic_remesh_roundtrip_ring_pipeline():
    """The 8 -> 6 re-mesh round-trip (disk checkpoint, device loss,
    survivor rebuild) holds under the ring exchange too: the ring is
    pure data-movement re-plumbing of the fixed-factor exchange, so
    neither the npz round-trip nor the survivor count nor the exchange
    pipeline perturbs the counter-based chain."""
    _run(_ELASTIC_SCRIPT, pipeline="ring")

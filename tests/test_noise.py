"""Noise models: adaptive precision posterior + probit augmentation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import (AdaptiveGaussian, FixedGaussian,
                              ProbitNoise, _truncnorm)


def test_fixed_gaussian_identity():
    n = FixedGaussian(7.5)
    st = n.init()
    assert float(st["alpha"]) == 7.5
    vals = jnp.ones((3, 4))
    out, alpha = n.augment(jax.random.PRNGKey(0), st, None, vals, vals)
    assert out is vals and float(alpha) == 7.5


def test_adaptive_gaussian_finds_precision():
    """alpha posterior concentrates at 1/sigma^2 of the residuals."""
    rng = np.random.default_rng(0)
    sigma = 0.5
    resid = sigma * rng.normal(size=(200, 300)).astype(np.float32)
    vals = jnp.asarray(resid)           # pred = 0
    pred = jnp.zeros_like(vals)
    mask = jnp.ones_like(vals)
    n = AdaptiveGaussian()
    st = n.init()
    alphas = []
    for i in range(20):
        st = n.sample_state(jax.random.PRNGKey(i), st, pred, vals, mask)
        alphas.append(float(st["alpha"]))
    est = np.mean(alphas)
    assert abs(est - 1 / sigma**2) / (1 / sigma**2) < 0.05, est


def test_adaptive_gaussian_respects_mask():
    vals = jnp.asarray([[100.0, 0.1], [100.0, -0.1]])
    pred = jnp.zeros((2, 2))
    mask = jnp.asarray([[0.0, 1.0], [0.0, 1.0]])   # ignore the 100s
    n = AdaptiveGaussian()
    st = n.init()
    st = n.sample_state(jax.random.PRNGKey(0), st, pred, vals, mask)
    assert float(st["alpha"]) > 1.0    # small residuals -> high precision


def test_truncnorm_signs():
    key = jax.random.PRNGKey(0)
    mean = jnp.zeros((10000,))
    pos = jnp.ones_like(mean)
    z = _truncnorm(key, mean, pos)
    assert float((z > 0).mean()) == 1.0
    z2 = _truncnorm(key, mean, jnp.zeros_like(mean))
    assert float((z2 < 0).mean()) == 1.0


def test_truncnorm_moments():
    """Half-normal mean = sqrt(2/pi)."""
    key = jax.random.PRNGKey(1)
    z = _truncnorm(key, jnp.zeros((200000,)), jnp.ones((200000,)))
    np.testing.assert_allclose(float(z.mean()), np.sqrt(2 / np.pi),
                               rtol=0.02)


def test_truncnorm_extreme_means_finite():
    key = jax.random.PRNGKey(2)
    mean = jnp.asarray([-12.0, 12.0, -6.0, 6.0])
    z = _truncnorm(key, mean, jnp.asarray([1.0, 0.0, 1.0, 0.0]))
    assert bool(jnp.isfinite(z).all())


def test_probit_augment():
    n = ProbitNoise()
    st = n.init()
    key = jax.random.PRNGKey(3)
    pred = jnp.zeros((50, 50))
    vals = (jax.random.uniform(key, (50, 50)) > 0.5).astype(jnp.float32)
    mask = jnp.ones_like(vals)
    z, alpha = n.augment(key, st, pred, vals, mask)
    assert float(alpha) == 1.0
    pos = np.asarray(vals) > 0.5
    zn = np.asarray(z)
    assert (zn[pos] > 0).all()
    assert (zn[~pos] < 0).all()


def test_probit_augment_row_offset_slices_bitwise():
    """The counter-based contract the distributed sweep relies on: a
    shard augmenting rows [off, off+n) with ``row_offset=off`` draws
    exactly the bits of the full augmentation's slice."""
    rng = np.random.default_rng(0)
    pred = jnp.asarray(rng.normal(size=(24, 9)), jnp.float32)
    vals = jnp.asarray((rng.random((24, 9)) < 0.5), jnp.float32)
    mask = jnp.ones_like(vals)
    n = ProbitNoise()
    st = n.init()
    key = jax.random.PRNGKey(7)
    z_full, _ = n.augment(key, st, pred, vals, mask)
    for off, cnt in ((0, 8), (8, 8), (16, 8), (6, 12)):
        sl = slice(off, off + cnt)
        z_part, _ = n.augment(key, st, pred[sl], vals[sl], mask[sl],
                              row_offset=off)
        np.testing.assert_array_equal(np.asarray(z_part),
                                      np.asarray(z_full)[sl])


def test_probit_augment_batch_shape_independent():
    """Row i's draw depends only on (key, global row index) — never on
    how many rows ride in the batch (the row_normals trick, applied to
    the probit uniforms)."""
    rng = np.random.default_rng(1)
    pred = jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)
    vals = jnp.asarray((rng.random((16, 5)) < 0.5), jnp.float32)
    mask = jnp.ones_like(vals)
    n = ProbitNoise()
    st = n.init()
    key = jax.random.PRNGKey(11)
    z16, _ = n.augment(key, st, pred, vals, mask)
    z4, _ = n.augment(key, st, pred[:4], vals[:4], mask[:4])
    np.testing.assert_array_equal(np.asarray(z4), np.asarray(z16)[:4])


def test_adaptive_gaussian_empty_block_keeps_alpha():
    """An all-masked block (nnz == 0, e.g. a fully padded shard view)
    has no residuals: the alpha draw from the data-free Gamma
    conditional is degenerate, so the previous alpha is kept — and it
    must never go NaN."""
    n = AdaptiveGaussian(sn_init=2.5)
    st = n.init()
    vals = jnp.ones((4, 3))
    pred = jnp.zeros_like(vals)
    zero_mask = jnp.zeros_like(vals)
    st1 = n.sample_state(jax.random.PRNGKey(0), st, pred, vals,
                         zero_mask)
    assert float(st1["alpha"]) == 2.5
    # the psummed override path the distributed sweep uses
    st2 = n.sample_state(jax.random.PRNGKey(0), st, pred, vals,
                         zero_mask, sse=jnp.asarray(0.0),
                         nnz=jnp.asarray(0.0))
    assert float(st2["alpha"]) == 2.5
    # with observations the draw still moves
    st3 = n.sample_state(jax.random.PRNGKey(0), st, pred, vals,
                         jnp.ones_like(vals))
    assert np.isfinite(float(st3["alpha"])) and float(st3["alpha"]) != 2.5


def test_empty_block_sweep_stays_finite():
    """A full gibbs_step over an all-masked dense block: factors fall
    back to the prior, alpha holds, and the rmse metric reports 0
    instead of 0/0 -> NaN."""
    from repro.core import (BlockDef, EntityDef, MFData, ModelDef,
                            NormalPrior, dense_block, gibbs_step,
                            init_state)
    X = np.ones((8, 6), np.float32)
    blk = dense_block(X, mask=np.zeros_like(X))
    model = ModelDef((EntityDef("r", 8, NormalPrior(3)),
                      EntityDef("c", 6, NormalPrior(3))),
                     (BlockDef(0, 1, AdaptiveGaussian(), sparse=False),),
                     3, False)
    data = MFData((blk,), (None, None))
    state = init_state(model, data, 0)
    state, metrics = gibbs_step(model, data, state)
    for f in state.factors:
        assert bool(jnp.all(jnp.isfinite(f)))
    assert float(metrics["rmse_train_0"]) == 0.0
    assert np.isfinite(float(metrics["alpha_0"]))

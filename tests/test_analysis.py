"""Self-tests for the static-analysis subsystem (repro.analysis).

The linter is validated two ways: fixture modules under
tests/fixtures/analysis/ carry seeded violations marked with
``# expect: <rule-id>`` comments (every marked line must be found, at
the right line, and nothing else), and the real tree must come back
clean — the linter IS the regression test for the invariants PRs 1–5
earned.

The contract engine is validated arithmetically here (the derivation
for every model-zoo family on both mesh layouts and both pipelines)
and against synthetic HLO with seeded violations; the end-to-end
checks against real lowerings live in tests/test_distributed.py and
the dry-run CLI.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (CommContract, ContractViolation, Finding,
                            assert_contract, check_compiled,
                            check_lowered, contract_for, lint_paths,
                            resolve_rules)
from repro.analysis.invariants import REPRO_ROOT
from repro.core.blocks import BlockDef, EntityDef, ModelDef
from repro.core.noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from repro.core.priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                               SpikeAndSlabPrior)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO_ROOT = Path(__file__).resolve().parents[1]

BAD_FIXTURES = sorted(p.name for p in FIXTURES.glob("bad_*.py"))


def _expected(path: Path):
    """{(line, rule-id)} read from the fixture's # expect: markers."""
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if "# expect: " in line:
            out.add((i, line.split("# expect: ", 1)[1].strip()))
    return out


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_fixture_violations_detected_at_marked_lines(name):
    path = FIXTURES / name
    expected = _expected(path)
    assert expected, f"{name} has no # expect: markers"
    found = {(f.line, f.rule) for f in lint_paths([path])}
    assert found == expected, (name, found, expected)


def test_suppression_comments_silence_findings():
    findings = lint_paths([FIXTURES / "suppressed_clean.py"])
    assert findings == [], [f.format() for f in findings]


def test_clean_tree_zero_findings():
    findings = lint_paths()          # defaults to all of src/repro
    assert findings == [], "\n".join(f.format() for f in findings)
    # and the default target really is the package under test
    assert (REPRO_ROOT / "core" / "gibbs.py").exists()


def test_findings_report_file_line_rule_and_hint():
    f = lint_paths([FIXTURES / "bad_registry_error.py"])[0]
    assert isinstance(f, Finding)
    txt = f.format()
    assert f"bad_registry_error.py:{f.line}:" in txt
    assert "[registry-error-without-choices]" in txt
    assert "fix:" in txt


def test_resolve_rules_names_choices_on_typo():
    assert [r.id for r in resolve_rules("nondeterminism-in-core")] == \
        ["nondeterminism-in-core"]
    with pytest.raises(ValueError, match="valid rules.*batch-rng"):
        resolve_rules("no-such-rule")


def test_rule_selection_scopes_the_pass():
    path = FIXTURES / "bad_sweep_rng.py"
    only_imports = lint_paths(
        [path], resolve_rules("experimental-import-outside-compat"))
    assert only_imports == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _run_cli(*args, timeout=240):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO_ROOT)


@pytest.mark.slow
def test_cli_exits_zero_on_repo_tree():
    out = _run_cli()
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stderr


@pytest.mark.slow
@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_cli_exits_nonzero_on_each_seeded_fixture(name):
    out = _run_cli(str(FIXTURES / name))
    assert out.returncode == 1, out.stdout + out.stderr
    for line, rule_id in _expected(FIXTURES / name):
        assert f"{name}:{line}: [{rule_id}]" in out.stdout, \
            (name, line, rule_id, out.stdout)


# ---------------------------------------------------------------------------
# contract derivation (pure arithmetic; real-lowering checks live in
# test_distributed.py and the dry-run CLI)
# ---------------------------------------------------------------------------

K = 8


def _two_entity(noise=None, row_prior=None, bf16=False):
    return ModelDef(
        (EntityDef("r", 96, row_prior or NormalPrior(K)),
         EntityDef("c", 48, NormalPrior(K))),
        (BlockDef(0, 1, noise or FixedGaussian(5.0), sparse=True),),
        K, use_pallas=False, bf16_gather=bf16)


def _gfa_model():
    ents = [EntityDef("z", 96, FixedNormalPrior(K)),
            EntityDef("v0", 48, SpikeAndSlabPrior(K)),
            EntityDef("v1", 24, SpikeAndSlabPrior(K))]
    blocks = (BlockDef(0, 1, AdaptiveGaussian(), sparse=False),
              BlockDef(0, 2, AdaptiveGaussian(), sparse=False))
    return ModelDef(tuple(ents), blocks, K)


ZOO = {
    "gaussian": (_two_entity(), 2, 6, K * K, "f32"),
    "probit": (_two_entity(noise=ProbitNoise()), 2, 6, K * K, "f32"),
    "bf16": (_two_entity(bf16=True), 2, 6, K * K, "bf16"),
    "macau": (_two_entity(row_prior=MacauPrior(K, 12)), 2, 8,
              12 * K, "f32"),
    "gfa": (_gfa_model(), 3, 8, K, "f32"),
}


@pytest.mark.parametrize("mesh_shape", [(8,), (4, 2)])
@pytest.mark.parametrize("name", sorted(ZOO))
@pytest.mark.parametrize("pipeline", ["eager", "ring"])
def test_contract_for_model_zoo(name, mesh_shape, pipeline):
    model, E, ar, max_elems, wire = ZOO[name]
    c = contract_for(model, mesh_shape, pipeline)
    S = 8
    assert c.n_shards == S
    assert c.all_reduces == ar
    assert c.max_reduce_elems == max_elems
    assert c.wire_dtype == wire
    if pipeline == "ring":
        # zero full-factor gathers in ring mode — the limited-
        # communication guarantee — and E circulations of S-1 hops
        assert c.all_gathers == 0
        assert c.collective_permutes == E * (S - 1)
    else:
        assert c.all_gathers == E
        assert c.collective_permutes == 0


def test_contract_for_validates_pipeline_choices():
    with pytest.raises(ValueError, match="valid pipelines"):
        contract_for(_two_entity(), (8,), "warp")


def test_contract_for_rejects_unknown_prior():
    class MysteryPrior:
        num_latent = K

    model = ModelDef((EntityDef("r", 96, MysteryPrior()),),
                     (), K)
    with pytest.raises(ValueError, match="NormalPrior"):
        contract_for(model, (8,), "eager")


# ---------------------------------------------------------------------------
# contract checking against synthetic IR with seeded violations
# ---------------------------------------------------------------------------

_FAKE_STABLEHLO = """
module @jit_step {
  func.func public @main(%arg0: tensor<12x8xf32>) {
    %0 = "stablehlo.all_gather"(%arg0) : (tensor<12x8xf32>) -> tensor<96x8xf32>
    %1 = "stablehlo.all_gather"(%arg0) : (tensor<12x8xf32>) -> tensor<96x8xf32>
    %2 = "stablehlo.all_reduce"(%arg0) : (tensor<8x8xf32>) -> tensor<8x8xf32>
    %3 = "stablehlo.all_reduce"(%arg0) : (tensor<8x8xf32>) -> tensor<8x8xf32>
    %4 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %5 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %6 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %7 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
  }
}
"""

_FAKE_HLO = """
HloModule jit_step

ENTRY %main (p0: f32[12,8]) -> f32[96,8] {
  %p0 = f32[12,8]{1,0} parameter(0)
  %ag0 = f32[96,8]{1,0} all-gather(f32[12,8]{1,0} %p0), dimensions={0}
  %ag1 = f32[96,8]{1,0} all-gather(f32[12,8]{1,0} %p0), dimensions={0}
  %ar0 = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), to_apply=%add
  ROOT %out = f32[96,8]{1,0} add(f32[96,8]{1,0} %ag0, f32[96,8]{1,0} %ag1)
}
"""


def _eager_contract(ar=6):
    return CommContract(pipeline="eager", n_shards=8, all_gathers=2,
                        collective_permutes=0, all_reduces=ar,
                        max_reduce_elems=K * K, wire_dtype="f32")


def test_check_lowered_passes_matching_module():
    assert check_lowered(_eager_contract(), _FAKE_STABLEHLO) == []


def test_check_lowered_catches_count_and_dtype_violations():
    # one gather too many expected -> count violation
    bad = _eager_contract()
    bad = CommContract(**{**bad.asdict(), "all_gathers": 3})
    msgs = check_lowered(bad, _FAKE_STABLEHLO)
    assert any("all-gather" in m for m in msgs), msgs
    # bf16 contract against an f32 wire -> dtype violation
    bad = CommContract(**{**_eager_contract().asdict(),
                          "wire_dtype": "bf16"})
    msgs = check_lowered(bad, _FAKE_STABLEHLO)
    assert any("wire" in m for m in msgs), msgs


def test_check_compiled_counts_and_payload_bound():
    assert check_compiled(_eager_contract(), _FAKE_HLO) == []
    # a ring contract must reject the gathers outright
    ring = CommContract(pipeline="ring", n_shards=8, all_gathers=0,
                        collective_permutes=14, all_reduces=6,
                        max_reduce_elems=K * K, wire_dtype="f32")
    msgs = check_compiled(ring, _FAKE_HLO)
    assert any("all-gather" in m for m in msgs), msgs
    assert any("collective-permute" in m for m in msgs), msgs
    # payload bound: an all-reduce bigger than max_reduce_elems fails
    tight = CommContract(**{**_eager_contract().asdict(),
                            "max_reduce_elems": 4})
    msgs = check_compiled(tight, _FAKE_HLO)
    assert any("payload" in m for m in msgs), msgs


def test_assert_contract_raises_with_every_violation():
    ring = CommContract(pipeline="ring", n_shards=8, all_gathers=0,
                        collective_permutes=14, all_reduces=6,
                        max_reduce_elems=K * K, wire_dtype="f32")
    with pytest.raises(ContractViolation, match="all-gather"):
        assert_contract(ring, lowered_text=_FAKE_STABLEHLO,
                        compiled_text=_FAKE_HLO, where="synthetic")
    # the passing direction raises nothing
    assert_contract(_eager_contract(), lowered_text=_FAKE_STABLEHLO,
                    compiled_text=_FAKE_HLO)


# ---------------------------------------------------------------------------
# dry-run JSON audit
# ---------------------------------------------------------------------------

DRYRUN = REPO_ROOT / "results" / "dryrun"


@pytest.mark.slow
def test_committed_dryrun_jsons_carry_valid_contracts():
    """Every committed dry-run record stores the contract its HLO was
    verified against, and re-deriving it from the cell reproduces it
    (audited in-process; CI also runs the CLI equivalent)."""
    from repro.analysis.contract import dryrun_contract_findings
    jsons = sorted(DRYRUN.glob("*.json"))
    assert jsons, "no committed dry-run JSONs"
    for j in jsons:
        assert dryrun_contract_findings(j) == [], j.name
        rec = json.loads(j.read_text())
        assert rec["contract_ok"] is True, j.name


@pytest.mark.slow
def test_cli_contract_audit_catches_tampered_json(tmp_path):
    """--contracts on a doctored record (ring claiming all-gathers)
    exits nonzero naming the mismatched field."""
    src = sorted(DRYRUN.glob("*.ring.json"))
    assert src, "no committed ring dry-run JSON"
    rec = json.loads(src[0].read_text())
    rec["contract"]["all_gathers"] = 2          # rings gather nothing
    (tmp_path / src[0].name).write_text(json.dumps(rec))
    out = _run_cli("--contracts", str(tmp_path))
    assert out.returncode == 1, out.stdout + out.stderr
    assert "all_gathers" in out.stdout


# ---------------------------------------------------------------------------
# observability schema audit (PR 10)
# ---------------------------------------------------------------------------

OBS_SAMPLES = REPO_ROOT / "results" / "obs"


def test_committed_obs_samples_pass_schema_audit():
    """Every committed results/obs sample is a well-formed repro.obs
    export (audited in-process; CI also runs the CLI equivalent)."""
    from repro.analysis.obsschema import obs_schema_findings
    jsons = sorted(OBS_SAMPLES.glob("*.json"))
    assert len(jsons) >= 3, "expected trace + metrics + serve samples"
    for j in jsons:
        assert obs_schema_findings(j) == [], j.name


@pytest.mark.slow
def test_cli_obs_audit_catches_tampered_samples(tmp_path):
    """--obs on doctored samples (sweep span missing bytes_on_wire;
    histogram total drifted off sum(counts); serve snapshot missing a
    required histogram) exits nonzero naming each defect."""
    trace = json.loads((OBS_SAMPLES / "train_trace.json").read_text())
    for ev in trace["traceEvents"]:
        if ev["name"] == "sweep":
            ev["args"].pop("bytes_on_wire", None)
    (tmp_path / "train_trace.json").write_text(json.dumps(trace))

    met = json.loads((OBS_SAMPLES / "serve_metrics.json").read_text())
    met["histograms"]["serve.execute_s"]["total"] += 1
    del met["histograms"]["serve.queue_wait_s"]
    (tmp_path / "serve_metrics.json").write_text(json.dumps(met))

    out = _run_cli("--obs", str(tmp_path))
    assert out.returncode == 1, out.stdout + out.stderr
    assert "bytes_on_wire" in out.stdout
    assert "sum(counts)" in out.stdout
    assert "serve.queue_wait_s" in out.stdout


# ---------------------------------------------------------------------------
# Pallas kernel contract verifier (PR 8)
# ---------------------------------------------------------------------------

KERNEL_FIXTURES = sorted(p.name for p in FIXTURES.glob("kernel_bad_*.py"))


def test_kernel_fixture_set_is_complete():
    """One seeded fixture per kernel rule (the four contract classes)."""
    assert KERNEL_FIXTURES == [
        "kernel_bad_bounds.py", "kernel_bad_dtype.py",
        "kernel_bad_race.py", "kernel_bad_vmem.py"]


def test_kernel_rules_registered_in_catalogue():
    from repro.analysis import KERNEL_RULE_IDS
    from repro.analysis.invariants import RULES
    for rid in KERNEL_RULE_IDS:
        assert rid in RULES
        assert [r.id for r in resolve_rules(rid)] == [rid]


@pytest.mark.parametrize("name", KERNEL_FIXTURES)
def test_kernel_fixture_violations_at_marked_lines(name):
    from repro.analysis import check_kernel_paths
    path = FIXTURES / name
    expected = _expected(path)
    assert expected, f"{name} has no # expect: markers"
    found = {(f.line, f.rule)
             for f in check_kernel_paths([path])}
    assert found == expected, (name, found, expected)


def test_kernel_rule_selection_scopes_the_pass():
    from repro.analysis import check_kernel_paths
    only_vmem = check_kernel_paths(
        [FIXTURES / "kernel_bad_race.py"],
        resolve_rules("kernel-vmem-budget"))
    assert only_vmem == []


def test_kernel_suppression_comment_silences_finding(tmp_path):
    from repro.analysis import check_kernel_paths
    src = (FIXTURES / "kernel_bad_race.py").read_text()
    quiet = tmp_path / "kernel_suppressed.py"
    quiet.write_text(src.replace(
        "# expect: kernel-output-race",
        "# repro-lint: disable=kernel-output-race"))
    assert check_kernel_paths([quiet]) == []


def test_kernel_file_without_registry_is_an_error(tmp_path):
    from repro.analysis import check_kernel_paths
    bare = tmp_path / "no_registry.py"
    bare.write_text("x = 1\n")
    with pytest.raises(ValueError, match="KERNELS registry"):
        check_kernel_paths([bare])


@pytest.mark.slow
def test_shipped_kernel_registry_proves_clean():
    """The real tree: all four kernels, all shipped block configs —
    race-free, in bounds, fp32-accumulating, inside VMEM budget."""
    from repro.analysis import check_kernels, vmem_report
    findings = check_kernels()
    assert findings == [], "\n".join(f.format() for f in findings)
    report = vmem_report()
    assert sorted(report) == ["flash", "gram", "sddmm", "topk_score"]
    for name, r in report.items():
        assert r["ok"], (name, r)
        assert 0 < r["peak_bytes"] <= r["budget_bytes"], (name, r)


@pytest.mark.slow
def test_kernel_capture_is_repeatable():
    """Back-to-back captures see every pallas_call site both times
    (jit/eval_shape caches must not swallow the second pass) and the
    kernels still execute correctly afterwards (the capture shim must
    not poison real traces)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis.kernelcheck import capture_spec
    from repro.kernels import ops, ref
    spec = ops.KERNELS["gram"]
    first = capture_spec(spec)
    second = capture_spec(spec)
    assert len(first) == len(second) == len(spec.probes)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (8, 128, 16), jnp.float32)
    val = jax.random.normal(k2, (8, 128), jnp.float32)
    mask = (jax.random.uniform(k3, (8, 128)) > 0.3).astype(jnp.float32)
    g1, r1 = ops.gram_and_rhs(vg, val, mask, use_pallas=True)
    g2, r2 = ref.gram_ref(vg, val, mask)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-4)
    assert float(jnp.sum(jnp.abs(g1))) > 0   # not the shim's zeros


@pytest.mark.slow
def test_cli_kernels_exits_zero_on_shipped_registry():
    out = _run_cli("--kernels", timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stderr


@pytest.mark.slow
@pytest.mark.parametrize("name", KERNEL_FIXTURES)
def test_cli_kernels_exits_nonzero_on_each_seeded_fixture(name):
    out = _run_cli("--kernels", str(FIXTURES / name), timeout=600)
    assert out.returncode == 1, out.stdout + out.stderr
    for line, rule_id in _expected(FIXTURES / name):
        assert f"{name}:{line}: [{rule_id}]" in out.stdout, \
            (name, line, rule_id, out.stdout)


# ---------------------------------------------------------------------------
# --json output mode (CI turns these into GitHub annotations)
# ---------------------------------------------------------------------------

def test_json_mode_emits_machine_readable_findings(capsys):
    from repro.analysis.__main__ import main
    rc = main([str(FIXTURES / "bad_registry_error.py"), "--json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == len(payload["findings"]) > 0
    f = payload["findings"][0]
    assert set(f) == {"path", "line", "rule", "message", "hint"}
    assert f["rule"] == "registry-error-without-choices"
    assert f["line"] > 0 and f["hint"]


def test_json_mode_clean_input_is_empty_payload(capsys):
    from repro.analysis.__main__ import main
    rc = main([str(FIXTURES / "suppressed_clean.py"), "--json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload == {"findings": [], "count": 0}


def test_github_annotations_script_formats_findings(tmp_path):
    script = REPO_ROOT / "scripts_dev" / "github_annotations.py"
    payload = json.dumps({"findings": [
        {"path": "src/x.py", "line": 7, "rule": "some-rule",
         "message": "broke it", "hint": "fix it"}], "count": 1})
    out = subprocess.run(
        [sys.executable, str(script)], input=payload,
        capture_output=True, text=True, timeout=60)
    assert out.returncode == 1
    assert "::error file=src/x.py,line=7,title=some-rule::" in out.stdout
    assert "fix it" in out.stdout
    clean = subprocess.run(
        [sys.executable, str(script)],
        input='{"findings": [], "count": 0}',
        capture_output=True, text=True, timeout=60)
    assert clean.returncode == 0


# ---------------------------------------------------------------------------
# kernel_vmem column in the dry-run audit
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_kernel_vmem_audit_catches_tampering(tmp_path):
    """The committed records' kernel_vmem estimates must match a fresh
    capture; a doctored peak or a dropped column is a finding."""
    from repro.analysis.contract import dryrun_contract_findings
    src = sorted(DRYRUN.glob("*.json"))[0]
    rec = json.loads(src.read_text())
    assert rec["kernel_vmem_ok"] is True
    rec["kernel_vmem"]["gram"]["peak_bytes"] = 1
    doctored = tmp_path / src.name
    doctored.write_text(json.dumps(rec))
    msgs = dryrun_contract_findings(doctored)
    assert any("kernel_vmem" in m and "peak_bytes" in m for m in msgs), \
        msgs

    rec = json.loads(src.read_text())
    del rec["kernel_vmem"]
    doctored.write_text(json.dumps(rec))
    msgs = dryrun_contract_findings(doctored)
    assert any("missing kernel_vmem" in m for m in msgs), msgs

"""Self-tests for the static-analysis subsystem (repro.analysis).

The linter is validated two ways: fixture modules under
tests/fixtures/analysis/ carry seeded violations marked with
``# expect: <rule-id>`` comments (every marked line must be found, at
the right line, and nothing else), and the real tree must come back
clean — the linter IS the regression test for the invariants PRs 1–5
earned.

The contract engine is validated arithmetically here (the derivation
for every model-zoo family on both mesh layouts and both pipelines)
and against synthetic HLO with seeded violations; the end-to-end
checks against real lowerings live in tests/test_distributed.py and
the dry-run CLI.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (CommContract, ContractViolation, Finding,
                            assert_contract, check_compiled,
                            check_lowered, contract_for, lint_paths,
                            resolve_rules)
from repro.analysis.invariants import REPRO_ROOT
from repro.core.blocks import BlockDef, EntityDef, ModelDef
from repro.core.noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from repro.core.priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                               SpikeAndSlabPrior)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO_ROOT = Path(__file__).resolve().parents[1]

BAD_FIXTURES = sorted(p.name for p in FIXTURES.glob("bad_*.py"))


def _expected(path: Path):
    """{(line, rule-id)} read from the fixture's # expect: markers."""
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if "# expect: " in line:
            out.add((i, line.split("# expect: ", 1)[1].strip()))
    return out


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_fixture_violations_detected_at_marked_lines(name):
    path = FIXTURES / name
    expected = _expected(path)
    assert expected, f"{name} has no # expect: markers"
    found = {(f.line, f.rule) for f in lint_paths([path])}
    assert found == expected, (name, found, expected)


def test_suppression_comments_silence_findings():
    findings = lint_paths([FIXTURES / "suppressed_clean.py"])
    assert findings == [], [f.format() for f in findings]


def test_clean_tree_zero_findings():
    findings = lint_paths()          # defaults to all of src/repro
    assert findings == [], "\n".join(f.format() for f in findings)
    # and the default target really is the package under test
    assert (REPRO_ROOT / "core" / "gibbs.py").exists()


def test_findings_report_file_line_rule_and_hint():
    f = lint_paths([FIXTURES / "bad_registry_error.py"])[0]
    assert isinstance(f, Finding)
    txt = f.format()
    assert f"bad_registry_error.py:{f.line}:" in txt
    assert "[registry-error-without-choices]" in txt
    assert "fix:" in txt


def test_resolve_rules_names_choices_on_typo():
    assert [r.id for r in resolve_rules("nondeterminism-in-core")] == \
        ["nondeterminism-in-core"]
    with pytest.raises(ValueError, match="valid rules.*batch-rng"):
        resolve_rules("no-such-rule")


def test_rule_selection_scopes_the_pass():
    path = FIXTURES / "bad_sweep_rng.py"
    only_imports = lint_paths(
        [path], resolve_rules("experimental-import-outside-compat"))
    assert only_imports == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _run_cli(*args, timeout=240):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO_ROOT)


@pytest.mark.slow
def test_cli_exits_zero_on_repo_tree():
    out = _run_cli()
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stderr


@pytest.mark.slow
@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_cli_exits_nonzero_on_each_seeded_fixture(name):
    out = _run_cli(str(FIXTURES / name))
    assert out.returncode == 1, out.stdout + out.stderr
    for line, rule_id in _expected(FIXTURES / name):
        assert f"{name}:{line}: [{rule_id}]" in out.stdout, \
            (name, line, rule_id, out.stdout)


# ---------------------------------------------------------------------------
# contract derivation (pure arithmetic; real-lowering checks live in
# test_distributed.py and the dry-run CLI)
# ---------------------------------------------------------------------------

K = 8


def _two_entity(noise=None, row_prior=None, bf16=False):
    return ModelDef(
        (EntityDef("r", 96, row_prior or NormalPrior(K)),
         EntityDef("c", 48, NormalPrior(K))),
        (BlockDef(0, 1, noise or FixedGaussian(5.0), sparse=True),),
        K, use_pallas=False, bf16_gather=bf16)


def _gfa_model():
    ents = [EntityDef("z", 96, FixedNormalPrior(K)),
            EntityDef("v0", 48, SpikeAndSlabPrior(K)),
            EntityDef("v1", 24, SpikeAndSlabPrior(K))]
    blocks = (BlockDef(0, 1, AdaptiveGaussian(), sparse=False),
              BlockDef(0, 2, AdaptiveGaussian(), sparse=False))
    return ModelDef(tuple(ents), blocks, K)


ZOO = {
    "gaussian": (_two_entity(), 2, 6, K * K, "f32"),
    "probit": (_two_entity(noise=ProbitNoise()), 2, 6, K * K, "f32"),
    "bf16": (_two_entity(bf16=True), 2, 6, K * K, "bf16"),
    "macau": (_two_entity(row_prior=MacauPrior(K, 12)), 2, 8,
              12 * K, "f32"),
    "gfa": (_gfa_model(), 3, 8, K, "f32"),
}


@pytest.mark.parametrize("mesh_shape", [(8,), (4, 2)])
@pytest.mark.parametrize("name", sorted(ZOO))
@pytest.mark.parametrize("pipeline", ["eager", "ring"])
def test_contract_for_model_zoo(name, mesh_shape, pipeline):
    model, E, ar, max_elems, wire = ZOO[name]
    c = contract_for(model, mesh_shape, pipeline)
    S = 8
    assert c.n_shards == S
    assert c.all_reduces == ar
    assert c.max_reduce_elems == max_elems
    assert c.wire_dtype == wire
    if pipeline == "ring":
        # zero full-factor gathers in ring mode — the limited-
        # communication guarantee — and E circulations of S-1 hops
        assert c.all_gathers == 0
        assert c.collective_permutes == E * (S - 1)
    else:
        assert c.all_gathers == E
        assert c.collective_permutes == 0


def test_contract_for_validates_pipeline_choices():
    with pytest.raises(ValueError, match="valid pipelines"):
        contract_for(_two_entity(), (8,), "warp")


def test_contract_for_rejects_unknown_prior():
    class MysteryPrior:
        num_latent = K

    model = ModelDef((EntityDef("r", 96, MysteryPrior()),),
                     (), K)
    with pytest.raises(ValueError, match="NormalPrior"):
        contract_for(model, (8,), "eager")


# ---------------------------------------------------------------------------
# contract checking against synthetic IR with seeded violations
# ---------------------------------------------------------------------------

_FAKE_STABLEHLO = """
module @jit_step {
  func.func public @main(%arg0: tensor<12x8xf32>) {
    %0 = "stablehlo.all_gather"(%arg0) : (tensor<12x8xf32>) -> tensor<96x8xf32>
    %1 = "stablehlo.all_gather"(%arg0) : (tensor<12x8xf32>) -> tensor<96x8xf32>
    %2 = "stablehlo.all_reduce"(%arg0) : (tensor<8x8xf32>) -> tensor<8x8xf32>
    %3 = "stablehlo.all_reduce"(%arg0) : (tensor<8x8xf32>) -> tensor<8x8xf32>
    %4 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %5 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %6 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
    %7 = "stablehlo.all_reduce"(%arg0) : (tensor<f32>) -> tensor<f32>
  }
}
"""

_FAKE_HLO = """
HloModule jit_step

ENTRY %main (p0: f32[12,8]) -> f32[96,8] {
  %p0 = f32[12,8]{1,0} parameter(0)
  %ag0 = f32[96,8]{1,0} all-gather(f32[12,8]{1,0} %p0), dimensions={0}
  %ag1 = f32[96,8]{1,0} all-gather(f32[12,8]{1,0} %p0), dimensions={0}
  %ar0 = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), to_apply=%add
  ROOT %out = f32[96,8]{1,0} add(f32[96,8]{1,0} %ag0, f32[96,8]{1,0} %ag1)
}
"""


def _eager_contract(ar=6):
    return CommContract(pipeline="eager", n_shards=8, all_gathers=2,
                        collective_permutes=0, all_reduces=ar,
                        max_reduce_elems=K * K, wire_dtype="f32")


def test_check_lowered_passes_matching_module():
    assert check_lowered(_eager_contract(), _FAKE_STABLEHLO) == []


def test_check_lowered_catches_count_and_dtype_violations():
    # one gather too many expected -> count violation
    bad = _eager_contract()
    bad = CommContract(**{**bad.asdict(), "all_gathers": 3})
    msgs = check_lowered(bad, _FAKE_STABLEHLO)
    assert any("all-gather" in m for m in msgs), msgs
    # bf16 contract against an f32 wire -> dtype violation
    bad = CommContract(**{**_eager_contract().asdict(),
                          "wire_dtype": "bf16"})
    msgs = check_lowered(bad, _FAKE_STABLEHLO)
    assert any("wire" in m for m in msgs), msgs


def test_check_compiled_counts_and_payload_bound():
    assert check_compiled(_eager_contract(), _FAKE_HLO) == []
    # a ring contract must reject the gathers outright
    ring = CommContract(pipeline="ring", n_shards=8, all_gathers=0,
                        collective_permutes=14, all_reduces=6,
                        max_reduce_elems=K * K, wire_dtype="f32")
    msgs = check_compiled(ring, _FAKE_HLO)
    assert any("all-gather" in m for m in msgs), msgs
    assert any("collective-permute" in m for m in msgs), msgs
    # payload bound: an all-reduce bigger than max_reduce_elems fails
    tight = CommContract(**{**_eager_contract().asdict(),
                            "max_reduce_elems": 4})
    msgs = check_compiled(tight, _FAKE_HLO)
    assert any("payload" in m for m in msgs), msgs


def test_assert_contract_raises_with_every_violation():
    ring = CommContract(pipeline="ring", n_shards=8, all_gathers=0,
                        collective_permutes=14, all_reduces=6,
                        max_reduce_elems=K * K, wire_dtype="f32")
    with pytest.raises(ContractViolation, match="all-gather"):
        assert_contract(ring, lowered_text=_FAKE_STABLEHLO,
                        compiled_text=_FAKE_HLO, where="synthetic")
    # the passing direction raises nothing
    assert_contract(_eager_contract(), lowered_text=_FAKE_STABLEHLO,
                    compiled_text=_FAKE_HLO)


# ---------------------------------------------------------------------------
# dry-run JSON audit
# ---------------------------------------------------------------------------

DRYRUN = REPO_ROOT / "results" / "dryrun"


@pytest.mark.slow
def test_committed_dryrun_jsons_carry_valid_contracts():
    """Every committed dry-run record stores the contract its HLO was
    verified against, and re-deriving it from the cell reproduces it
    (audited in-process; CI also runs the CLI equivalent)."""
    from repro.analysis.contract import dryrun_contract_findings
    jsons = sorted(DRYRUN.glob("*.json"))
    assert jsons, "no committed dry-run JSONs"
    for j in jsons:
        assert dryrun_contract_findings(j) == [], j.name
        rec = json.loads(j.read_text())
        assert rec["contract_ok"] is True, j.name


@pytest.mark.slow
def test_cli_contract_audit_catches_tampered_json(tmp_path):
    """--contracts on a doctored record (ring claiming all-gathers)
    exits nonzero naming the mismatched field."""
    src = sorted(DRYRUN.glob("*.ring.json"))
    assert src, "no committed ring dry-run JSON"
    rec = json.loads(src[0].read_text())
    rec["contract"]["all_gathers"] = 2          # rings gather nothing
    (tmp_path / src[0].name).write_text(json.dumps(rec))
    out = _run_cli("--contracts", str(tmp_path))
    assert out.returncode == 1, out.stdout + out.stderr
    assert "all_gathers" in out.stdout

"""Structural tests for the MF production dry-run (fast paths only).

Full-size lower+compile runs live in launch/mf_dryrun.py (minutes);
here we verify the abstract construction — ShapeDtypeStruct pytrees,
model assembly, eval_shape through init_state and one gibbs_step — at
both production scale (abstract, no allocation) and a tiny concrete
scale where the distributed step actually executes on 1 device.

The CLI smoke tests at the bottom cover the argparse surface itself
(subprocess-based — the module pins a 512-device host platform at
import): ``--help`` exits 0 naming every cell, and a typo'd ``--cell``
fails FAST with the list of valid cells — the same
tell-you-the-right-knobs contract as ``session._prior_by_name``'s
ValueError — instead of after a 256-chip lowering.
"""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.launch.mf_dryrun import (CELLS, MFCell, abstract_data,
                                    build_model, mf_model_flops)
from repro.core.blocks import DenseBlock
from repro.core.gibbs import gibbs_step, init_state
from repro.core.noise import ProbitNoise


def test_abstract_cells_eval_shape():
    for name, cell in CELLS.items():
        model = build_model(cell, "baseline")
        data = abstract_data(cell)
        state = jax.eval_shape(lambda m=model, d=data: init_state(m, d, 0))
        assert state.factors[0].shape == (cell.n_rows, cell.K)
        assert state.factors[1].shape == (cell.n_cols, cell.K)
        # a full sweep traces abstractly without allocating anything
        out = jax.eval_shape(
            lambda d, s, m=model: gibbs_step(m, d, s), data, state)
        st1, metrics = out
        assert st1.factors[0].shape == state.factors[0].shape
        assert "rmse_train_0" in metrics


def test_bf16_gather_variant_traces():
    cell = CELLS["bmf_chembl"]
    model = build_model(cell, "bf16gather")
    assert model.bf16_gather
    data = abstract_data(cell)
    state = jax.eval_shape(lambda: init_state(model, data, 0))
    st1, _ = jax.eval_shape(
        lambda d, s: gibbs_step(model, d, s), data, state)
    # factor dtype is preserved f32 (bf16 is only the exchange view)
    assert st1.factors[0].dtype == np.float32


def test_tiny_concrete_cell_runs():
    """A miniature cell of the same structure actually samples."""
    cell = MFCell("tiny", 64, 16, 4, 8, 32, 256)
    model = build_model(cell, "baseline")
    rng = np.random.default_rng(0)
    from repro.core import from_coo
    nnz = 100
    flat = rng.choice(64 * 16, size=nnz, replace=False)
    i, j = np.divmod(flat, 16)
    v = rng.normal(size=nnz).astype(np.float32)
    mat = from_coo(i, j, v, (64, 16))
    from repro.core.gibbs import MFData
    data = MFData((mat,), (None, None))
    state = init_state(model, data, 0)
    for _ in range(3):
        state, metrics = gibbs_step(model, data, state)
    assert np.isfinite(float(metrics["rmse_train_0"]))


def test_model_flops_positive_and_scales():
    for name in ("bmf_chembl", "dense_views", "probit_chembl"):
        cell = CELLS[name]
        f256 = mf_model_flops(cell, 256)
        f512 = mf_model_flops(cell, 512)
        assert f256 > 0 and abs(f256 / f512 - 2.0) < 1e-6, name


def test_widened_cells_build_their_workloads():
    """The paper's classification cell carries ProbitNoise and the
    dense cell a both-orientations DenseBlock — and both sit in the
    sharded subset on the production mesh shape (checked structurally
    here; the real mesh lower/compile lives in the dry-run CLI)."""
    pro = build_model(CELLS["probit_chembl"], "baseline")
    assert isinstance(pro.blocks[0].noise, ProbitNoise)
    assert pro.blocks[0].sparse

    dv = CELLS["dense_views"]
    den = build_model(dv, "baseline")
    assert not den.blocks[0].sparse
    payload = abstract_data(dv).blocks[0]
    assert isinstance(payload, DenseBlock) and payload.fully
    assert payload.X.shape == (dv.n_rows, dv.n_cols)
    assert payload.XT.shape == (dv.n_cols, dv.n_rows)
    # 512-shard divisibility — the structural half of
    # distributed_supported (the whitelist half is type-based)
    for cell in (CELLS["probit_chembl"], dv):
        assert cell.n_rows % 512 == 0 and cell.n_cols % 512 == 0

    # both trace abstractly through a full sweep at production size
    for model, cell in ((pro, CELLS["probit_chembl"]), (den, dv)):
        data = abstract_data(cell)
        state = jax.eval_shape(lambda m=model, d=data:
                               init_state(m, d, 0))
        st1, metrics = jax.eval_shape(
            lambda d, s, m=model: gibbs_step(m, d, s), data, state)
        assert st1.factors[0].shape == (cell.n_rows, cell.K)
        assert "rmse_train_0" in metrics


def test_gfa_cell_builds_multiview_sns_workload():
    """The gfa_views cell composes FixedNormal Z + spike-and-slab
    loadings over 3 dense views — and sits in the sharded subset on
    the production mesh shape (structural check; the real 256-chip
    lower/compile lives in the dry-run CLI, JSON under
    results/dryrun/)."""
    from repro.core.priors import FixedNormalPrior, SpikeAndSlabPrior

    cell = CELLS["gfa_views"]
    model = build_model(cell, "baseline")
    assert isinstance(model.entities[0].prior, FixedNormalPrior)
    assert len(model.entities) == 1 + len(cell.gfa_dims)
    for ent in model.entities[1:]:
        assert isinstance(ent.prior, SpikeAndSlabPrior)
    assert len(model.blocks) == len(cell.gfa_dims)
    data = abstract_data(cell)
    for blk, D in zip(data.blocks, cell.gfa_dims):
        assert isinstance(blk, DenseBlock) and blk.fully
        assert blk.X.shape == (cell.n_rows, D)
        assert blk.XT.shape == (D, cell.n_rows)
    # 512-shard divisibility: every entity (samples AND each view)
    assert cell.n_rows % 512 == 0
    for D in cell.gfa_dims:
        assert D % 512 == 0

    # a full sweep traces abstractly at production size
    state = jax.eval_shape(lambda: init_state(model, data, 0))
    st1, metrics = jax.eval_shape(
        lambda d, s: gibbs_step(model, d, s), data, state)
    assert st1.factors[0].shape == (cell.n_rows, cell.K)
    for m in range(len(cell.gfa_dims)):
        assert f"rmse_train_{m}" in metrics
    # the rho/tau hyper-state rides the sweep for every view entity
    for h in st1.hypers[1:]:
        assert set(h) == {"rho", "tau"}
        assert h["rho"].shape == (cell.K,)


# ---------------------------------------------------------------------------
# CLI smoke (subprocess: the module locks 512 host devices at import)
# ---------------------------------------------------------------------------

def _run_cli(*args, timeout=120):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.mf_dryrun", *args],
        env=env, capture_output=True, text=True, timeout=timeout)


def test_cli_help_exits_zero_and_names_every_cell():
    out = _run_cli("--help")
    assert out.returncode == 0, out.stderr
    for flag in ("--cell", "--mesh", "--variant"):
        assert flag in out.stdout, (flag, out.stdout)
    for cell in CELLS:
        assert cell in out.stdout, (cell, out.stdout)


def test_cli_unknown_cell_fails_fast_listing_choices():
    out = _run_cli("--cell", "bogus_cell")
    assert out.returncode != 0
    assert out.stdout == ""            # failed before any lowering
    for cell in list(CELLS) + ["all"]:
        assert cell in out.stderr, (cell, out.stderr)


def test_cli_unknown_mesh_fails_fast():
    out = _run_cli("--cell", "bmf_chembl", "--mesh", "mega")
    assert out.returncode != 0
    assert out.stdout == ""
    assert "single" in out.stderr and "multi" in out.stderr


def test_cli_unknown_variant_fails_fast():
    """A typo'd --variant must not lower 256 chips and write a
    baseline-numbers JSON under the bogus tag."""
    out = _run_cli("--cell", "bmf_chembl", "--variant", "rign")
    assert out.returncode != 0
    assert out.stdout == ""
    for v in ("baseline", "bf16gather", "ring"):
        assert v in out.stderr, (v, out.stderr)

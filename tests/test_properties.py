"""Hypothesis property tests on system invariants.

Invariants covered:
  * COO -> padded-bucket -> COO is lossless, both orientations agree
    with a dense reconstruction (the TPU-native CSR is exact);
  * transpose is an involution on every observed entry;
  * the batched gram/rhs equals the per-row loop for ARBITRARY sparse
    patterns (not just the fixed seeds of test_gibbs_reference);
  * the bf16 gather path (ModelDef.bf16_gather) stays within bf16
    tolerance of the f32 gram;
  * one gibbs_step preserves every invariant of the sampler state
    (shapes, finiteness, PSD-able precision, positive noise alpha)
    for arbitrary planted data;
  * with_coo_values rebuilds both orientations consistently;
  * the probit truncated-normal machinery: _truncnorm draws carry the
    observation's sign and stay finite for |mean| up to 8, and the
    counter-based row_uniforms (the distributed probit contract) give
    bitwise shard-slice parity for every divisor split;
  * the counter-based row_bernoulli (the spike-and-slab inclusion
    contract) gives the same bitwise shard-slice parity and tracks
    its probability argument.
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container without dev deps — see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (AdaptiveGaussian, BlockDef, EntityDef,
                        FixedGaussian, MFData, ModelDef, NormalPrior,
                        ProbitNoise, from_coo, gibbs_step, init_state)
from repro.core.gibbs import (_sparse_contrib, row_bernoulli,
                              row_uniforms)
from repro.core.noise import _truncnorm
from repro.kernels import ref


@st.composite
def sparse_problem(draw, max_n=24, max_m=16):
    n = draw(st.integers(2, max_n))
    m = draw(st.integers(2, max_m))
    nnz = draw(st.integers(1, min(60, n * m)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    flat = rng.choice(n * m, size=nnz, replace=False)
    i, j = np.divmod(flat, m)
    v = rng.normal(size=nnz).astype(np.float32)
    # hypothesis shouldn't shrink through the rng — keep data derived
    return n, m, i.astype(np.int64), j.astype(np.int64), v


@settings(max_examples=25, deadline=None)
@given(sparse_problem())
def test_padded_roundtrip_lossless(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    dense = np.zeros((n, m), np.float32)
    dense[i, j] = v

    # rows orientation reconstructs the dense matrix
    rows = mat.rows
    rec = np.zeros((n, m), np.float32)
    idx = np.asarray(rows.idx)
    val = np.asarray(rows.val)
    msk = np.asarray(rows.mask)
    for r in range(n):
        for t in range(rows.max_nnz):
            if msk[r, t] > 0:
                rec[r, idx[r, t]] += val[r, t]
    np.testing.assert_allclose(rec, dense, atol=0)

    # cols orientation reconstructs the transpose
    cols = mat.cols
    recT = np.zeros((m, n), np.float32)
    idx = np.asarray(cols.idx)
    val = np.asarray(cols.val)
    msk = np.asarray(cols.mask)
    for c in range(m):
        for t in range(cols.max_nnz):
            if msk[c, t] > 0:
                recT[c, idx[c, t]] += val[c, t]
    np.testing.assert_allclose(recT, dense.T, atol=0)

    # nnz preserved, COO mask exact
    assert int(np.asarray(mat.nnz)) == len(v)


@settings(max_examples=20, deadline=None)
@given(sparse_problem())
def test_transpose_involution(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    tt = mat.transpose().transpose()
    assert tt.shape == mat.shape
    np.testing.assert_array_equal(np.asarray(tt.rows.idx),
                                  np.asarray(mat.rows.idx))
    np.testing.assert_array_equal(np.asarray(tt.coo_v),
                                  np.asarray(mat.coo_v))


@settings(max_examples=20, deadline=None)
@given(sparse_problem(), st.integers(2, 6))
def test_gram_matches_loop_any_pattern(prob, K):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    rng = np.random.default_rng(K)
    V = rng.normal(size=(m, K)).astype(np.float32)
    alpha = 3.0
    noise = FixedGaussian(alpha)
    model = ModelDef((EntityDef("r", n, NormalPrior(K)),
                      EntityDef("c", m, NormalPrior(K))),
                     (BlockDef(0, 1, noise, sparse=True),), K, False)
    gram, rhs = _sparse_contrib(model, mat, True, jnp.asarray(V),
                                jnp.zeros((n, K)), noise, noise.init(),
                                jax.random.PRNGKey(0))
    for r in range(n):
        sel = i == r
        vs = V[j[sel]]
        np.testing.assert_allclose(np.asarray(gram[r]),
                                   alpha * (vs.T @ vs),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(rhs[r]),
                                   alpha * (v[sel] @ vs),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(sparse_problem(), st.integers(2, 5))
def test_bf16_gather_gram_close_to_f32(prob, K):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    rng = np.random.default_rng(K + 1)
    V = rng.normal(size=(m, K)).astype(np.float32)
    vg32 = jnp.asarray(V)[mat.rows.idx]
    vg16 = jnp.asarray(V).astype(jnp.bfloat16)[mat.rows.idx]
    g32, b32 = ref.gram_ref(vg32, mat.rows.val, mat.rows.mask)
    g16, b16 = ref.gram_ref(vg16, mat.rows.val, mat.rows.mask)
    # bf16 mantissa ~ 8 bits -> ~1e-2 relative
    scale = float(jnp.max(jnp.abs(g32))) + 1e-6
    assert float(jnp.max(jnp.abs(g16 - g32))) < 0.05 * scale


@settings(max_examples=10, deadline=None)
@given(sparse_problem(), st.booleans())
def test_gibbs_step_preserves_state_invariants(prob, bf16):
    n, m, i, j, v = prob
    K = 3
    mat = from_coo(i, j, v, (n, m))
    model = ModelDef((EntityDef("r", n, NormalPrior(K)),
                      EntityDef("c", m, NormalPrior(K))),
                     (BlockDef(0, 1, AdaptiveGaussian(), sparse=True),),
                     K, False, bf16_gather=bf16)
    data = MFData((mat,), (None, None))
    state = init_state(model, data, 7)
    st1, metrics = gibbs_step(model, data, state)

    assert st1.step == state.step + 1
    for e, f in enumerate(st1.factors):
        assert f.shape == state.factors[e].shape
        assert bool(jnp.all(jnp.isfinite(f)))
    for h in st1.hypers:
        lam = h["Lambda"]
        # precision sample must be symmetric positive definite
        assert bool(jnp.all(jnp.isfinite(lam)))
        evals = np.linalg.eigvalsh(np.asarray(lam))
        assert evals.min() > 0
    assert float(st1.noises[0]["alpha"]) > 0
    assert np.isfinite(float(metrics["rmse_train_0"]))


@st.composite
def truncnorm_problem(draw, max_n=64):
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    scale_tenths = draw(st.integers(0, 80))     # |mean| up to 8.0
    rng = np.random.default_rng(seed)
    mean = rng.uniform(-1.0, 1.0, size=n).astype(np.float32) \
        * (scale_tenths / 10.0)
    obs = (rng.random(n) < 0.5).astype(np.float32)
    return seed, mean, obs


@settings(max_examples=30, deadline=None)
@given(truncnorm_problem())
def test_truncnorm_sign_agreement_and_finite(prob):
    """The latent draw stays finite out to |mean| = 8, and lands on
    the observation's side of 0 wherever the f32 inverse-CDF can
    resolve the tail (|mean| <= 4; beyond ~5 the 1e-7 CDF clip trades
    sign for finiteness, which the clip-to-[mean-8, mean+8] bounds)."""
    seed, mean, obs = prob
    z = np.asarray(_truncnorm(jax.random.PRNGKey(seed),
                              jnp.asarray(mean), jnp.asarray(obs)))
    assert np.isfinite(z).all(), (mean, z)
    resolvable = np.abs(mean) <= 4.0
    agree = (z > 0) == (obs > 0)
    assert agree[resolvable].all(), (mean, obs, z)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_row_uniforms_shard_slices_bitwise(n_shards, width, seed):
    """Counter-based uniforms: a shard holding rows [off, off+n) draws
    EXACTLY the bits of the full draw's slice — the probit analogue of
    the row_normals contract the distributed sweep is built on."""
    key = jax.random.PRNGKey(seed)
    rows_per = 6
    n_rows = n_shards * rows_per
    full = np.asarray(row_uniforms(key, n_rows, width))
    assert ((0.0 <= full) & (full < 1.0)).all()
    for s in range(n_shards):
        part = np.asarray(row_uniforms(key, rows_per, width,
                                       row_offset=rows_per * s))
        np.testing.assert_array_equal(part,
                                      full[rows_per * s:
                                           rows_per * (s + 1)])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2**31 - 1), st.booleans())
def test_row_bernoulli_shard_slices_bitwise(n_shards, seed, wide):
    """Counter-based Bernoulli (the SnS inclusion indicators): a shard
    holding rows [off, off+n) draws EXACTLY the bits of the full
    draw's slice, for (n_rows,) and (n_rows, W) probability shapes —
    the sibling of the row_normals/row_uniforms contracts that admits
    spike-and-slab into the distributed sweep."""
    rng = np.random.default_rng(seed)
    rows_per = 6
    n_rows = n_shards * rows_per
    shape = (n_rows, 3) if wide else (n_rows,)
    p = jnp.asarray(rng.random(shape), jnp.float32)
    key = jax.random.PRNGKey(seed)
    full = np.asarray(row_bernoulli(key, p))
    assert full.dtype == bool and full.shape == shape
    for s in range(n_shards):
        sl = slice(rows_per * s, rows_per * (s + 1))
        part = np.asarray(row_bernoulli(key, p[sl],
                                        row_offset=rows_per * s))
        np.testing.assert_array_equal(part, full[sl])


def test_row_bernoulli_tracks_probability():
    """Statistical sanity: the inclusion rate follows p."""
    key = jax.random.PRNGKey(0)
    for p in (0.1, 0.5, 0.9):
        draws = np.asarray(row_bernoulli(
            key, jnp.full((20000,), p, jnp.float32)))
        assert abs(draws.mean() - p) < 0.02, (p, draws.mean())


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(0, 2**31 - 1))
def test_probit_augment_shard_slices_bitwise(n_shards, seed):
    """ProbitNoise.augment(row_offset=...) on a row slice reproduces
    the matching slice of the full augmentation bit for bit (given the
    same pred slice) — what admits probit into the sharded sweep."""
    rng = np.random.default_rng(seed)
    rows_per, width = 5, 7
    n_rows = n_shards * rows_per
    pred = jnp.asarray(rng.normal(size=(n_rows, width)), jnp.float32)
    vals = jnp.asarray((rng.random((n_rows, width)) < 0.5), jnp.float32)
    mask = jnp.asarray((rng.random((n_rows, width)) < 0.8), jnp.float32)
    noise = ProbitNoise()
    state = noise.init()
    key = jax.random.PRNGKey(seed)
    z_full, _ = noise.augment(key, state, pred, vals, mask)
    for s in range(n_shards):
        sl = slice(rows_per * s, rows_per * (s + 1))
        z_part, _ = noise.augment(key, state, pred[sl], vals[sl],
                                  mask[sl], row_offset=rows_per * s)
        np.testing.assert_array_equal(np.asarray(z_part),
                                      np.asarray(z_full)[sl])


@settings(max_examples=15, deadline=None)
@given(sparse_problem())
def test_with_coo_values_consistent(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    # COO view is padded: provide one value per padded slot
    new_v = (jnp.arange(1, mat.coo_v.shape[0] + 1, dtype=jnp.float32)
             * mat.coo_mask)
    mat2 = mat.with_coo_values(new_v)
    # both orientations must carry exactly the new values
    assert float(jnp.sum(mat2.rows.val * mat2.rows.mask)) == \
        float(jnp.sum(new_v * mat.coo_mask))
    assert float(jnp.sum(mat2.cols.val * mat2.cols.mask)) == \
        float(jnp.sum(new_v * mat.coo_mask))

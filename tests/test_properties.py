"""Hypothesis property tests on system invariants.

Invariants covered:
  * COO -> padded-bucket -> COO is lossless, both orientations agree
    with a dense reconstruction (the TPU-native CSR is exact);
  * transpose is an involution on every observed entry;
  * the batched gram/rhs equals the per-row loop for ARBITRARY sparse
    patterns (not just the fixed seeds of test_gibbs_reference);
  * the bf16 gather path (ModelDef.bf16_gather) stays within bf16
    tolerance of the f32 gram;
  * one gibbs_step preserves every invariant of the sampler state
    (shapes, finiteness, PSD-able precision, positive noise alpha)
    for arbitrary planted data;
  * with_coo_values rebuilds both orientations consistently;
  * the probit truncated-normal machinery: _truncnorm draws carry the
    observation's sign and stay finite for |mean| up to 8, and the
    counter-based row_uniforms (the distributed probit contract) give
    bitwise shard-slice parity for every divisor split;
  * the counter-based row_bernoulli (the spike-and-slab inclusion
    contract) gives the same bitwise shard-slice parity and tracks
    its probability argument;
  * the ring pipeline's chunk-accumulated dense Gram/RHS moments
    (``_dense_chunk_contrib``, folded per ppermute hop in
    ``distributed._ring_accumulate``) equal the monolithic
    ``_dense_contrib`` moments for arbitrary chunk counts, UNEVEN
    chunk widths, masked payloads, and the all-ones-mask
    ``fully=True`` shared-Gram fast path — and the ring's
    ``dynamic_update_slice`` view reassembly is bitwise the gathered
    array for every rotation of the chunk order.
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container without dev deps — see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (AdaptiveGaussian, BlockDef, EntityDef,
                        FixedGaussian, MFData, ModelDef, NormalPrior,
                        ProbitNoise, dense_block, from_coo, gibbs_step,
                        init_state)
from repro.core.gibbs import (_dense_chunk_contrib, _dense_contrib,
                              _sparse_contrib, row_bernoulli,
                              row_uniforms)
from repro.core.noise import _truncnorm
from repro.kernels import ref


@st.composite
def sparse_problem(draw, max_n=24, max_m=16):
    n = draw(st.integers(2, max_n))
    m = draw(st.integers(2, max_m))
    nnz = draw(st.integers(1, min(60, n * m)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    flat = rng.choice(n * m, size=nnz, replace=False)
    i, j = np.divmod(flat, m)
    v = rng.normal(size=nnz).astype(np.float32)
    # hypothesis shouldn't shrink through the rng — keep data derived
    return n, m, i.astype(np.int64), j.astype(np.int64), v


@settings(max_examples=25, deadline=None)
@given(sparse_problem())
def test_padded_roundtrip_lossless(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    dense = np.zeros((n, m), np.float32)
    dense[i, j] = v

    # rows orientation reconstructs the dense matrix
    rows = mat.rows
    rec = np.zeros((n, m), np.float32)
    idx = np.asarray(rows.idx)
    val = np.asarray(rows.val)
    msk = np.asarray(rows.mask)
    for r in range(n):
        for t in range(rows.max_nnz):
            if msk[r, t] > 0:
                rec[r, idx[r, t]] += val[r, t]
    np.testing.assert_allclose(rec, dense, atol=0)

    # cols orientation reconstructs the transpose
    cols = mat.cols
    recT = np.zeros((m, n), np.float32)
    idx = np.asarray(cols.idx)
    val = np.asarray(cols.val)
    msk = np.asarray(cols.mask)
    for c in range(m):
        for t in range(cols.max_nnz):
            if msk[c, t] > 0:
                recT[c, idx[c, t]] += val[c, t]
    np.testing.assert_allclose(recT, dense.T, atol=0)

    # nnz preserved, COO mask exact
    assert int(np.asarray(mat.nnz)) == len(v)


@settings(max_examples=20, deadline=None)
@given(sparse_problem())
def test_transpose_involution(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    tt = mat.transpose().transpose()
    assert tt.shape == mat.shape
    np.testing.assert_array_equal(np.asarray(tt.rows.idx),
                                  np.asarray(mat.rows.idx))
    np.testing.assert_array_equal(np.asarray(tt.coo_v),
                                  np.asarray(mat.coo_v))


@settings(max_examples=20, deadline=None)
@given(sparse_problem(), st.integers(2, 6))
def test_gram_matches_loop_any_pattern(prob, K):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    rng = np.random.default_rng(K)
    V = rng.normal(size=(m, K)).astype(np.float32)
    alpha = 3.0
    noise = FixedGaussian(alpha)
    model = ModelDef((EntityDef("r", n, NormalPrior(K)),
                      EntityDef("c", m, NormalPrior(K))),
                     (BlockDef(0, 1, noise, sparse=True),), K, False)
    gram, rhs = _sparse_contrib(model, mat, True, jnp.asarray(V),
                                jnp.zeros((n, K)), noise, noise.init(),
                                jax.random.PRNGKey(0))
    for r in range(n):
        sel = i == r
        vs = V[j[sel]]
        np.testing.assert_allclose(np.asarray(gram[r]),
                                   alpha * (vs.T @ vs),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(rhs[r]),
                                   alpha * (v[sel] @ vs),
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(sparse_problem(), st.integers(2, 5))
def test_bf16_gather_gram_close_to_f32(prob, K):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    rng = np.random.default_rng(K + 1)
    V = rng.normal(size=(m, K)).astype(np.float32)
    vg32 = jnp.asarray(V)[mat.rows.idx]
    vg16 = jnp.asarray(V).astype(jnp.bfloat16)[mat.rows.idx]
    g32, b32 = ref.gram_ref(vg32, mat.rows.val, mat.rows.mask)
    g16, b16 = ref.gram_ref(vg16, mat.rows.val, mat.rows.mask)
    # bf16 mantissa ~ 8 bits -> ~1e-2 relative
    scale = float(jnp.max(jnp.abs(g32))) + 1e-6
    assert float(jnp.max(jnp.abs(g16 - g32))) < 0.05 * scale


@settings(max_examples=10, deadline=None)
@given(sparse_problem(), st.booleans())
def test_gibbs_step_preserves_state_invariants(prob, bf16):
    n, m, i, j, v = prob
    K = 3
    mat = from_coo(i, j, v, (n, m))
    model = ModelDef((EntityDef("r", n, NormalPrior(K)),
                      EntityDef("c", m, NormalPrior(K))),
                     (BlockDef(0, 1, AdaptiveGaussian(), sparse=True),),
                     K, False, bf16_gather=bf16)
    data = MFData((mat,), (None, None))
    state = init_state(model, data, 7)
    st1, metrics = gibbs_step(model, data, state)

    assert st1.step == state.step + 1
    for e, f in enumerate(st1.factors):
        assert f.shape == state.factors[e].shape
        assert bool(jnp.all(jnp.isfinite(f)))
    for h in st1.hypers:
        lam = h["Lambda"]
        # precision sample must be symmetric positive definite
        assert bool(jnp.all(jnp.isfinite(lam)))
        evals = np.linalg.eigvalsh(np.asarray(lam))
        assert evals.min() > 0
    assert float(st1.noises[0]["alpha"]) > 0
    assert np.isfinite(float(metrics["rmse_train_0"]))


@st.composite
def truncnorm_problem(draw, max_n=64):
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    scale_tenths = draw(st.integers(0, 80))     # |mean| up to 8.0
    rng = np.random.default_rng(seed)
    mean = rng.uniform(-1.0, 1.0, size=n).astype(np.float32) \
        * (scale_tenths / 10.0)
    obs = (rng.random(n) < 0.5).astype(np.float32)
    return seed, mean, obs


@settings(max_examples=30, deadline=None)
@given(truncnorm_problem())
def test_truncnorm_sign_agreement_and_finite(prob):
    """The latent draw stays finite out to |mean| = 8, and lands on
    the observation's side of 0 wherever the f32 inverse-CDF can
    resolve the tail (|mean| <= 4; beyond ~5 the 1e-7 CDF clip trades
    sign for finiteness, which the clip-to-[mean-8, mean+8] bounds)."""
    seed, mean, obs = prob
    z = np.asarray(_truncnorm(jax.random.PRNGKey(seed),
                              jnp.asarray(mean), jnp.asarray(obs)))
    assert np.isfinite(z).all(), (mean, z)
    resolvable = np.abs(mean) <= 4.0
    agree = (z > 0) == (obs > 0)
    assert agree[resolvable].all(), (mean, obs, z)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_row_uniforms_shard_slices_bitwise(n_shards, width, seed):
    """Counter-based uniforms: a shard holding rows [off, off+n) draws
    EXACTLY the bits of the full draw's slice — the probit analogue of
    the row_normals contract the distributed sweep is built on."""
    key = jax.random.PRNGKey(seed)
    rows_per = 6
    n_rows = n_shards * rows_per
    full = np.asarray(row_uniforms(key, n_rows, width))
    assert ((0.0 <= full) & (full < 1.0)).all()
    for s in range(n_shards):
        part = np.asarray(row_uniforms(key, rows_per, width,
                                       row_offset=rows_per * s))
        np.testing.assert_array_equal(part,
                                      full[rows_per * s:
                                           rows_per * (s + 1)])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2**31 - 1), st.booleans())
def test_row_bernoulli_shard_slices_bitwise(n_shards, seed, wide):
    """Counter-based Bernoulli (the SnS inclusion indicators): a shard
    holding rows [off, off+n) draws EXACTLY the bits of the full
    draw's slice, for (n_rows,) and (n_rows, W) probability shapes —
    the sibling of the row_normals/row_uniforms contracts that admits
    spike-and-slab into the distributed sweep."""
    rng = np.random.default_rng(seed)
    rows_per = 6
    n_rows = n_shards * rows_per
    shape = (n_rows, 3) if wide else (n_rows,)
    p = jnp.asarray(rng.random(shape), jnp.float32)
    key = jax.random.PRNGKey(seed)
    full = np.asarray(row_bernoulli(key, p))
    assert full.dtype == bool and full.shape == shape
    for s in range(n_shards):
        sl = slice(rows_per * s, rows_per * (s + 1))
        part = np.asarray(row_bernoulli(key, p[sl],
                                        row_offset=rows_per * s))
        np.testing.assert_array_equal(part, full[sl])


def test_row_bernoulli_tracks_probability():
    """Statistical sanity: the inclusion rate follows p."""
    key = jax.random.PRNGKey(0)
    for p in (0.1, 0.5, 0.9):
        draws = np.asarray(row_bernoulli(
            key, jnp.full((20000,), p, jnp.float32)))
        assert abs(draws.mean() - p) < 0.02, (p, draws.mean())


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(0, 2**31 - 1))
def test_probit_augment_shard_slices_bitwise(n_shards, seed):
    """ProbitNoise.augment(row_offset=...) on a row slice reproduces
    the matching slice of the full augmentation bit for bit (given the
    same pred slice) — what admits probit into the sharded sweep."""
    rng = np.random.default_rng(seed)
    rows_per, width = 5, 7
    n_rows = n_shards * rows_per
    pred = jnp.asarray(rng.normal(size=(n_rows, width)), jnp.float32)
    vals = jnp.asarray((rng.random((n_rows, width)) < 0.5), jnp.float32)
    mask = jnp.asarray((rng.random((n_rows, width)) < 0.8), jnp.float32)
    noise = ProbitNoise()
    state = noise.init()
    key = jax.random.PRNGKey(seed)
    z_full, _ = noise.augment(key, state, pred, vals, mask)
    for s in range(n_shards):
        sl = slice(rows_per * s, rows_per * (s + 1))
        z_part, _ = noise.augment(key, state, pred[sl], vals[sl],
                                  mask[sl], row_offset=rows_per * s)
        np.testing.assert_array_equal(np.asarray(z_part),
                                      np.asarray(z_full)[sl])


@st.composite
def chunked_dense_problem(draw, max_r=10, max_c=32, max_k=5):
    """A dense block, a fixed factor, and an UNEVEN partition of the
    fixed-factor rows into chunks (the ring exchange delivers equal
    chunks, but the chunk math must not depend on that)."""
    R = draw(st.integers(2, max_r))
    C = draw(st.integers(2, max_c))
    K = draw(st.integers(2, max_k))
    n_chunks = draw(st.integers(1, min(6, C)))
    fully = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, C), size=n_chunks - 1,
                              replace=False)) if n_chunks > 1 else \
        np.array([], np.int64)
    bounds = [0] + [int(c) for c in cuts] + [C]
    X = rng.normal(size=(R, C)).astype(np.float32)
    mask = np.ones((R, C), np.float32) if fully else \
        (rng.random((R, C)) < 0.7).astype(np.float32)
    F = rng.normal(size=(C, K)).astype(np.float32)
    return X, mask, F, bounds, fully


@settings(max_examples=20, deadline=None)
@given(chunked_dense_problem(), st.floats(0.5, 4.0))
def test_dense_chunk_moments_match_monolithic(prob, alpha):
    """Chunk-accumulated dense Gram/RHS (the ring pipeline's per-hop
    fold, ``_dense_chunk_contrib``) equals the monolithic
    ``_dense_contrib`` moments over any partition of the fixed-factor
    rows — uneven widths, masked payloads, and the all-ones-mask
    ``fully=True`` shared-Gram fast path — up to f32 summation order."""
    X, mask, F, bounds, fully = prob
    payload = dense_block(X, None if fully else mask)
    assert payload.fully == fully
    noise = FixedGaussian(alpha)
    u = jnp.zeros((X.shape[0], F.shape[1]), jnp.float32)
    gs_m, gr_m, rhs_m = _dense_contrib(payload, True, jnp.asarray(F), u,
                                       noise, noise.init(),
                                       jax.random.PRNGKey(0))
    gs = gr = None
    rhs = jnp.zeros_like(rhs_m)
    vals, msk = payload.oriented(True)
    for c0, c1 in zip(bounds, bounds[1:]):
        dgs, dgr, drh = _dense_chunk_contrib(vals, msk, fully,
                                             jnp.asarray(F[c0:c1]),
                                             jnp.asarray(c0))
        if dgs is not None:
            gs = dgs if gs is None else gs + dgs
        if dgr is not None:
            gr = dgr if gr is None else gr + dgr
        rhs = rhs + drh
    scale = float(jnp.max(jnp.abs(gs_m if gr_m is None else gr_m))) + 1.0
    if fully:
        assert gr_m is None and gr is None
        np.testing.assert_allclose(np.asarray(alpha * gs),
                                   np.asarray(gs_m), atol=1e-4 * scale)
    else:
        assert gs_m is None and gs is None
        np.testing.assert_allclose(np.asarray(alpha * gr),
                                   np.asarray(gr_m), atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(alpha * rhs),
                               np.asarray(rhs_m), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 7), st.integers(2, 6),
       st.integers(0, 2**31 - 1))
def test_ring_view_reassembly_bitwise(n_shards, start, width, seed):
    """The ring's view reassembly (``dynamic_update_slice`` of equal
    chunks, visited in the shard-dependent rotation ``(s + t) % S``)
    rebuilds EXACTLY the gathered array — pure data movement, no
    arithmetic — for every shard's rotation of the chunk order.  This
    is what makes the ring chain bitwise the eager chain on every
    gather-indexed (sparse/SnS/probit/metrics) path."""
    rows_per = 5
    rng = np.random.default_rng(seed)
    full = rng.normal(size=(n_shards * rows_per, width)) \
        .astype(np.float32)
    s0 = start % n_shards
    out = jnp.zeros_like(full)
    for t in range(n_shards):
        owner = (s0 + t) % n_shards
        chunk = jnp.asarray(full[owner * rows_per:
                                 (owner + 1) * rows_per])
        out = jax.lax.dynamic_update_slice(
            out, chunk, (jnp.asarray(owner * rows_per), 0))
    np.testing.assert_array_equal(np.asarray(out), full)


@settings(max_examples=15, deadline=None)
@given(sparse_problem())
def test_with_coo_values_consistent(prob):
    n, m, i, j, v = prob
    mat = from_coo(i, j, v, (n, m))
    # COO view is padded: provide one value per padded slot
    new_v = (jnp.arange(1, mat.coo_v.shape[0] + 1, dtype=jnp.float32)
             * mat.coo_mask)
    mat2 = mat.with_coo_values(new_v)
    # both orientations must carry exactly the new values
    assert float(jnp.sum(mat2.rows.val * mat2.rows.mask)) == \
        float(jnp.sum(new_v * mat.coo_mask))
    assert float(jnp.sum(mat2.cols.val * mat2.cols.mask)) == \
        float(jnp.sum(new_v * mat.coo_mask))

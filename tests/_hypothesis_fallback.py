"""Minimal stand-in for the ``hypothesis`` API the suite uses.

The container image does not ship hypothesis (see requirements-dev.txt
for the real pin).  Rather than skip three whole test modules, this
shim implements just enough of the surface — ``given``, ``settings``,
``strategies.integers/booleans/composite`` — to run each property test
over a deterministic sample of the strategy space: the all-minimum
point, the all-maximum point, then seeded pseudo-random draws up to
``max_examples``.

No shrinking, no database, no health checks — if a property fails
here, rerun under real hypothesis for a minimal counterexample.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import numpy as np


class _Strategy:
    """A value source: ``sample(rng, mode)`` with mode in
    {"min", "max", "random"}."""

    def __init__(self, fn: Callable[[np.random.Generator, str], Any]):
        self._fn = fn

    def sample(self, rng: np.random.Generator, mode: str) -> Any:
        return self._fn(rng, mode)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def draw(rng, mode):
            if mode == "min":
                return int(min_value)
            if mode == "max":
                return int(max_value)
            return int(rng.integers(min_value, max_value + 1))
        return _Strategy(draw)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng, mode: {"min": False, "max": True}
                         .get(mode, bool(rng.integers(0, 2))))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        def draw(rng, mode):
            if mode == "min":
                return float(min_value)
            if mode == "max":
                return float(max_value)
            return float(rng.uniform(min_value, max_value))
        return _Strategy(draw)

    @staticmethod
    def composite(fn):
        """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy
        factory, with ``draw`` resolving sub-strategies in sequence."""
        @functools.wraps(fn)
        def factory(*args, **kwargs):
            def sample(rng, mode):
                return fn(lambda strat: strat.sample(rng, mode),
                          *args, **kwargs)
            return _Strategy(sample)
        return factory


strategies = _Strategies()


def given(*strats: _Strategy):
    def deco(test_fn):
        # zero-arg wrapper: unlike real hypothesis we don't support
        # mixing pytest fixtures into the signature, and exposing the
        # original parameters would make pytest resolve them as
        # fixtures.
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = np.random.default_rng(0xC0FFEE)
            for i in range(n):
                mode = "min" if i == 0 else "max" if i == 1 else "random"
                drawn = [s.sample(rng, mode) for s in strats]
                test_fn(*drawn)
        wrapper.__name__ = test_fn.__name__
        wrapper.__doc__ = test_fn.__doc__
        wrapper.__module__ = test_fn.__module__
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def settings(max_examples: int = 20, **_ignored):
    """Accepts (and mostly ignores) real-hypothesis knobs like
    ``deadline``."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco

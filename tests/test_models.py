"""Per-architecture smoke tests: reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs (brief (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import (forward, init_model, init_serve_cache, loss_fn,
                          param_count, serve_step)
from repro.models.transformer import encode


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
    }
    if cfg.n_frontend_tokens:
        batch["frontend"] = jnp.asarray(rng.normal(
            size=(B, cfg.n_frontend_tokens, cfg.d_model))
            .astype(np.float32))
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_frames, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_grad_serve(arch):
    cfg = get_smoke(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)[0])(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    logits, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["enc_frames"])
    caches = init_serve_cache(params, cfg, B, 64, enc_out=enc_out,
                              prefilled=5)
    tok = jnp.asarray([[1], [2]], jnp.int32)
    lg, caches2 = serve_step(params, cfg, caches, tok)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    assert int(caches2["pos"]) == int(caches["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    total, active = param_count(cfg)
    assert total >= active > 0


def test_param_counts_match_published():
    """Full configs land near their advertised parameter counts."""
    expect = {
        "jamba_v01_52b": (52e9, 0.08),
        "grok_1_314b": (314e9, 0.05),
        "deepseek_v2_lite_16b": (15.7e9, 0.06),
        "qwen25_32b": (32.5e9, 0.05),
        "smollm_135m": (135e6, 0.05),
        "yi_6b": (6e9, 0.06),
        "qwen3_4b": (4e9, 0.12),
        "mamba2_130m": (130e6, 0.10),
        "internvl2_2b": (2e9, 0.12),
        "whisper_medium": (769e6, 0.10),
    }
    for arch, (target, tol) in expect.items():
        total, _ = param_count(get_config(arch))
        assert abs(total - target) / target < tol, \
            f"{arch}: {total/1e9:.2f}B vs {target/1e9:.2f}B"


def test_decode_matches_forward_incremental():
    """Decoding token-by-token equals the parallel forward pass."""
    cfg = get_smoke("smollm_135m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    logits_par, _ = forward(params, cfg, {"tokens": jnp.asarray(toks)},
                            remat=False)
    caches = init_serve_cache(params, cfg, B, S + 4, prefilled=0)
    outs = []
    for t in range(S):
        lg, caches = serve_step(params, cfg, caches,
                                jnp.asarray(toks[:, t:t + 1]))
        outs.append(np.asarray(lg[:, 0].astype(jnp.float32)))
    dec = np.stack(outs, axis=1)
    par = np.asarray(logits_par.astype(jnp.float32))
    np.testing.assert_allclose(dec, par, rtol=0.08, atol=0.08)
    # argmax agreement is the functional contract
    agree = (dec.argmax(-1) == par.argmax(-1)).mean()
    assert agree > 0.95, agree


def test_decode_matches_forward_ssm():
    """Same decode-vs-forward contract for the SSM (stateful) family."""
    cfg = get_smoke("mamba2_130m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B, S = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    logits_par, _ = forward(params, cfg, {"tokens": jnp.asarray(toks)},
                            remat=False)
    caches = init_serve_cache(params, cfg, B, S + 4, prefilled=0)
    outs = []
    for t in range(S):
        lg, caches = serve_step(params, cfg, caches,
                                jnp.asarray(toks[:, t:t + 1]))
        outs.append(np.asarray(lg[:, 0].astype(jnp.float32)))
    dec = np.stack(outs, axis=1)
    par = np.asarray(logits_par.astype(jnp.float32))
    agree = (dec.argmax(-1) == par.argmax(-1)).mean()
    assert agree > 0.9, agree


def test_moe_router_balanced_losses_present():
    cfg = get_smoke("grok_1_314b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, met = loss_fn(params, cfg, batch)
    assert float(met["aux"]) >= 0.0

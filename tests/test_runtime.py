"""Fault tolerance, checkpoint/restart, straggler, elastic mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint.ckpt import load_pytree, save_pytree
from repro.runtime import StragglerMonitor
from repro.runtime.fault import (ElasticMesh, FailureSim,
                                 best_mesh_shape, run_with_restarts)


def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": (jnp.ones((3, 3)),
                                         jnp.asarray(3, jnp.int32))}
    p = str(tmp_path / "ck")
    save_pytree(tree, p)
    out = load_pytree(jax.tree.map(jnp.zeros_like, tree), p)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_manager_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.zeros(4)}
    for step in [10, 20, 30]:
        mgr.save(step, {"x": jnp.full(4, float(step))})
    mgr.wait()
    assert mgr.all_steps() == [20, 30]
    restored = mgr.restore_latest(state)
    assert restored is not None
    step, out = restored
    assert step == 30
    np.testing.assert_allclose(np.asarray(out["x"]), 30.0)


def test_manager_atomic_no_partial(tmp_path):
    """A leftover incomplete step dir from a killed writer is ignored
    by restore (no treedef.json => not a complete checkpoint)."""
    from repro.checkpoint.ckpt import latest_step
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, {"x": jnp.ones(2)})
    mgr.wait()
    os.makedirs(str(tmp_path / "step_9"), exist_ok=True)   # no payload
    assert latest_step(str(tmp_path)) == 5
    step, _ = mgr.restore_latest({"x": jnp.zeros(2)})
    assert step == 5


def test_run_with_restarts_bit_identical(tmp_path):
    """A crashed+restarted run ends in the same state as uninterrupted.

    Relies on counter-based RNG: step_fn(state, step) derives all
    randomness from (seed, step), never from wall time.
    """

    def init_fn():
        return {"x": jnp.zeros(3), "step_sum": jnp.asarray(0.0)}

    def step_fn(state, step):
        noise = jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(0), step), (3,))
        return {"x": state["x"] + noise,
                "step_sum": state["step_sum"] + step}

    clean, stats0 = run_with_restarts(
        20, init_fn, step_fn, CheckpointManager(str(tmp_path / "a"),
                                                keep=2), save_every=5)
    assert stats0["restarts"] == 0

    sim = FailureSim(fail_at=[7, 13])
    crashed, stats = run_with_restarts(
        20, init_fn, step_fn, CheckpointManager(str(tmp_path / "b"),
                                                keep=2),
        save_every=5, failure_sim=sim)
    assert stats["restarts"] == 2
    np.testing.assert_allclose(np.asarray(clean["x"]),
                               np.asarray(crashed["x"]), rtol=1e-6)
    assert float(clean["step_sum"]) == float(crashed["step_sum"])


def test_failure_sim_raises_once_per_step():
    sim = FailureSim(fail_at=[3])
    sim.check(2)
    with pytest.raises(FailureSim.DeviceLost):
        sim.check(3)
    sim.check(3)   # cleared after firing


def test_best_mesh_shape_shrinks():
    assert best_mesh_shape(256, 16) == (16, 16)
    assert best_mesh_shape(240, 16) == (15, 16)
    assert best_mesh_shape(250, 16) == (125, 2)   # 16,8,4 don't divide; 2 does
    assert best_mesh_shape(512, 16, multi_pod=True) == (2, 16, 16)
    assert best_mesh_shape(7, 4) == (7, 1)


def test_elastic_mesh_builds_on_survivors():
    mesh = ElasticMesh(model_parallel=1).build(jax.devices())
    assert mesh.devices.size == len(jax.devices())


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=2.0, patience=3)
    for _ in range(10):
        assert not mon.record(1.0)
    assert not mon.record(5.0)
    assert not mon.record(5.0)
    assert mon.record(5.0)          # third consecutive slow step
    assert not mon.record(1.0)      # recovery resets the streak


def test_train_loop_failure_restart(tmp_path):
    """LM train loop restarts from checkpoint and reaches the target
    step with identical loss trajectory after the restart point."""
    from repro.configs import get_smoke
    from repro.launch.train import train

    cfg = get_smoke("smollm_135m")
    out_clean = train(cfg, steps=8, batch=2, seq=32,
                      ckpt_dir=str(tmp_path / "clean"), save_every=4,
                      log_every=0)
    sim = FailureSim(fail_at=[6])
    out_crash = train(cfg, steps=8, batch=2, seq=32,
                      ckpt_dir=str(tmp_path / "crash"), save_every=4,
                      log_every=0, failure_sim=sim)
    assert out_crash["final_step"] == 8
    # the last steps (after restore from step 4) match the clean run
    np.testing.assert_allclose(out_clean["losses"][-2:],
                               out_crash["losses"][-2:], rtol=1e-5)

"""Flash attention: XLA custom-VJP and the Pallas forward kernel.

Both implementations must match the plain chunked-attention oracle —
forward to float tolerance, backward (custom VJP) against autodiff of
the reference.  The Pallas kernel runs in interpret mode (CPU
container; TPU is the target) over shape/dtype/GQA sweeps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_fwd_pallas
from repro.models.layers import chunked_attention, flash_attention


def _qkv(seed, B, Sq, Sk, H, KVH, hd, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, KVH, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, KVH, hd)), dtype)
    return q, k, v


def test_flash_vjp_fwd_matches_reference():
    q, k, v = _qkv(0, 2, 256, 256, 6, 3, 16)
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    out = flash_attention(q, k, v, True, 0, 0, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_vjp_grads_match_autodiff():
    q, k, v = _qkv(1, 1, 128, 128, 4, 2, 8)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(
            chunked_attention(q, k, v, causal=True, chunk=32)))

    def loss_fl(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, True, 0, 0, 32)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-4)


def test_flash_vjp_windowed():
    q, k, v = _qkv(2, 1, 128, 128, 2, 2, 8)
    ref = chunked_attention(q, k, v, causal=True, chunk=32, window=48)
    out = flash_attention(q, k, v, True, 48, 0, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KVH,hd", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 128, 4, 2, 16),     # GQA 2:1
    (1, 256, 6, 3, 16),     # GQA 2:1, longer
    (1, 128, 8, 1, 8),      # MQA
])
def test_pallas_flash_fwd_sweep(B, S, H, KVH, hd):
    q, k, v = _qkv(3, B, S, S, H, KVH, hd)
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    out = flash_fwd_pallas(q, k, v, causal=True, block_q=64,
                           block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_fwd_dtypes(dtype):
    q, k, v = _qkv(4, 1, 128, 128, 4, 2, 16, dtype)
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    out = flash_fwd_pallas(q, k, v, causal=True, block_q=64,
                           block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_pallas_flash_fwd_windowed_offset():
    # decode-style: q continues at an offset against a longer cache
    q, k, v = _qkv(5, 1, 64, 256, 4, 2, 16)
    ref = chunked_attention(q, k, v, causal=True, chunk=64,
                            window=128, q_offset=192)
    out = flash_fwd_pallas(q, k, v, causal=True, window=128,
                           q_offset=192, block_q=64, block_k=64,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pallas_flash_noncausal():
    q, k, v = _qkv(6, 1, 128, 128, 4, 4, 16)
    ref = chunked_attention(q, k, v, causal=False, chunk=64)
    out = flash_fwd_pallas(q, k, v, causal=False, block_q=64,
                           block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

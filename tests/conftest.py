import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep the default 1-device CPU platform for tests; only dryrun.py (its
# own process) forces 512 placeholder devices.  Tests that need a few
# devices spawn subprocesses (see test_distributed.py).

"""The ``repro.obs`` observability subsystem (PR 10).

Three contract families:

1. **Primitives** — fixed-bucket histograms (observe/percentile/
   serialization round-trip), recorder span/counter/gauge semantics,
   trace + metrics + Prometheus export formats.
2. **Determinism** — a DISABLED recorder never reads the clock and
   records nothing (the on/off bitwise-equality side lives in
   tests/test_golden_chain.py and tests/test_multichain.py).
3. **Wiring** — ``REPRO_OBS=1`` makes an ordinary ``TrainSession``
   emit a loadable Chrome trace with contract-derived
   ``bytes_on_wire`` on every sweep span; ``PredictSession`` exposes
   cache hit/miss stats; the module-level spec cache is a bounded LRU.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.obs import (Histogram, METRICS_FORMAT, TRACE_FORMAT,
                       Recorder, integer_buckets, latency_buckets,
                       obs_enabled, percentile_summary,
                       prometheus_text, resolve_recorder,
                       write_json_atomic)


# ---------------------------------------------------------------------------
# histogram primitives
# ---------------------------------------------------------------------------

def test_latency_buckets_geometric_and_bounded():
    b = latency_buckets()
    assert b[0] == pytest.approx(1e-4)
    assert all(y > x for x, y in zip(b, b[1:]))
    assert b[-1] >= 120.0
    # geometric: constant ratio
    ratios = [y / x for x, y in zip(b, b[1:])]
    assert max(ratios) - min(ratios) < 1e-9


def test_integer_buckets_count_exactly():
    h = Histogram(integer_buckets(4))
    for occ, times in ((1, 3), (2, 1), (4, 2)):
        for _ in range(times):
            h.observe(occ)
    # exact counts: 0.5/1.5/2.5/3.5/4.5 edges isolate each integer
    assert h.counts[1] == 3 and h.counts[2] == 1 and h.counts[4] == 2
    assert h.total == 6
    assert h.mean() == pytest.approx((1 * 3 + 2 + 4 * 2) / 6, rel=0.5)


def test_histogram_percentile_interpolates():
    h = Histogram([1.0, 2.0, 4.0, 8.0])
    for v in (0.5, 1.5, 1.6, 3.0, 6.0):
        h.observe(v)
    assert 0.0 <= h.percentile(0.0) <= 1.0
    assert 1.0 <= h.percentile(0.5) <= 4.0
    assert h.percentile(0.5) == pytest.approx(1.75)  # interpolated
    assert h.percentile(1.0) <= 8.0
    with pytest.raises(ValueError):
        h.percentile(1.5)


def test_histogram_overflow_and_empty():
    h = Histogram([1.0, 2.0])
    assert math.isnan(h.percentile(0.5))    # empty
    h.observe(100.0)                        # overflow bucket
    assert h.counts[-1] == 1
    assert h.percentile(0.99) == 2.0        # clamped to last bound
    assert h.sum == pytest.approx(100.0)


def test_histogram_dict_round_trip_and_validation():
    h = Histogram(latency_buckets())
    for v in (0.001, 0.01, 0.01, 5.0):
        h.observe(v)
    d = h.to_dict()
    assert len(d["counts"]) == len(d["bounds"]) + 1
    assert d["total"] == 4
    h2 = Histogram.from_dict(d)
    assert h2.counts == h.counts and h2.bounds == h.bounds
    assert h2.percentile(0.5) == h.percentile(0.5)
    bad = dict(d, counts=d["counts"][:-1])
    with pytest.raises(ValueError):
        Histogram.from_dict(bad)


def test_percentile_summary_keys():
    h = Histogram(latency_buckets())
    h.observe(0.02)
    s = percentile_summary(h)
    assert set(s) == {"p50", "p99", "mean", "count"}
    assert s["count"] == 1


# ---------------------------------------------------------------------------
# recorder semantics
# ---------------------------------------------------------------------------

def test_disabled_recorder_records_nothing_and_skips_clock():
    rec = Recorder(enabled=False)
    assert rec.now() == 0.0     # no clock read on the off path
    with rec.span("x", cat="t"):
        pass
    rec.add("c")
    rec.gauge("g", 1.0)
    rec.observe("h", 0.5)
    assert rec.trace()["traceEvents"] == []
    m = rec.metrics()
    assert m["counters"] == {} and m["gauges"] == {} \
        and m["histograms"] == {}


def test_recorder_span_counter_gauge_and_trace_shape():
    rec = Recorder(enabled=True)
    rec.set_kind("session")
    with rec.span("phase/work", cat="test", step=3):
        rec.instant("marker", cat="test")
    rec.add("n", 2)
    rec.add("n")
    rec.gauge("depth", 4.0)
    rec.observe("lat", 0.01)

    tr = rec.trace()
    assert tr["repro"] == {"format": TRACE_FORMAT, "kind": "session"}
    by_name = {e["name"]: e for e in tr["traceEvents"]}
    span = by_name["phase/work"]
    assert span["ph"] == "X" and span["dur"] >= 0 \
        and span["args"]["step"] == 3
    assert by_name["marker"]["ph"] == "i"
    # instant fired inside the span's window
    assert span["ts"] <= by_name["marker"]["ts"] \
        <= span["ts"] + span["dur"]

    m = rec.metrics()
    assert m["format"] == METRICS_FORMAT and m["kind"] == "session"
    assert m["counters"]["n"] == 3.0
    assert m["gauges"]["depth"] == 4.0
    assert m["histograms"]["lat"]["total"] == 1

    rec.reset()
    assert rec.trace()["traceEvents"] == []
    assert rec.metrics()["counters"] == {}


def test_prometheus_text_exposition():
    rec = Recorder(enabled=True)
    rec.add("serve.completed", 5)
    rec.gauge("ckpt.queue_depth", 1.0)
    rec.observe("lat", 0.5, bounds=[1.0, 2.0])
    text = rec.prometheus()
    assert "repro_serve_completed 5" in text
    assert "repro_ckpt_queue_depth 1" in text
    assert 'repro_lat_bucket{le="1' in text
    assert 'le="+Inf"' in text
    assert "repro_lat_count 1" in text
    # standalone renderer agrees (TYPE header then the sample line)
    assert "\nrepro_a_b 1" in prometheus_text({"a.b": 1.0}, {}, {})


def test_obs_enabled_and_resolve_recorder(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert not obs_enabled()
    assert not resolve_recorder(None).enabled
    monkeypatch.setenv("REPRO_OBS", "1")
    assert obs_enabled()
    assert resolve_recorder(None).enabled
    # fresh per call — two runs never interleave traces
    assert resolve_recorder(None) is not resolve_recorder(None)
    mine = Recorder(enabled=False)
    assert resolve_recorder(mine) is mine


def test_write_json_atomic(tmp_path):
    p = tmp_path / "sub" / "x.json"
    write_json_atomic(p, {"a": 1})
    assert json.loads(p.read_text()) == {"a": 1}
    assert [f.name for f in (tmp_path / "sub").iterdir()] == ["x.json"]


# ---------------------------------------------------------------------------
# session wiring: REPRO_OBS=1 emits a loadable trace
# ---------------------------------------------------------------------------

def _toy_train(tmp_path, **kw):
    from repro.core import TrainSession
    from repro.core.sparse import random_sparse
    mat, _, _ = random_sparse(3, (40, 24), 0.3, rank=3)
    s = TrainSession(num_latent=4, burnin=2, nsamples=2, seed=3,
                     chains=1, save_freq=1,
                     save_dir=str(tmp_path / "store"), **kw)
    s.add_train_and_test(mat)
    return s.run()


def test_repro_obs_env_emits_loadable_trace(tmp_path, monkeypatch):
    """The acceptance path: REPRO_OBS=1 + REPRO_OBS_DIR, an ordinary
    TrainSession run, and the exported Chrome trace carries sweep
    spans with contract bytes_on_wire plus the compile split — and
    both exports pass the CI schema audit."""
    from repro.analysis.obsschema import obs_schema_findings

    out = tmp_path / "obs_out"
    monkeypatch.setenv("REPRO_OBS", "1")
    monkeypatch.setenv("REPRO_OBS_DIR", str(out))
    r = _toy_train(tmp_path)

    trace_p = out / "train_trace.json"
    metrics_p = out / "train_metrics.json"
    assert trace_p.is_file() and metrics_p.is_file()
    assert obs_schema_findings(trace_p) == []
    assert obs_schema_findings(metrics_p) == []

    doc = json.loads(trace_p.read_text())
    assert doc["repro"]["kind"] == "session"
    sweeps = [e for e in doc["traceEvents"] if e["name"] == "sweep"]
    assert len(sweeps) == 4     # burnin 2 + nsamples 2
    assert {e["args"]["phase"] for e in sweeps} == {"burnin", "sample"}
    assert all(isinstance(e["args"]["bytes_on_wire"], int)
               for e in sweeps)
    assert sweeps[0]["args"]["stage"] == "first"
    assert [e["args"]["sweep"] for e in sweeps] == [0, 1, 2, 3]
    compiles = [e for e in doc["traceEvents"]
                if e["name"] == "session/compile"]
    assert len(compiles) == 1

    met = json.loads(metrics_p.read_text())
    assert met["counters"]["session.sweeps"] == 4.0
    assert met["counters"]["ckpt.saves"] >= 1.0
    assert "session.sweep_s" in met["histograms"]

    # satellite 1: the runtime split is additive and JSON-visible
    d = r.to_dict()
    assert d["compile_s"] > 0.0
    assert d["total_s"] == pytest.approx(d["compile_s"]
                                         + d["runtime_s"])
    json.dumps(d)   # serializable end to end


def test_obs_off_session_exports_nothing(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    monkeypatch.delenv("REPRO_OBS_DIR", raising=False)
    r = _toy_train(tmp_path)
    assert not (tmp_path / "store" / "obs").exists()
    # the compile/runtime split is measured regardless of obs
    assert r.compile_s > 0.0 and r.runtime_s > 0.0


# ---------------------------------------------------------------------------
# predict/serve wiring: cache stats + bounded spec cache
# ---------------------------------------------------------------------------

def test_predict_cache_stats_and_serve_snapshot(tmp_path):
    from repro.core import PredictSession
    from repro.launch.serve import RecommendServer

    _toy_train(tmp_path)
    store = str(tmp_path / "store")
    ps = PredictSession(store)
    ps.warm_cache()     # miss
    ps.warm_cache()     # hit
    st = ps.cache_stats()
    assert st["misses"] == 1 and st["hits"] == 1
    assert st["over_budget"] == 0
    assert st["resident"] is True
    assert st["resident_bytes"] > 0
    assert st["load_count"] >= 1
    assert st["spec_cache"]["size"] <= st["spec_cache"]["max_size"]

    # a store bigger than the budget refuses residency and counts it
    tiny = PredictSession(store, cache_bytes=16)
    assert tiny.warm_cache() is None
    t = tiny.cache_stats()
    assert t["over_budget"] == 1 and t["resident"] is False

    srv = RecommendServer(ps, slots=2, k=3)
    for u in range(4):
        srv.submit(user=u)
    srv.run()
    snap = srv.metrics_snapshot()
    assert snap["kind"] == "serve"
    assert snap["counters"]["serve.completed"] == 4.0
    for name in ("serve.queue_wait_s", "serve.execute_s",
                 "serve.batch_occupancy"):
        assert name in snap["histograms"], name
    occ = Histogram.from_dict(snap["histograms"]
                              ["serve.batch_occupancy"])
    assert 1.0 <= occ.mean() <= 2.0     # slots=2 bound respected


def test_spec_cache_is_a_bounded_lru(tmp_path, monkeypatch):
    from repro.core import predict

    monkeypatch.setattr(predict, "_SPEC_CACHE_MAX", 2)
    predict._SPEC_CACHE.clear()
    for k in ("hits", "misses", "evictions"):
        predict._SPEC_CACHE_STATS[k] = 0

    stores = []
    for i in range(3):
        d = tmp_path / f"s{i}"
        _toy_train(tmp_path / f"t{i}")
        os.rename(tmp_path / f"t{i}" / "store", d)
        stores.append(str(d))

    for s in stores:
        predict.PredictSession(s)
    assert len(predict._SPEC_CACHE) == 2        # bounded
    st = predict.spec_cache_stats()
    assert st["misses"] == 3 and st["evictions"] == 1
    # LRU: oldest store evicted, newest two resident
    predict.PredictSession(stores[2])
    assert predict.spec_cache_stats()["hits"] >= 1

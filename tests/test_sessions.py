"""End-to-end Bayesian MF: the paper's algorithms recover planted data.

Paper analogues: §4 "We verified that the predictive performance of the
model, from all implementations is the same" — our check is recovery to
the planted noise floor + a slow dense reference sampler agreeing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveGaussian, FixedGaussian, GFASession,
                        ProbitNoise, TrainSession, from_coo, smurff)
from repro.data.synthetic import chembl_like


def _planted(seed=0, n=300, m=100, density=0.25, rank=4, noise=0.3):
    return chembl_like(seed, n_compounds=n, n_proteins=m,
                       density=density, rank=rank, noise=noise)


def test_bmf_recovers_noise_floor():
    mat, test, _ = _planted()
    res = smurff(mat, test=test, num_latent=4, burnin=80, nsamples=80,
                 seed=1)
    noise_floor = 0.3
    assert res.rmse_test < 1.3 * noise_floor, res.rmse_test
    # posterior averaging beats the last single sample (BMF robustness)
    assert res.rmse_test <= res.rmse_test_trace[0] + 1e-6


def test_bmf_adaptive_noise_finds_alpha():
    mat, test, _ = _planted(noise=0.5)
    sess = TrainSession(num_latent=4, burnin=60, nsamples=40, seed=0)
    sess.add_train_and_test(mat, test=test, noise=AdaptiveGaussian())
    res = sess.run()
    # chain 0's draw — state gains a leading (C,) axis under
    # REPRO_CHAINS>1 (the CI chains4 leg runs this file that way)
    alpha = float(np.atleast_1d(np.asarray(
        res.state.noises[0]["alpha"]))[0])
    # true precision = 1/0.25 = 4
    assert 2.0 < alpha < 7.0, alpha
    assert res.rmse_test < 0.75


def test_macau_side_info_lift():
    """Macau beats BMF when rows are cold (paper §4 Macau)."""
    mat, test, F = chembl_like(3, n_compounds=400, n_proteins=60,
                               density=0.04, rank=8, noise=0.2,
                               n_features=64, feature_noise=0.25)
    bmf = smurff(mat, test=test, num_latent=8, burnin=60, nsamples=60,
                 seed=0)
    macau = smurff(mat, test=test, side_info=(F, None), num_latent=8,
                   burnin=60, nsamples=60, seed=0)
    assert macau.rmse_test < bmf.rmse_test, \
        (macau.rmse_test, bmf.rmse_test)


def test_probit_binary_auc():
    rng = np.random.default_rng(3)
    U = rng.normal(size=(200, 4))
    V = rng.normal(size=(60, 4))
    P = (U @ V.T + 0.3 * rng.normal(size=(200, 60)) > 0)
    obs = rng.random((200, 60)) < 0.5
    i, j = np.nonzero(obs)
    perm = rng.permutation(len(i))
    i, j = i[perm], j[perm]
    v = P[i, j].astype(np.float32)
    n_test = len(i) // 5
    mat = from_coo(i[n_test:], j[n_test:], v[n_test:], (200, 60))
    res = smurff(mat, test=(i[:n_test], j[:n_test], v[:n_test]),
                 noise=ProbitNoise(), num_latent=4, burnin=80,
                 nsamples=80, seed=0)
    assert res.auc_test > 0.9, res.auc_test


def test_gfa_two_views():
    """GFA finds shared + private factors across views (paper §4 GFA)."""
    rng = np.random.default_rng(0)
    N, K = 150, 6
    Z = rng.normal(size=(N, K)).astype(np.float32)
    W1 = rng.normal(size=(40, K)).astype(np.float32)
    W1[:, 4:] = 0                  # view 1 misses factors 4,5
    W2 = rng.normal(size=(30, K)).astype(np.float32)
    W2[:, :2] = 0                  # view 2 misses factors 0,1
    X1 = Z @ W1.T + 0.1 * rng.normal(size=(N, 40)).astype(np.float32)
    X2 = Z @ W2.T + 0.1 * rng.normal(size=(N, 30)).astype(np.float32)
    g = GFASession([X1, X2], num_latent=8, burnin=80, nsamples=80,
                   seed=0).run()
    # reconstruction reaches the noise floor on both views
    assert g["rmse_train"][0][-1] < 0.15
    assert g["rmse_train"][1][-1] < 0.15
    # spike-and-slab kills unused components: the loading posterior
    # mean should have some components with tiny column norms
    Wm = g["W"][0]
    norms = np.sort(np.linalg.norm(Wm, axis=0))
    assert norms[0] < 0.1 * norms[-1]


def test_dense_block_bmf():
    """Fully-known dense input ('Dense-Dense' row of Table 1)."""
    rng = np.random.default_rng(1)
    U = rng.normal(size=(60, 3)).astype(np.float32)
    V = rng.normal(size=(40, 3)).astype(np.float32)
    R = U @ V.T + 0.1 * rng.normal(size=(60, 40)).astype(np.float32)
    sess = TrainSession(num_latent=3, burnin=60, nsamples=40, seed=0)
    sess.add_train_and_test(R, noise=FixedGaussian(25.0))
    res = sess.run()
    assert res.rmse_train_trace[-1] < 0.2


def test_use_pallas_path_matches_xla_path():
    """The Pallas kernels and the jnp oracle give the same chain."""
    mat, test, _ = _planted(n=64, m=32, density=0.3)
    a = smurff(mat, test=test, num_latent=4, burnin=20, nsamples=20,
               seed=5, use_pallas=False)
    b = smurff(mat, test=test, num_latent=4, burnin=20, nsamples=20,
               seed=5, use_pallas=True)
    # same RNG stream, same math -> near-identical chains
    np.testing.assert_allclose(a.rmse_test, b.rmse_test, rtol=1e-3)


def test_reproducible_same_seed():
    mat, test, _ = _planted(n=64, m=32, density=0.3)
    a = smurff(mat, test=test, num_latent=4, burnin=10, nsamples=10,
               seed=7)
    b = smurff(mat, test=test, num_latent=4, burnin=10, nsamples=10,
               seed=7)
    assert a.rmse_test == b.rmse_test
    c = smurff(mat, test=test, num_latent=4, burnin=10, nsamples=10,
               seed=8)
    assert a.rmse_test != c.rmse_test


def test_prior_registry_names():
    """Every named prior builds; unknown names raise a ValueError that
    lists the valid choices (not a bare KeyError)."""
    from repro.core.priors import (FixedNormalPrior, NormalPrior,
                                   SpikeAndSlabPrior)
    mat, test, _ = _planted(n=16, m=8, density=0.5)
    for name, cls in (("normal", NormalPrior),
                      ("spikeandslab", SpikeAndSlabPrior),
                      ("fixednormal", FixedNormalPrior)):
        sess = TrainSession(num_latent=3, priors=(name, "normal"))
        sess.add_train_and_test(mat)
        model, _ = sess._build()
        assert isinstance(model.entities[0].prior, cls), name

    sess = TrainSession(num_latent=3, priors=("bogus", "normal"))
    sess.add_train_and_test(mat)
    with pytest.raises(ValueError) as ei:
        sess._build()
    msg = str(ei.value)
    assert "bogus" in msg
    for name in ("normal", "spikeandslab", "fixednormal"):
        assert name in msg


def test_dense_all_ones_mask_fast_path():
    """dense_block with an explicit all-ones mask takes the fully-
    observed shared-Gram path and produces the IDENTICAL sweep to the
    mask=None construction."""
    from repro.core import (BlockDef, EntityDef, MFData, ModelDef,
                            NormalPrior, dense_block, gibbs_step,
                            init_state)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(24, 12)).astype(np.float32)
    a = dense_block(X)
    b = dense_block(X, mask=np.ones_like(X))
    assert a.fully and b.fully

    model = ModelDef((EntityDef("r", 24, NormalPrior(3)),
                      EntityDef("c", 12, NormalPrior(3))),
                     (BlockDef(0, 1, FixedGaussian(10.0), sparse=False),),
                     3, False)
    outs = []
    for blk in (a, b):
        data = MFData((blk,), (None, None))
        state = init_state(model, data, 0)
        for _ in range(2):
            state, metrics = gibbs_step(model, data, state)
        outs.append((state, metrics))
    for fa, fb in zip(outs[0][0].factors, outs[1][0].factors):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert float(outs[0][1]["rmse_train_0"]) == \
        float(outs[1][1]["rmse_train_0"])
    # a genuinely masked block still takes the per-row path
    m = np.ones_like(X)
    m[0, 0] = 0.0
    assert not dense_block(X, mask=m).fully


def test_session_mesh_and_pipeline_knob():
    """``TrainSession(mesh=..., pipeline=...)`` routes through the
    explicit distributed sweep: on the degenerate 1-device mesh both
    exchange pipelines must reproduce the plain single-device session
    chain exactly (the ring has zero hops, the gather is a no-op —
    any drift would mean the knob changes the SAMPLED chain, which it
    never may), and an unknown pipeline fails fast with the valid
    choices before any sweep runs."""
    mat, test, _ = _planted(n=64, m=32, density=0.4)
    from repro.launch.mesh import make_mesh

    def session(**kw):
        s = TrainSession(num_latent=3, burnin=4, nsamples=4, seed=0, **kw)
        s.add_train_and_test(mat, test=test, noise=AdaptiveGaussian())
        return s

    ref = session().run()
    mesh = make_mesh((1,), ("data",))
    for pipe in ("eager", "ring"):
        res = session(mesh=mesh, pipeline=pipe).run()
        np.testing.assert_allclose(res.rmse_train_trace,
                                   ref.rmse_train_trace, rtol=1e-5,
                                   err_msg=pipe)
        np.testing.assert_allclose(res.rmse_test, ref.rmse_test,
                                   rtol=1e-5, err_msg=pipe)

    with pytest.raises(ValueError, match="valid pipelines"):
        session(mesh=mesh, pipeline="warp").run()

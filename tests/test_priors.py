"""Prior conditionals: statistical sanity of the Gibbs building blocks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.priors import (MacauPrior, NormalPrior,
                               SpikeAndSlabPrior, chol_solve,
                               sample_mvn_from_precision, sample_wishart)


def test_chol_solve_batched():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(5, 4, 4)).astype(np.float32)
    spd = A @ np.swapaxes(A, -1, -2) + 4 * np.eye(4, dtype=np.float32)
    b = rng.normal(size=(5, 4)).astype(np.float32)
    L = np.linalg.cholesky(spd)
    x = chol_solve(jnp.asarray(L), jnp.asarray(b))
    expect = np.linalg.solve(spd, b[..., None])[..., 0]
    np.testing.assert_allclose(x, expect, rtol=1e-4, atol=1e-4)


def test_wishart_mean():
    """E[Wishart(V, df)] = df * V."""
    key = jax.random.PRNGKey(0)
    K, df, n = 3, 10.0, 4000
    V = np.array([[1.0, 0.3, 0.0], [0.3, 1.0, 0.2], [0.0, 0.2, 0.5]],
                 np.float32)
    L = jnp.asarray(np.linalg.cholesky(V))
    draws = jax.vmap(lambda k: sample_wishart(k, L, df))(
        jax.random.split(key, n))
    mean = np.asarray(draws).mean(axis=0)
    np.testing.assert_allclose(mean, df * V, rtol=0.08, atol=0.05)


def test_mvn_from_precision_moments():
    key = jax.random.PRNGKey(1)
    K, n = 3, 20000
    Lam = np.array([[2.0, 0.5, 0.0], [0.5, 1.5, 0.3], [0.0, 0.3, 1.0]],
                   np.float32)
    L = jnp.asarray(np.linalg.cholesky(Lam))
    mean = jnp.asarray([1.0, -2.0, 0.5])
    draws = jax.vmap(
        lambda k: sample_mvn_from_precision(
            k, L, mean))(jax.random.split(key, n))
    d = np.asarray(draws)
    np.testing.assert_allclose(d.mean(axis=0), mean, atol=0.05)
    np.testing.assert_allclose(np.cov(d.T), np.linalg.inv(Lam),
                               rtol=0.1, atol=0.05)


def test_normal_prior_hyper_tracks_factor():
    """With many rows the NW posterior concentrates near the sample
    moments of the factor matrix."""
    rng = np.random.default_rng(2)
    N, K = 5000, 4
    true_mu = np.array([1.0, -1.0, 0.5, 0.0], np.float32)
    F = (true_mu + 0.5 * rng.normal(size=(N, K))).astype(np.float32)
    prior = NormalPrior(K)
    h = prior.init(jax.random.PRNGKey(0), N)
    h = prior.sample_hyper(jax.random.PRNGKey(1), jnp.asarray(F), h)
    np.testing.assert_allclose(np.asarray(h["mu"]), true_mu, atol=0.1)
    # Lambda ~ inverse of sample covariance = 1/0.25 * I
    lam = np.asarray(h["Lambda"])
    np.testing.assert_allclose(lam, 4.0 * np.eye(K), rtol=0.25, atol=0.4)


def test_normal_prior_distributed_moments_match():
    """Passing psummed moments equals the local computation."""
    rng = np.random.default_rng(3)
    F = jnp.asarray(rng.normal(size=(100, 4)).astype(np.float32))
    prior = NormalPrior(4)
    h0 = prior.init(jax.random.PRNGKey(0), 100)
    key = jax.random.PRNGKey(42)
    a = prior.sample_hyper(key, F, h0)
    b = prior.sample_hyper(key, F, h0, F_sum=F.sum(axis=0),
                           F_cov=F.T @ F, n_rows=100)
    np.testing.assert_allclose(np.asarray(a["mu"]), np.asarray(b["mu"]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a["Lambda"]),
                               np.asarray(b["Lambda"]),
                               rtol=1e-4, atol=1e-4)


def test_macau_beta_recovers_planted_link():
    """U = F beta* + noise: the beta conditional should find beta*."""
    rng = np.random.default_rng(4)
    N, D, K = 2000, 8, 3
    F = rng.normal(size=(N, D)).astype(np.float32)
    beta_true = rng.normal(size=(D, K)).astype(np.float32)
    U = (F @ beta_true + 0.1 * rng.normal(size=(N, K))).astype(np.float32)
    prior = MacauPrior(K, D, sample_beta_precision=False,
                       beta_precision=1.0)
    h = prior.init(jax.random.PRNGKey(0), N)
    for it in range(5):
        h = prior.sample_hyper(jax.random.PRNGKey(it), jnp.asarray(U), h,
                               side=jnp.asarray(F))
    np.testing.assert_allclose(np.asarray(h["beta"]), beta_true,
                               rtol=0.15, atol=0.15)


def test_sns_hyper_estimates_sparsity():
    """rho_k tracks the per-component inclusion rate."""
    rng = np.random.default_rng(5)
    N, K = 4000, 4
    incl = np.array([0.9, 0.5, 0.1, 1.0])
    s = rng.random((N, K)) < incl
    F = (s * rng.normal(size=(N, K))).astype(np.float32)
    prior = SpikeAndSlabPrior(K)
    h = prior.sample_hyper(jax.random.PRNGKey(0), jnp.asarray(F),
                           prior.init(jax.random.PRNGKey(0), N))
    np.testing.assert_allclose(np.asarray(h["rho"]), incl, atol=0.05)
    # tau ~ 1 (unit slab variance); the rarely-included component has
    # few samples, so its posterior draw is noisy
    np.testing.assert_allclose(np.asarray(h["tau"]), 1.0, rtol=0.35)


def test_sns_distributed_moments_match():
    """Passing psummed per-component moments equals the local
    computation — the SnS sibling of the NormalPrior moments test,
    backing the two K-sized psums the distributed sweep issues."""
    rng = np.random.default_rng(6)
    N, K = 200, 4
    s = rng.random((N, K)) < 0.6
    F = jnp.asarray((s * rng.normal(size=(N, K))).astype(np.float32))
    prior = SpikeAndSlabPrior(K)
    h0 = prior.init(jax.random.PRNGKey(0), N)
    key = jax.random.PRNGKey(42)
    a = prior.sample_hyper(key, F, h0)
    incl = (jnp.abs(F) > 0).astype(jnp.float32)
    b = prior.sample_hyper_moments(key, h0, n_incl=incl.sum(axis=0),
                                   sumsq=(F * F).sum(axis=0), n_rows=N)
    for hk in ("rho", "tau"):
        np.testing.assert_allclose(np.asarray(a[hk]), np.asarray(b[hk]),
                                   rtol=1e-5, atol=1e-6)

"""Golden-chain regression: the sampled chain itself is pinned.

Parity and property tests check *relationships* (sharded == single
device, batched == loop); none of them notices if a refactor changes
the RNG consumption order and silently produces a different — equally
valid-looking — chain, which would invalidate every stored checkpoint
and reproducibility claim.  This locks the 3-sweep RMSE/alpha
trajectories of one Gaussian, one probit, and one GFA (spike-and-slab)
model on a fixed seed into ``results/golden_chains.json``.  The GFA
chain pins the counter-based SnS draw order (``row_bernoulli`` +
per-component-folded ``row_normals``) that the distributed sweep's
shard slices are defined against.

``test_golden_chain_ring_pipeline_no_fork`` additionally replays the
same three models through the RING-pipelined distributed sweep
(``pipeline="ring"``) and asserts the trajectories land on the SAME
fixture — the ring exchange must not fork the golden chains, so the
fixture never needs a ring-mode regeneration.

Tolerance: 1e-3 relative.  XLA reduction-order drift across versions
measures ~1e-6..1e-5 on these trajectories; a changed draw sequence
moves them by ~1e-1.  Regenerate INTENTIONALLY after an acknowledged
chain-breaking change:

    PYTHONPATH=src python tests/test_golden_chain.py --regen
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (AdaptiveGaussian, BlockDef, EntityDef,
                        FixedNormalPrior, MFData, ModelDef, NormalPrior,
                        ProbitNoise, SpikeAndSlabPrior, dense_block,
                        gibbs_step, init_state)
from repro.core.sparse import random_sparse

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "results",
                       "golden_chains.json")
SWEEPS = 3
SEED = 11


def _chain(name):
    K = 4
    if name == "gfa":
        return _gfa_chain(K)
    n_rows, n_cols = 48, 32
    binary = name == "probit"
    mat, _, _ = random_sparse(SEED, (n_rows, n_cols), 0.3, rank=3,
                              binary=binary)
    noise = ProbitNoise() if binary else AdaptiveGaussian()
    model = ModelDef((EntityDef("r", n_rows, NormalPrior(K)),
                      EntityDef("c", n_cols, NormalPrior(K))),
                     (BlockDef(0, 1, noise, sparse=True),), K, False)
    data = MFData((mat,), (None, None))
    state = init_state(model, data, seed=SEED)
    rmse, alpha = [], []
    for _ in range(SWEEPS):
        state, metrics = gibbs_step(model, data, state)
        rmse.append(float(metrics["rmse_train_0"]))
        alpha.append(float(metrics["alpha_0"]))
    return {"rmse_train": rmse, "alpha": alpha}


def _gfa_chain(K):
    """GFA (FixedNormal Z + SnS loadings, two dense views): pins the
    counter-based spike-and-slab draw order."""
    rng = np.random.default_rng(SEED)
    N, dims = 48, (16, 12)
    Z = rng.normal(size=(N, K)).astype(np.float32)
    ents = [EntityDef("samples", N, FixedNormalPrior(K))]
    blocks, payloads = [], []
    for m, D in enumerate(dims):
        W = rng.normal(size=(D, K)).astype(np.float32)
        X = (Z @ W.T + 0.1 * rng.normal(size=(N, D))).astype(np.float32)
        ents.append(EntityDef(f"view{m}", D, SpikeAndSlabPrior(K)))
        blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                               sparse=False))
        payloads.append(dense_block(X))
    model = ModelDef(tuple(ents), tuple(blocks), K, False)
    data = MFData(tuple(payloads), tuple([None] * len(ents)))
    state = init_state(model, data, seed=SEED)
    rmse, alpha = [], []
    for _ in range(SWEEPS):
        state, metrics = gibbs_step(model, data, state)
        rmse.append(float(metrics["rmse_train_0"]))
        alpha.append(float(metrics["alpha_0"]))
    return {"rmse_train": rmse, "alpha": alpha}


def _run_all():
    return {name: _chain(name) for name in ("gaussian", "probit", "gfa")}


def test_golden_chain_trajectories():
    with open(FIXTURE) as f:
        golden = json.load(f)
    got = _run_all()
    assert set(got) == set(golden["chains"])
    for name, traj in got.items():
        for key in ("rmse_train", "alpha"):
            np.testing.assert_allclose(
                traj[key], golden["chains"][name][key],
                rtol=1e-3, atol=1e-5,
                err_msg=f"{name}.{key} drifted — if the chain change "
                        "is intentional, regen the fixture (see module "
                        "docstring)")


def test_wrappers_replay_golden_chain():
    """The session wrappers (now thin layers over ``ModelBuilder``)
    compose the IDENTICAL model graphs the engine fixtures pin:
    ``TrainSession`` replays the gaussian/probit chains and
    ``GFASession(zero_init_loadings=False)`` the GFA chain —
    BITWISE against the in-process engine chain (same jit program,
    same RNG stream) and at the usual tolerance against the on-disk
    fixture.  The builder redesign provably forks no sampled chain."""
    from repro.core import (AdaptiveGaussian, GFASession, ProbitNoise,
                            TrainSession)
    from repro.core.sparse import random_sparse

    with open(FIXTURE) as f:
        golden = json.load(f)["chains"]
    engine = _run_all()

    def trace_cb(store):
        def cb(info):
            store["rmse_train"].append(
                float(info.metrics["rmse_train_0"]))
            store["alpha"].append(float(info.metrics["alpha_0"]))
        return cb

    got = {}
    for name in ("gaussian", "probit"):
        binary = name == "probit"
        mat, _, _ = random_sparse(SEED, (48, 32), 0.3, rank=3,
                                  binary=binary)
        store = {"rmse_train": [], "alpha": []}
        s = TrainSession(num_latent=4, burnin=SWEEPS, nsamples=0,
                         seed=SEED, callbacks=[trace_cb(store)])
        s.add_train_and_test(
            mat, noise=ProbitNoise() if binary else AdaptiveGaussian())
        s.run()
        got[name] = store

    rng = np.random.default_rng(SEED)
    N, dims, K = 48, (16, 12), 4
    Z = rng.normal(size=(N, K)).astype(np.float32)
    views = []
    for m, D in enumerate(dims):
        W = rng.normal(size=(D, K)).astype(np.float32)
        views.append((Z @ W.T + 0.1 * rng.normal(size=(N, D)))
                     .astype(np.float32))
    store = {"rmse_train": [], "alpha": []}
    GFASession(views, num_latent=K, burnin=SWEEPS, nsamples=0,
               seed=SEED, zero_init_loadings=False,
               callbacks=[trace_cb(store)]).run()
    got["gfa"] = store

    for name, traj in got.items():
        for key in ("rmse_train", "alpha"):
            # bitwise vs the engine chain computed in this process
            np.testing.assert_array_equal(
                traj[key], engine[name][key],
                err_msg=f"wrapper {name}.{key} forked off the engine "
                        "chain — the builder rewrite changed the "
                        "sampled draws")
            # and within reduction-order tolerance of the fixture
            np.testing.assert_allclose(
                traj[key], golden[name][key], rtol=1e-3, atol=1e-5,
                err_msg=f"wrapper {name}.{key} drifted off the golden "
                        "fixture")


_RING_GOLDEN_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax

from repro.core import (AdaptiveGaussian, BlockDef, EntityDef,
                        FixedNormalPrior, MFData, ModelDef, NormalPrior,
                        ProbitNoise, SpikeAndSlabPrior, dense_block,
                        init_state)
from repro.core.distributed import (distributed_supported,
                                    make_distributed_step)
from repro.core.sparse import random_sparse
from repro.launch.mesh import make_mesh

FIXTURE = os.environ["GOLDEN_FIXTURE"]
SWEEPS, SEED, K = 3, 11, 4

with open(FIXTURE) as f:
    golden = json.load(f)["chains"]
assert json.load(open(FIXTURE))["seed"] == SEED


def ring_chain(model, data, n_dev):
    # the GFA golden dims (16, 12) divide 4 shards, not 8 — the mesh
    # is part of the harness, the chain must not depend on it
    mesh = make_mesh((n_dev,), ("data",))
    assert distributed_supported(model, mesh, data)
    state = init_state(model, data, seed=SEED)
    step, ds, ss = make_distributed_step(model, mesh, data, state,
                                         pipeline="ring")
    st = jax.device_put(state, ss)
    pdata = jax.device_put(data, ds)
    rmse, alpha = [], []
    for _ in range(SWEEPS):
        st, metrics = step(pdata, st)
        rmse.append(float(metrics["rmse_train_0"]))
        alpha.append(float(metrics["alpha_0"]))
    return {"rmse_train": rmse, "alpha": alpha}


chains = {}
n_rows, n_cols = 48, 32
for name in ("gaussian", "probit"):
    binary = name == "probit"
    mat, _, _ = random_sparse(SEED, (n_rows, n_cols), 0.3, rank=3,
                              binary=binary)
    noise = ProbitNoise() if binary else AdaptiveGaussian()
    model = ModelDef((EntityDef("r", n_rows, NormalPrior(K)),
                      EntityDef("c", n_cols, NormalPrior(K))),
                     (BlockDef(0, 1, noise, sparse=True),), K, False)
    chains[name] = ring_chain(model, MFData((mat,), (None, None)), 8)

rng = np.random.default_rng(SEED)
N, dims = 48, (16, 12)
Z = rng.normal(size=(N, K)).astype(np.float32)
ents = [EntityDef("samples", N, FixedNormalPrior(K))]
blocks, payloads = [], []
for m, D in enumerate(dims):
    W = rng.normal(size=(D, K)).astype(np.float32)
    X = (Z @ W.T + 0.1 * rng.normal(size=(N, D))).astype(np.float32)
    ents.append(EntityDef(f"view{m}", D, SpikeAndSlabPrior(K)))
    blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(), sparse=False))
    payloads.append(dense_block(X))
gfa_model = ModelDef(tuple(ents), tuple(blocks), K, False)
chains["gfa"] = ring_chain(
    gfa_model, MFData(tuple(payloads), tuple([None] * len(ents))), 4)

for name, traj in chains.items():
    for key in ("rmse_train", "alpha"):
        np.testing.assert_allclose(
            traj[key], golden[name][key], rtol=1e-3, atol=1e-5,
            err_msg=f"ring {name}.{key} forked off the golden chain")
    print(name, "ring == golden", traj["rmse_train"])
print("OK")
"""


@pytest.mark.slow
def test_golden_chain_ring_pipeline_no_fork():
    """The ring-pipelined distributed sweep reproduces the pinned
    golden trajectories — ring mode does NOT fork
    ``results/golden_chains.json``, so the fixture regenerates
    identical whichever pipeline produced the running chain."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env["GOLDEN_FIXTURE"] = os.path.abspath(FIXTURE)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _RING_GOLDEN_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


def test_recorder_does_not_fork_golden_chain(tmp_path):
    """Bitwise non-interference (the ``repro.obs`` contract): a
    ``TrainSession`` run with an ENABLED recorder replays the
    recorder-off run EXACTLY — every trace value and every state
    leaf — because timestamps are taken outside jitted code and
    never feed back into sampling.  ``chains=1`` is pinned so the
    CI ``REPRO_CHAINS=4`` leg exercises the same baseline."""
    import jax

    from repro.core import TrainSession
    from repro.obs import Recorder

    mat, _, _ = random_sparse(SEED, (48, 32), 0.3, rank=3)

    def run(recorder):
        s = TrainSession(num_latent=4, burnin=2, nsamples=3,
                         seed=SEED, chains=1, recorder=recorder)
        s.add_train_and_test(mat, noise=AdaptiveGaussian())
        return s.run()

    off = run(Recorder(enabled=False))
    rec = Recorder(enabled=True)
    on = run(rec)

    assert on.rmse_train_trace == off.rmse_train_trace
    assert on.rmse_test_trace == off.rmse_test_trace
    assert on.rmse_test == off.rmse_test
    for x, y in zip(jax.tree.leaves(on.state),
                    jax.tree.leaves(off.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # the enabled run actually recorded: compile split + sweep spans
    names = {e["name"] for e in rec.trace()["traceEvents"]}
    assert {"session/compile", "sweep"} <= names
    assert rec.counter("session.sweeps") == 5.0
    # and the split is visible in the result
    assert on.compile_s > 0.0
    assert off.compile_s > 0.0


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("pass --regen to overwrite the fixture")
    out = {"seed": SEED, "sweeps": SWEEPS, "chains": _run_all()}
    with open(FIXTURE, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", FIXTURE)

"""The posterior serving layer: resident cache + RecommendServer.

Pins the three contracts ISSUE 7 introduced:

* the RELOAD BUG stays fixed — after the first request warms the
  resident cache, every further ``predict``/``predict_all``/
  ``predict_new``/``recommend`` performs ZERO checkpoint loads
  (``PredictSession.load_count`` stays flat);
* BATCHING CHANGES NO ANSWER — ``RecommendServer`` results are
  bitwise equal to sequential ``PredictSession.recommend`` calls
  (each query runs one identical float program whatever the batch);
* the slot runtime's request ids are collision-free — monotonic
  defaults survive queue drains, explicit duplicates raise.
"""
import numpy as np
import pytest

from repro.core import (AdaptiveGaussian, ModelBuilder, PredictSession,
                        from_coo)
from repro.launch.serve import RecommendServer, SlotServer


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    """A small saved Macau store: (save_dir, F, obs, n_warm)."""
    rng = np.random.default_rng(0)
    n_c, n_t, n_feat, rank = 36, 20, 6, 3
    F = rng.normal(size=(n_c, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    T = rng.normal(size=(n_t, rank)).astype(np.float32)
    act = (F @ B @ T.T).astype(np.float32)
    n_warm = n_c - 4                       # last 4 rows never trained
    obs = rng.random((n_warm, n_t)) < 0.6
    i, j = np.nonzero(obs)
    mat = from_coo(i, j, act[i, j], (n_warm, n_t))
    d = tmp_path_factory.mktemp("serving_store")
    b = ModelBuilder(num_latent=4)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])
    b.add_entity("target", n_t)
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian())
    b.session(burnin=5, nsamples=6, seed=0, save_freq=1,
              save_dir=str(d)).run()
    return str(d), F, obs, n_warm


# -- the reload bug stays fixed -------------------------------------------

def test_second_request_zero_checkpoint_loads(store):
    """The acceptance criterion: warming costs exactly S loads, every
    later request of ANY kind costs zero."""
    d, F, _, n_warm = store
    p = PredictSession(d)
    assert p.load_count == 0
    p.recommend(user=[0, 1], k=3)
    assert p.load_count == p.num_samples    # the one-time warm
    warm = p.load_count
    p.recommend(user=[2, 3], k=5)
    p.recommend(features=F[n_warm:], k=3)
    p.predict([0, 1], [2, 3])
    p.predict_all()
    p.predict_new("compound", F[n_warm:])
    assert p.load_count == warm
    assert p.cache_resident


def test_cached_predict_bitwise_equals_lazy(store):
    """Routing predict through the cache keeps the identical float
    program: cached and lazy answers are bitwise equal."""
    d, F, _, n_warm = store
    cached = PredictSession(d)
    lazy = PredictSession(d, cache_bytes=0)
    assert lazy.warm_cache() is None
    i, j = [0, 5, 9], [1, 2, 3]
    np.testing.assert_array_equal(cached.predict(i, j),
                                  lazy.predict(i, j))
    np.testing.assert_array_equal(cached.predict_all(),
                                  lazy.predict_all())
    np.testing.assert_array_equal(
        cached.predict_new("compound", F[n_warm:]),
        lazy.predict_new("compound", F[n_warm:]))
    assert not lazy.cache_resident and lazy.load_count > 0


def test_over_budget_recommend_falls_back(store):
    """Stores above the byte budget still serve recommendations (the
    streaming fallback): same ids, means to float tolerance."""
    d, _, _, _ = store
    cached = PredictSession(d).recommend(user=[0, 1, 2], k=5)
    lazy = PredictSession(d, cache_bytes=0).recommend(user=[0, 1, 2],
                                                      k=5)
    np.testing.assert_array_equal(cached.ids, lazy.ids)
    np.testing.assert_allclose(cached.mean, lazy.mean,
                               rtol=1e-6, atol=1e-7)
    # std subtracts near-equal moments (sqrt(ex2 - mean^2)); the
    # different summation order amplifies the cancellation
    np.testing.assert_allclose(cached.std, lazy.std,
                               rtol=1e-3, atol=1e-6)


def test_store_nbytes_gates_residency(store):
    d, _, _, _ = store
    p = PredictSession(d)
    assert 0 < p.store_nbytes() < p.cache_bytes
    assert PredictSession(d, cache_bytes=0).store_nbytes() \
        == p.store_nbytes()


def test_spec_cached_across_instances(store):
    """model.json parses once per store (mtime-keyed), not once per
    PredictSession."""
    d, _, _, _ = store
    assert PredictSession(d).spec is PredictSession(d).spec


def test_load_sample_unknown_step_still_raises(store):
    d, _, _, _ = store
    p = PredictSession(d)
    with pytest.raises(ValueError, match="no sample at step"):
        p.load_sample(10**9)


# -- recommend: the session-level API -------------------------------------

def test_recommend_batched_equals_sequential_bitwise(store):
    d, F, _, n_warm = store
    p = PredictSession(d)
    users = [0, 3, 7, 11]
    batched = p.recommend(user=users, k=5)
    for b, u in enumerate(users):
        single = p.recommend(user=u, k=5)
        np.testing.assert_array_equal(batched.ids[b], single.ids[0])
        np.testing.assert_array_equal(batched.mean[b], single.mean[0])
        np.testing.assert_array_equal(batched.std[b], single.std[0])


def test_recommend_exclusion_and_clamping(store):
    d, _, obs, _ = store
    p = PredictSession(d)
    seen = np.nonzero(obs[0])[0]
    r = p.recommend(user=[0], k=8, exclude=[seen])
    assert not set(r.ids[0][r.ids[0] >= 0]) & set(seen.tolist())
    n_items = obs.shape[1]
    big = p.recommend(user=[0], k=n_items + 50)
    assert big.ids.shape == (1, n_items)          # K > n_items clamps
    # excluding all but two items leaves a -1/NaN tail
    almost = list(range(n_items - 2))
    t = p.recommend(user=[0], k=5, exclude=[almost])
    assert (t.ids[0][2:] == -1).all()
    assert np.isnan(t.mean[0][2:]).all() and (t.ids[0][:2] >= 0).all()


def test_recommend_cold_start_matches_predict_new(store):
    """Cold-start ranking must agree with the out-of-matrix posterior
    mean: the top recommended item is predict_new's argmax row-wise,
    and the reported mean matches its value."""
    d, F, _, n_warm = store
    p = PredictSession(d)
    dense = p.predict_new("compound", F[n_warm:])     # (4, n_items)
    rec = p.recommend(features=F[n_warm:], k=3)
    for m in range(dense.shape[0]):
        assert rec.ids[m, 0] == int(np.argmax(dense[m]))
        np.testing.assert_allclose(rec.mean[m, 0], dense[m].max(),
                                   rtol=1e-5, atol=1e-6)


def test_recommend_validation(store):
    d, F, _, n_warm = store
    p = PredictSession(d)
    with pytest.raises(ValueError, match="cold start"):
        p.recommend(user=n_warm + 100)      # out of range names fix
    with pytest.raises(ValueError, match="user="):
        p.recommend()
    with pytest.raises(ValueError, match="one id-sequence"):
        p.recommend(user=[0, 1], k=3, exclude=[[1]])


# -- RecommendServer: the batched online layer ----------------------------

def test_recommend_server_bitwise_vs_sequential(store):
    """The e2e acceptance: a full mixed workload (warm, cold,
    exclusions, per-request k) served through the batching runtime is
    bitwise identical to one-at-a-time PredictSession calls."""
    d, F, obs, n_warm = store
    sess = PredictSession(d)
    srv = RecommendServer(sess, slots=3, k=5)
    warm_loads = sess.load_count
    reqs = {}
    for u in range(7):
        excl = np.nonzero(obs[u])[0] if u % 2 else None
        reqs[srv.submit(user=u, exclude=excl)] = ("warm", u, excl)
    reqs[srv.submit(features=F[n_warm], k=3)] = ("cold", n_warm, None)
    done = {r["id"]: r for r in srv.run()}
    assert len(done) == len(reqs)
    assert sess.load_count == warm_loads     # zero loads while serving
    for rid, (kind, u, excl) in reqs.items():
        if kind == "warm":
            seq = sess.recommend(user=u, k=5,
                                 exclude=None if excl is None
                                 else [excl])
        else:
            seq = sess.recommend(features=F[u:u + 1], k=3)
        np.testing.assert_array_equal(done[rid]["ids"], seq.ids[0])
        np.testing.assert_array_equal(done[rid]["mean"], seq.mean[0])
        np.testing.assert_array_equal(done[rid]["std"], seq.std[0])
        assert done[rid]["t_done"] >= done[rid]["t_submit"]


def test_recommend_server_refuses_over_budget_store(store):
    d, _, _, _ = store
    with pytest.raises(ValueError, match="resident"):
        RecommendServer(PredictSession(d, cache_bytes=0))


def test_recommend_server_request_validation(store):
    d, F, _, _ = store
    srv = RecommendServer(PredictSession(d))
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit(user=0, features=F[0])
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit()
    with pytest.raises(ValueError, match="one .D,. row"):
        srv.submit(features=F[:2])


# -- the shared slot runtime ----------------------------------------------

class _EchoServer(SlotServer):
    """Trivial service: each step completes every active request."""

    def submit(self, payload, req_id=None):
        return self._enqueue({"payload": payload}, req_id)

    def step(self):
        for s, req in enumerate(self.active):
            if req is not None:
                req["echo"] = req["payload"]
                self._finish(s)


def test_slot_ids_monotonic_across_queue_drains():
    """The original bug: ``r{len(queue)}`` reused ids once the queue
    drained; ids must never repeat across a server's lifetime."""
    srv = _EchoServer(slots=2)
    a = srv.submit("x")
    srv.run()
    b = srv.submit("y")                 # queue drained in between
    srv.run()
    assert a != b
    assert len({r["id"] for r in srv.done}) == 2


def test_slot_duplicate_explicit_id_raises_naming_clash():
    srv = _EchoServer(slots=2)
    srv.submit("x", req_id="dup")
    with pytest.raises(ValueError, match="'dup'"):
        srv.submit("y", req_id="dup")
    srv.run()
    srv.submit("z", req_id="dup")       # reusable once completed
    assert len(srv.run()) == 2


def test_slot_server_more_requests_than_slots():
    srv = _EchoServer(slots=2)
    ids = [srv.submit(i) for i in range(7)]
    done = srv.run()
    assert [r["id"] for r in done] == ids      # FIFO admission
    assert [r["echo"] for r in done] == list(range(7))

"""The batched Gibbs sweep vs a slow loop-based reference sampler.

The paper's validation is "all implementations produce the same
predictive performance".  Ours is stronger where possible: with the
noise fixed and the same conditioning values, the *conditional
distribution parameters* (posterior precision and mean of each row)
from the batched padded-bucket path must equal a dense per-row Python
loop exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FixedGaussian, MFData, ModelDef, BlockDef,
                        EntityDef, NormalPrior, from_coo)
from repro.core.gibbs import _sparse_contrib


def test_batched_gram_equals_per_row_loop():
    rng = np.random.default_rng(0)
    n, m, K, nnz = 40, 25, 5, 300
    flat = rng.choice(n * m, size=nnz, replace=False)
    i, j = np.divmod(flat, m)
    v = rng.normal(size=nnz).astype(np.float32)
    mat = from_coo(i, j, v, (n, m))
    V = rng.normal(size=(m, K)).astype(np.float32)
    U = rng.normal(size=(n, K)).astype(np.float32)
    alpha = 5.0

    noise = FixedGaussian(alpha)
    model = ModelDef(
        (EntityDef("rows", n, NormalPrior(K)),
         EntityDef("cols", m, NormalPrior(K))),
        (BlockDef(0, 1, noise, sparse=True),), K, False)
    gram, rhs = _sparse_contrib(model, mat, True, jnp.asarray(V),
                                jnp.asarray(U), noise, noise.init(),
                                jax.random.PRNGKey(0))

    # slow reference: explicit per-row loops over the COO triplets
    for r in range(n):
        sel = i == r
        vs = V[j[sel]]                        # (nnz_r, K)
        g_ref = alpha * (vs.T @ vs)
        b_ref = alpha * (v[sel] @ vs)
        np.testing.assert_allclose(np.asarray(gram[r]), g_ref,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(rhs[r]), b_ref,
                                   rtol=1e-4, atol=1e-4)


def test_posterior_mean_equals_ridge_solution():
    """With Lambda_p = I, mu_p = 0 and no sampled noise, the factor
    conditional mean is the ridge regression solution per row."""
    rng = np.random.default_rng(1)
    n, m, K = 30, 20, 4
    flat = rng.choice(n * m, size=200, replace=False)
    i, j = np.divmod(flat, m)
    v = rng.normal(size=200).astype(np.float32)
    mat = from_coo(i, j, v, (n, m))
    V = rng.normal(size=(m, K)).astype(np.float32)
    alpha = 2.0

    noise = FixedGaussian(alpha)
    model = ModelDef(
        (EntityDef("rows", n, NormalPrior(K)),
         EntityDef("cols", m, NormalPrior(K))),
        (BlockDef(0, 1, noise, sparse=True),), K, False)
    gram, rhs = _sparse_contrib(model, mat, True, jnp.asarray(V),
                                jnp.zeros((n, K)), noise, noise.init(),
                                jax.random.PRNGKey(0))
    for r in range(n):
        sel = i == r
        vs = V[j[sel]]
        A = alpha * (vs.T @ vs) + np.eye(K, dtype=np.float32)
        b = alpha * (v[sel] @ vs)
        mean_ref = np.linalg.solve(A, b)
        A_b = np.asarray(gram[r]) + np.eye(K, dtype=np.float32)
        mean_batched = np.linalg.solve(A_b, np.asarray(rhs[r]))
        np.testing.assert_allclose(mean_batched, mean_ref,
                                   rtol=1e-3, atol=1e-4)

"""Padded-bucket sparse matrix: round-trips + invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container without dev deps — see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import from_coo, from_dense
from repro.core.sparse import gather_predict


def _random_coo(rng, n, m, nnz):
    flat = rng.choice(n * m, size=nnz, replace=False)
    i, j = np.divmod(flat, m)
    v = rng.normal(size=nnz).astype(np.float32)
    return i.astype(np.int64), j.astype(np.int64), v


def test_from_coo_roundtrip():
    rng = np.random.default_rng(0)
    i, j, v = _random_coo(rng, 50, 30, 200)
    mat = from_coo(i, j, v, (50, 30))
    assert mat.shape == (50, 30)
    assert float(mat.nnz) == 200
    # dense reconstruction from the row orientation
    dense = np.zeros((50, 30), np.float32)
    ridx = np.asarray(mat.rows.idx)
    rval = np.asarray(mat.rows.val)
    rmask = np.asarray(mat.rows.mask)
    for r in range(50):
        for t in range(mat.rows.max_nnz):
            if rmask[r, t]:
                dense[r, ridx[r, t]] = rval[r, t]
    expect = np.zeros((50, 30), np.float32)
    expect[i, j] = v
    np.testing.assert_allclose(dense, expect)
    # col orientation agrees
    dense_c = np.zeros((50, 30), np.float32)
    cidx = np.asarray(mat.cols.idx)
    cval = np.asarray(mat.cols.val)
    cmask = np.asarray(mat.cols.mask)
    for c in range(30):
        for t in range(mat.cols.max_nnz):
            if cmask[c, t]:
                dense_c[cidx[c, t], c] = cval[c, t]
    np.testing.assert_allclose(dense_c, expect)


def test_transpose():
    rng = np.random.default_rng(1)
    i, j, v = _random_coo(rng, 20, 40, 100)
    mat = from_coo(i, j, v, (20, 40))
    t = mat.transpose()
    assert t.shape == (40, 20)
    assert t.rows.max_nnz == mat.cols.max_nnz
    np.testing.assert_allclose(np.asarray(t.rows.val),
                               np.asarray(mat.cols.val))


def test_with_coo_values_rebuilds_both_orientations():
    rng = np.random.default_rng(2)
    i, j, v = _random_coo(rng, 25, 15, 80)
    mat = from_coo(i, j, v, (25, 15))
    new_v = rng.normal(size=mat.coo_v.shape).astype(np.float32)
    m2 = mat.with_coo_values(jnp.asarray(new_v))
    # check a handful of entries in both orientations
    expect = np.zeros((25, 15), np.float32)
    expect[i, j] = (new_v * np.asarray(mat.coo_mask))[:len(i)]
    got_r = np.zeros_like(expect)
    ridx, rval, rmask = (np.asarray(m2.rows.idx), np.asarray(m2.rows.val),
                         np.asarray(m2.rows.mask))
    for r in range(25):
        for t in range(m2.rows.max_nnz):
            if rmask[r, t]:
                got_r[r, ridx[r, t]] = rval[r, t]
    np.testing.assert_allclose(got_r, expect)
    got_c = np.zeros_like(expect)
    cidx, cval, cmask = (np.asarray(m2.cols.idx), np.asarray(m2.cols.val),
                         np.asarray(m2.cols.mask))
    for c in range(15):
        for t in range(m2.cols.max_nnz):
            if cmask[c, t]:
                got_c[cidx[c, t], c] = cval[c, t]
    np.testing.assert_allclose(got_c, expect)


def test_from_dense_keep_zeros_vs_not():
    R = np.array([[1.0, 0.0], [0.0, 2.0]], np.float32)
    sparse = from_dense(R)                      # zeros are unknowns
    dense = from_dense(R, keep_zeros=True)      # zeros are data
    assert float(sparse.nnz) == 2
    assert float(dense.nnz) == 4


def test_row_too_wide_raises():
    i = np.zeros(10, np.int64)          # all in row 0
    j = np.arange(10, dtype=np.int64)
    v = np.ones(10, np.float32)
    with pytest.raises(ValueError):
        from_coo(i, j, v, (4, 16), max_nnz_row=4)


def test_gather_predict():
    rng = np.random.default_rng(3)
    U = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    V = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    i = jnp.asarray([0, 3, 9])
    j = jnp.asarray([1, 1, 7])
    out = gather_predict(U, V, i, j)
    expect = np.einsum("ek,ek->e", np.asarray(U)[np.asarray(i)],
                       np.asarray(V)[np.asarray(j)])
    np.testing.assert_allclose(out, expect, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.integers(2, 30), st.integers(0, 2**31 - 1),
       st.integers(1, 16))
def test_padding_round_to_invariance(n, m, seed, round_to):
    """The padded width never changes values, only layout."""
    rng = np.random.default_rng(seed)
    nnz = min(n * m - 1, max(1, (n * m) // 3))
    i, j, v = _random_coo(rng, n, m, nnz)
    a = from_coo(i, j, v, (n, m), round_to=1)
    b = from_coo(i, j, v, (n, m), round_to=round_to)
    assert float(a.nnz) == float(b.nnz) == nnz
    assert b.rows.max_nnz % round_to == 0
    # row sums are layout-independent
    np.testing.assert_allclose(
        np.asarray((a.rows.val * a.rows.mask).sum(axis=1)),
        np.asarray((b.rows.val * b.rows.mask).sum(axis=1)), rtol=1e-6)

# repro-lint: treat-as=core/gibbs.py
"""Suppression comments silence findings line by line — this file
must produce ZERO findings despite containing rule violations."""
import time

import jax


def legacy_draw(key, n):
    return jax.random.normal(key, (n, 4))  # repro-lint: disable=batch-rng-in-sweep-path


def timed_draw(key, n):
    # repro-lint: disable=all
    t0 = time.time()
    return t0, legacy_draw(key, n)

"""Seeded violations: version-gated imports outside compat.py."""
from jax.experimental import pallas  # expect: experimental-import-outside-compat
from jax.experimental.shard_map import shard_map  # expect: experimental-import-outside-compat
import jax._src.mesh  # expect: experimental-import-outside-compat

__all__ = ["pallas", "shard_map", "jax"]

"""Seeded violation: registry lookup error hiding the choices."""
_SAMPLERS = {"gibbs": object, "sgld": object}


def resolve(name):
    if name not in _SAMPLERS:
        raise ValueError(f"unknown sampler {name!r}")  # expect: registry-error-without-choices
    return _SAMPLERS[name]


def resolve_ok(name):
    """Names the choices -> must not be flagged."""
    if name not in _SAMPLERS:
        raise ValueError(
            f"unknown sampler {name!r}; valid samplers: "
            f"{', '.join(sorted(_SAMPLERS))}")
    return _SAMPLERS[name]


def resolve_ok_helper_line(name):
    """Choices formatted on a helper line -> must not be flagged."""
    if name not in _SAMPLERS:
        known = ", ".join(sorted(_SAMPLERS))
        raise ValueError(f"unknown sampler {name!r}; try: {known}")
    return _SAMPLERS[name]

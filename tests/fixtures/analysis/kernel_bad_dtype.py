# repro-lint: treat-as=kernels/fixture.py
"""Seeded violations: a contraction with no
``preferred_element_type=jnp.float32`` (the MXU will accumulate bf16
inputs in bf16) and a bf16 OUTPUT used as the across-grid accumulator
(every partial sum rounds to bf16).  The race discipline itself is
correct here — only the dtypes are wrong."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import KernelProbe, KernelSpec


def _bf16_acc_kernel(x_ref, y_ref, o_ref):
    t = pl.program_id(1)
    part = jax.lax.dot_general(  # expect: kernel-accum-dtype
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())))

    @pl.when(t == 0)
    def _init():
        o_ref[...] = part.astype(o_ref.dtype)  # expect: kernel-accum-dtype

    @pl.when(t != 0)
    def _acc():
        o_ref[...] += part.astype(o_ref.dtype)


def bf16_gram(x, y, *, block_r=8, block_t=128):
    R, T = x.shape
    return pl.pallas_call(
        _bf16_acc_kernel,
        grid=(R // block_r, T // block_t),
        in_specs=[
            pl.BlockSpec((block_r, block_t), lambda r, t: (r, t)),
            pl.BlockSpec((block_r, block_t),
                         lambda r, t: (r, t)),
        ],
        out_specs=pl.BlockSpec((block_r, block_r), lambda r, t: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, block_r), jnp.bfloat16),
        interpret=True,
    )(x, y)


KERNELS = {
    "bf16_gram": KernelSpec(
        "bf16_gram",
        probes=(
            KernelProbe(
                "bf16 r8 t256",
                (jax.ShapeDtypeStruct((8, 256), jnp.bfloat16),
                 jax.ShapeDtypeStruct((8, 256), jnp.bfloat16)),
                bf16_gram),
        ),
        vmem_budget=4 << 20),
}

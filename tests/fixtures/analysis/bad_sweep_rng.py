# repro-lint: treat-as=core/gibbs.py
"""Seeded violations: batch-shaped draws on the sweep path.

Each flagged line carries an expect-marker comment read by
tests/test_analysis.py; the whitelisted function below it must NOT
be flagged.
"""
import jax


def sample_block(key, n_rows, num_latent):
    eps = jax.random.normal(key, (n_rows, num_latent))  # expect: batch-rng-in-sweep-path
    u = jax.random.uniform(key, (n_rows,))  # expect: batch-rng-in-sweep-path
    s = jax.random.bernoulli(key, 0.5, (n_rows,))  # expect: batch-rng-in-sweep-path
    return eps, u, s


def init_state(key, n_rows):
    # whitelisted: pre-sweep init runs once with a replicated key
    return jax.random.normal(key, (n_rows, 4))

# repro-lint: treat-as=kernels/fixture.py
"""Seeded violation: a block configuration whose per-grid-step
resident bytes (double-buffered tiles) dwarf the kernel's VMEM
budget.  One (64, 4096, 128) f32 input tile is ~134 MB — it compiles
fine in interpret mode and OOMs only on real TPU hardware, which is
exactly why the checker estimates it statically."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import KernelProbe, KernelSpec


def _mean_kernel(v_ref, o_ref):
    o_ref[...] = jnp.mean(v_ref[...], axis=(0, 1))


def whole_stack_mean(v):
    S, N, K = v.shape
    return pl.pallas_call(  # expect: kernel-vmem-budget
        _mean_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((S, N, K), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((K,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((K,), jnp.float32),
        interpret=True,
    )(v)


KERNELS = {
    "whole_stack_mean": KernelSpec(
        "whole_stack_mean",
        probes=(
            KernelProbe(
                "whole catalogue resident s64 n4096 K128",
                (jax.ShapeDtypeStruct((64, 4096, 128), jnp.float32),),
                whole_stack_mean),
        ),
        vmem_budget=8 << 20),
}

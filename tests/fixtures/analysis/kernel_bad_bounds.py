# repro-lint: treat-as=kernels/fixture.py
"""Seeded violation: ceil-grid over an UNPADDED operand.  The wrapper
computes a ceiling grid but never routes the operand through
``ops.pad_to_blocks``, so the last grid point's block hangs off the
end of the array — the uneven-tail bug the shared padding helper
exists to prevent."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import KernelProbe, KernelSpec


def _scale_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def scale_unpadded(x, *, block_e=512):
    E = x.shape[0]
    grid = ((E + block_e - 1) // block_e,)      # ceil — but no pad!
    return pl.pallas_call(
        _scale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda e: (e,)),  # expect: kernel-block-out-of-bounds
        ],
        out_specs=pl.BlockSpec(
            (block_e,), lambda e: (e,)),  # expect: kernel-block-out-of-bounds
        out_shape=jax.ShapeDtypeStruct((E,), jnp.float32),
        interpret=True,
    )(x)


KERNELS = {
    "scale_unpadded": KernelSpec(
        "scale_unpadded",
        probes=(
            KernelProbe(
                "uneven tail e1030",
                (jax.ShapeDtypeStruct((1030,), jnp.float32),),
                scale_unpadded),
        ),
        vmem_budget=4 << 20),
}

# repro-lint: treat-as=core/sampler_utils.py
"""Seeded violations: wall-clock / global-RNG nondeterminism in
core/; the explicitly seeded generator must NOT be flagged."""
import time

import numpy as np


def jitter():
    t = time.time()  # expect: nondeterminism-in-core
    r = np.random.rand(4)  # expect: nondeterminism-in-core
    g = np.random.default_rng()  # expect: nondeterminism-in-core
    ok = np.random.default_rng(0)
    return t, r, g, ok

# repro-lint: treat-as=launch/bench_loop.py
"""Seeded violations: ad-hoc wall-clock timing outside repro/obs.

``time.sleep`` is pacing, not a clock READ, and must NOT be flagged;
neither must the sanctioned ``obs.clock`` calls.
"""
import time
from time import perf_counter
from time import monotonic as mono

from repro.obs import clock


def drive(requests):
    t0 = time.perf_counter()  # expect: timing-outside-obs
    lat = []
    for r in requests:
        start = mono()  # expect: timing-outside-obs
        r()
        lat.append(perf_counter() - start)  # expect: timing-outside-obs
        time.sleep(0.001)
    wall = time.perf_counter() - t0  # expect: timing-outside-obs
    ok = clock.perf_counter()
    allowed = time.perf_counter()  # repro-lint: disable=timing-outside-obs
    return lat, wall, ok, allowed

# repro-lint: treat-as=launch/serve.py
"""Seeded violations: checkpoint loads on serving request paths.

Construction-time loads (``__init__`` / ``warm*``) are the allowed
pattern and must NOT be flagged.
"""


class LeakyServer:
    def __init__(self, session):
        self.session = session
        self.cache = session.warm_cache()       # construction: fine

    def warm_extra(self, step):
        return self.session.load_sample(step)   # warm*-prefixed: fine

    def step(self):
        st = self.session.load_sample(0)  # expect: checkpoint-load-in-serving-request-path
        for s in self.session.samples():  # expect: checkpoint-load-in-serving-request-path
            st = s
        return st

    def resume(self, template, path):
        from repro.checkpoint.ckpt import load_pytree
        return load_pytree(template, path)  # expect: checkpoint-load-in-serving-request-path

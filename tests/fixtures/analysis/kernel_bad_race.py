# repro-lint: treat-as=kernels/fixture.py
"""Seeded violation: revisit-accumulate output with NO first-visit
init guard.  The out spec maps every t to the same (r,) block, so the
+= below reads uninitialized VMEM at t == 0 — the bug class
kernels/gram.py's ``@pl.when(t == 0)`` pattern exists to prevent."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import KernelProbe, KernelSpec


def _racy_kernel(x_ref, o_ref):
    t = pl.program_id(1)
    del t                                       # never used as a guard
    o_ref[...] += jnp.sum(x_ref[...], axis=1)  # expect: kernel-output-race


def racy_rowsum(x, *, block_rows=4, block_t=128):
    R, T = x.shape
    return pl.pallas_call(
        _racy_kernel,
        grid=(R // block_rows, T // block_t),
        in_specs=[
            pl.BlockSpec((block_rows, block_t), lambda r, t: (r, t)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda r, t: (r,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        interpret=True,
    )(x)


KERNELS = {
    "racy_rowsum": KernelSpec(
        "racy_rowsum",
        probes=(
            KernelProbe(
                "r8 t256",
                (jax.ShapeDtypeStruct((8, 256), jnp.float32),),
                racy_rowsum),
        ),
        vmem_budget=4 << 20),
}

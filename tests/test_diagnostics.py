"""Convergence diagnostics (core/diagnostics.py) against a hand-rolled
numpy oracle.

The oracle recomputes split-R-hat and bulk-ESS from the Vehtari et al.
(2021) formulas with deliberately DIFFERENT numerics than the module:
the inverse normal CDF via bisection on ``math.erf`` (the module uses
Acklam's rational approximation), tie-averaged ranks via an explicit
sorted-group walk (the module uses ``np.unique``/``np.add.at``), and
per-chain autocovariances via explicit double loops (the module uses
``np.correlate``).  Agreement therefore pins the ESTIMATOR, not one
implementation against itself.

Behavioral pins: iid chains pass the gate, a mean-shifted chain and a
single non-stationary chain fail it, strong autocorrelation slashes
ESS, and degenerate inputs (short, constant, non-finite) return nan
rather than a misleading number.
"""
import math

import numpy as np
import pytest

from repro.core.diagnostics import (DEFAULT_RHAT_THRESHOLD, Diagnostics,
                                    MIN_DRAWS, _ndtri, bulk_ess,
                                    compute_diagnostics, ess,
                                    load_diagnostics, rank_normalize,
                                    save_diagnostics, split_chains,
                                    split_rhat)


# ---------------------------------------------------------------------------
# the oracle
# ---------------------------------------------------------------------------

def _oracle_phi_inv(p: float) -> float:
    """Invert Phi by bisection on erf — no shared code with _ndtri."""
    lo, hi = -12.0, 12.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if 0.5 * (1.0 + math.erf(mid / math.sqrt(2.0))) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _oracle_split(x: np.ndarray) -> np.ndarray:
    half = x.shape[1] // 2
    return np.vstack([x[:, :half], x[:, x.shape[1] - half:]])


def _oracle_rhat(x: np.ndarray) -> float:
    z = _oracle_split(np.asarray(x, np.float64))
    m, n = z.shape
    w = float(np.mean([np.var(z[c], ddof=1) for c in range(m)]))
    b = n * float(np.var([z[c].mean() for c in range(m)], ddof=1))
    var_hat = (n - 1) / n * w + b / n
    return math.sqrt(var_hat / w)


def _oracle_rank_normalize(x: np.ndarray) -> np.ndarray:
    flat = np.asarray(x, np.float64).ravel()
    s = flat.size
    order = np.argsort(flat, kind="mergesort")
    srt = flat[order]
    rr = np.empty(s)
    i = 0
    while i < s:          # walk tie groups in sorted order
        j = i
        while j + 1 < s and srt[j + 1] == srt[i]:
            j += 1
        rr[i:j + 1] = 0.5 * (i + j) + 1.0   # average 1-based rank
        i = j + 1
    ranks = np.empty(s)
    ranks[order] = rr
    z = np.array([_oracle_phi_inv((r - 0.375) / (s + 0.25))
                  for r in ranks])
    return z.reshape(x.shape)


def _oracle_ess(z: np.ndarray) -> float:
    """ESS of already-prepared draws, explicit-loop autocovariances."""
    m, n = z.shape
    acov = np.zeros((m, n))
    for c in range(m):
        mu = z[c].mean()
        for t in range(n):
            acc = 0.0
            for i in range(n - t):
                acc += (z[c, i] - mu) * (z[c, i + t] - mu)
            acov[c, t] = acc / n
    w = float(np.mean(acov[:, 0] * n / (n - 1.0)))
    b_over_n = float(np.var(z.mean(axis=1), ddof=1)) if m > 1 else 0.0
    var_hat = (n - 1.0) / n * w + b_over_n
    rho = 1.0 - (w - acov.mean(axis=0)) / var_hat
    pair_sums = []
    prev = math.inf
    t = 0
    while 2 * t + 1 < n:
        p = rho[2 * t] + rho[2 * t + 1]
        if p < 0.0:
            break
        p = min(p, prev)
        pair_sums.append(p)
        prev = p
        t += 1
    tau = -rho[0] + 2.0 * sum(pair_sums) if pair_sums else 1.0
    tau = max(tau, 1.0 / math.log10(max(m * n, 10)))
    return m * n / tau


def _oracle_bulk_ess(x: np.ndarray) -> float:
    return _oracle_ess(_oracle_rank_normalize(_oracle_split(
        np.asarray(x, np.float64))))


def _chains(seed, c=4, n=60, phi=0.0, shift=None):
    """AR(1) chains; ``shift[c]`` offsets chain c's mean."""
    rng = np.random.default_rng(seed)
    x = np.zeros((c, n))
    eps = rng.normal(size=(c, n))
    for t in range(n):
        x[:, t] = (phi * x[:, t - 1] if t else 0.0) + eps[:, t]
    if shift is not None:
        x += np.asarray(shift)[:, None]
    return x


# ---------------------------------------------------------------------------
# oracle agreement
# ---------------------------------------------------------------------------

def test_ndtri_matches_erf_bisection():
    p = np.concatenate([np.array([1e-9, 1e-6, 0.02, 0.024, 0.025]),
                        np.linspace(0.03, 0.97, 41),
                        np.array([0.975, 0.976, 0.98, 1 - 1e-6])])
    got = _ndtri(p)
    want = np.array([_oracle_phi_inv(v) for v in p])
    assert np.max(np.abs(got - want)) < 1e-7


@pytest.mark.parametrize("n", [25, 60])   # odd n drops the middle draw
@pytest.mark.parametrize("phi", [0.0, 0.7])
def test_split_rhat_matches_oracle(n, phi):
    x = _chains(1, n=n, phi=phi)
    assert split_rhat(x) == pytest.approx(_oracle_rhat(x), rel=1e-12)
    shifted = _chains(2, n=n, phi=phi, shift=[0, 0, 0, 3.0])
    assert split_rhat(shifted) == pytest.approx(_oracle_rhat(shifted),
                                                rel=1e-12)


def test_split_chains_layout():
    x = np.arange(10, dtype=float).reshape(2, 5)
    z = split_chains(x)
    # odd length: middle draw dropped, first/second halves stacked
    assert z.shape == (4, 2)
    assert np.array_equal(z, [[0, 1], [5, 6], [3, 4], [8, 9]])


def test_rank_normalize_matches_oracle_and_averages_ties():
    x = _chains(3, c=2, n=20)
    x[0, 3] = x[1, 7] = x[0, 11]          # seed a 3-way tie
    got = rank_normalize(x)
    want = _oracle_rank_normalize(x)
    assert np.max(np.abs(got - want)) < 1e-7
    tied = got[[0, 1, 0], [3, 7, 11]]
    assert tied[0] == tied[1] == tied[2]


@pytest.mark.parametrize("phi", [0.0, 0.5, 0.9])
def test_bulk_ess_matches_oracle(phi):
    x = _chains(4, c=3, n=50, phi=phi)
    assert bulk_ess(x) == pytest.approx(_oracle_bulk_ess(x), rel=1e-6)


def test_ess_matches_oracle_without_rank_normalization():
    x = _chains(5, c=2, n=40, phi=0.6)
    assert ess(x) == pytest.approx(_oracle_ess(
        np.asarray(x, np.float64)), rel=1e-6)


# ---------------------------------------------------------------------------
# behavioral pins
# ---------------------------------------------------------------------------

def test_iid_chains_pass_and_mixing_failures_flag():
    iid = _chains(6, c=4, n=250)
    assert abs(split_rhat(iid) - 1.0) < 0.02
    assert bulk_ess(iid) > 0.5 * iid.size
    # one chain sampling a different mean: R-hat blows up, ESS craters
    bad = _chains(7, c=4, n=250, shift=[0, 0, 0, 5.0])
    assert split_rhat(bad) > 1.5
    assert bulk_ess(bad) < 0.1 * bad.size
    # a single drifting chain flags ITSELF through the split
    drift = np.linspace(0.0, 5.0, 200)[None, :] + _chains(8, c=1, n=200)
    assert split_rhat(drift) > 1.5


def test_autocorrelation_slashes_ess():
    fast = bulk_ess(_chains(9, c=4, n=200, phi=0.0))
    slow = bulk_ess(_chains(9, c=4, n=200, phi=0.9))
    # AR(1) theory: ESS ratio ~ (1-phi)/(1+phi) = 1/19
    assert slow < 0.25 * fast


def test_degenerate_inputs_return_nan_not_lies():
    short = np.zeros((2, MIN_DRAWS - 1))
    assert math.isnan(split_rhat(short))
    assert math.isnan(bulk_ess(short))
    nonfinite = _chains(10, c=2, n=20)
    nonfinite[1, 5] = np.nan
    assert math.isnan(split_rhat(nonfinite))
    assert math.isnan(bulk_ess(nonfinite))
    # identical constants: converged by definition; differing
    # constants: undefined -> nan (and the gate flags nan)
    assert split_rhat(np.full((3, 20), 2.5)) == 1.0
    assert math.isnan(bulk_ess(np.full((3, 20), 2.5)))
    two_consts = np.vstack([np.zeros(20), np.ones(20)])
    assert math.isnan(split_rhat(two_consts))


def test_diagnostics_gate_and_roundtrip(tmp_path):
    traces = {"rmse": _chains(11, c=4, n=40),
              "alpha": _chains(12, c=4, n=40, shift=[0, 0, 0, 9.0])}
    d = compute_diagnostics(traces)
    assert d.n_chains == 4 and d.n_draws == 40
    assert set(d.rhat) == set(d.ess) == {"rmse", "alpha"}
    failing = d.failing(DEFAULT_RHAT_THRESHOLD)
    assert "alpha" in failing
    assert not d.converged()
    assert d.converged(threshold=float(d.max_rhat))
    # nan R-hat is never convergence evidence
    d2 = Diagnostics(n_chains=2, n_draws=10,
                     rhat={"x": float("nan")}, ess={"x": float("nan")})
    assert "x" in d2.failing(1e9)
    assert not Diagnostics(2, 10).converged()   # no quantities at all

    save_diagnostics(str(tmp_path), d)
    back = load_diagnostics(str(tmp_path))
    assert back.n_chains == d.n_chains and back.n_draws == d.n_draws
    for k in d.rhat:
        assert back.rhat[k] == pytest.approx(d.rhat[k])
        assert back.ess[k] == pytest.approx(d.ess[k])
    assert load_diagnostics(str(tmp_path / "nope")) is None


def test_bad_shape_rejected():
    with pytest.raises(ValueError, match="chains, draws"):
        split_rhat(np.zeros((2, 3, 4)))

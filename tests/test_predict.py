"""Posterior-predictive evaluation: the AUC tie handling regression.

Rank-sum AUC with raw ``argsort`` ranks assigns tied predictions an
arbitrary input-order permutation; on discrete/probit outputs (where
ties are the common case) that biases the statistic by up to the tied
mass.  ``predict.auc`` uses MIDRANKS: every tied positive/negative
pair contributes exactly 1/2, matching the trapezoidal ROC area and
the pairwise definition

    AUC = ( #(p_pos > p_neg) + 0.5 #(p_pos == p_neg) ) / (n_pos n_neg)

which is the brute-force oracle used below.
"""
import numpy as np

from repro.core.predict import auc


def _auc_pairwise(pred, truth, threshold=0.5):
    """O(n^2) oracle: pairwise wins + half-credit for ties."""
    pos = np.asarray(truth) > threshold
    p, n = pred[pos], pred[~pos]
    wins = (p[:, None] > n[None, :]).sum()
    ties = (p[:, None] == n[None, :]).sum()
    return (wins + 0.5 * ties) / (len(p) * len(n))


def test_auc_all_tied_is_half():
    """Constant predictions carry no information: AUC must be exactly
    0.5, not an artifact of the argsort permutation."""
    truth = np.array([1, 0, 1, 0, 0, 1, 0, 1], np.float32)
    pred = np.zeros_like(truth)
    assert auc(pred, truth) == 0.5


def test_auc_heavy_ties_matches_pairwise_oracle():
    """Probit-style discrete predictions (few distinct values, heavy
    ties) agree with the brute-force pairwise definition."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n = int(rng.integers(10, 120))
        # few distinct levels -> most comparisons are ties
        pred = rng.integers(0, 4, size=n).astype(np.float32) / 4.0
        truth = (rng.random(n) < 0.5).astype(np.float32)
        if truth.min() == truth.max():
            continue
        np.testing.assert_allclose(auc(pred, truth),
                                   _auc_pairwise(pred, truth),
                                   atol=1e-12)


def test_auc_tie_free_unchanged():
    """Without ties the midrank formula reduces to the classic
    rank-sum statistic."""
    rng = np.random.default_rng(1)
    pred = rng.permutation(np.linspace(0.0, 1.0, 50)).astype(np.float32)
    truth = (rng.random(50) < 0.4).astype(np.float32)
    np.testing.assert_allclose(auc(pred, truth),
                               _auc_pairwise(pred, truth), atol=1e-12)


def test_auc_input_order_invariant_under_ties():
    """The regression itself: permuting tied entries must not move the
    AUC (raw argsort ranks did)."""
    pred = np.array([0.2, 0.2, 0.2, 0.8, 0.8, 0.8], np.float32)
    truth = np.array([1, 0, 0, 1, 1, 0], np.float32)
    base = auc(pred, truth)
    rng = np.random.default_rng(2)
    for _ in range(10):
        perm = rng.permutation(len(pred))
        assert auc(pred[perm], truth[perm]) == base


def test_auc_degenerate_classes_nan():
    assert np.isnan(auc(np.array([0.1, 0.9]), np.array([1.0, 1.0])))
    assert np.isnan(auc(np.array([0.1, 0.9]), np.array([0.0, 0.0])))


# -- PredictAccumulator posterior variance --------------------------------

def test_accumulator_var_matches_moment_oracle():
    """``var == E[p^2] - E[p]^2`` with both moments over the
    accumulated POSTERIOR SAMPLES — the posterior-predictive spread of
    the per-sample predictions, pinned against a hand-rolled oracle —
    and ``std`` is its square root (the serving uncertainty field)."""
    from repro.core.predict import PredictAccumulator, make_test_set

    rng = np.random.default_rng(1)
    n_rows, n_latent, n_cells, n_samp = 12, 4, 30, 7
    i = rng.integers(0, n_rows, n_cells)
    j = rng.integers(0, n_rows, n_cells)
    acc = PredictAccumulator(
        make_test_set(i, j, np.zeros(n_cells, np.float32)))
    preds = []
    for _ in range(n_samp):
        U = rng.normal(size=(n_rows, n_latent)).astype(np.float32)
        V = rng.normal(size=(n_rows, n_latent)).astype(np.float32)
        acc.update(U, V)
        preds.append((U[i] * V[j]).sum(axis=1))
    P = np.stack(preds)                       # (S, E) oracle samples
    mean_o = P.mean(axis=0)
    var_o = np.maximum((P * P).mean(axis=0) - mean_o ** 2, 0.0)
    np.testing.assert_allclose(acc.mean, mean_o, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(acc.var, var_o, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(acc.std, np.sqrt(var_o),
                               rtol=1e-4, atol=1e-5)


def test_accumulator_var_does_not_shrink_with_n():
    """The var is the spread OVER samples, not a mean-estimator error
    bar: feeding the same two alternating samples many times keeps the
    variance fixed instead of shrinking it by 1/n."""
    from repro.core.predict import PredictAccumulator, make_test_set

    rng = np.random.default_rng(2)
    U0 = rng.normal(size=(4, 3)).astype(np.float32)
    U1 = rng.normal(size=(4, 3)).astype(np.float32)
    V = rng.normal(size=(4, 3)).astype(np.float32)
    test = make_test_set([0, 1], [2, 3], np.zeros(2, np.float32))

    def spread(reps):
        acc = PredictAccumulator(test)
        for _ in range(reps):
            acc.update(U0, V)
            acc.update(U1, V)
        return np.asarray(acc.var)

    np.testing.assert_allclose(spread(1), spread(20),
                               rtol=1e-5, atol=1e-7)

"""Posterior-predictive evaluation: the AUC tie handling regression.

Rank-sum AUC with raw ``argsort`` ranks assigns tied predictions an
arbitrary input-order permutation; on discrete/probit outputs (where
ties are the common case) that biases the statistic by up to the tied
mass.  ``predict.auc`` uses MIDRANKS: every tied positive/negative
pair contributes exactly 1/2, matching the trapezoidal ROC area and
the pairwise definition

    AUC = ( #(p_pos > p_neg) + 0.5 #(p_pos == p_neg) ) / (n_pos n_neg)

which is the brute-force oracle used below.
"""
import numpy as np

from repro.core.predict import auc


def _auc_pairwise(pred, truth, threshold=0.5):
    """O(n^2) oracle: pairwise wins + half-credit for ties."""
    pos = np.asarray(truth) > threshold
    p, n = pred[pos], pred[~pos]
    wins = (p[:, None] > n[None, :]).sum()
    ties = (p[:, None] == n[None, :]).sum()
    return (wins + 0.5 * ties) / (len(p) * len(n))


def test_auc_all_tied_is_half():
    """Constant predictions carry no information: AUC must be exactly
    0.5, not an artifact of the argsort permutation."""
    truth = np.array([1, 0, 1, 0, 0, 1, 0, 1], np.float32)
    pred = np.zeros_like(truth)
    assert auc(pred, truth) == 0.5


def test_auc_heavy_ties_matches_pairwise_oracle():
    """Probit-style discrete predictions (few distinct values, heavy
    ties) agree with the brute-force pairwise definition."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n = int(rng.integers(10, 120))
        # few distinct levels -> most comparisons are ties
        pred = rng.integers(0, 4, size=n).astype(np.float32) / 4.0
        truth = (rng.random(n) < 0.5).astype(np.float32)
        if truth.min() == truth.max():
            continue
        np.testing.assert_allclose(auc(pred, truth),
                                   _auc_pairwise(pred, truth),
                                   atol=1e-12)


def test_auc_tie_free_unchanged():
    """Without ties the midrank formula reduces to the classic
    rank-sum statistic."""
    rng = np.random.default_rng(1)
    pred = rng.permutation(np.linspace(0.0, 1.0, 50)).astype(np.float32)
    truth = (rng.random(50) < 0.4).astype(np.float32)
    np.testing.assert_allclose(auc(pred, truth),
                               _auc_pairwise(pred, truth), atol=1e-12)


def test_auc_input_order_invariant_under_ties():
    """The regression itself: permuting tied entries must not move the
    AUC (raw argsort ranks did)."""
    pred = np.array([0.2, 0.2, 0.2, 0.8, 0.8, 0.8], np.float32)
    truth = np.array([1, 0, 0, 1, 1, 0], np.float32)
    base = auc(pred, truth)
    rng = np.random.default_rng(2)
    for _ in range(10):
        perm = rng.permutation(len(pred))
        assert auc(pred[perm], truth[perm]) == base


def test_auc_degenerate_classes_nan():
    assert np.isnan(auc(np.array([0.1, 0.9]), np.array([1.0, 1.0])))
    assert np.isnan(auc(np.array([0.1, 0.9]), np.array([0.0, 0.0])))

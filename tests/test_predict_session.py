"""PredictSession: saved posterior samples reproduce the live chain.

``save_freq`` streams every retained sample (the full ``MFState``)
through ``checkpoint.CheckpointManager``; this file pins the three
contracts that make the store useful:

* a reload averages the SAME samples through the SAME kernel, so the
  from-disk posterior mean reproduces the in-session ``rmse_test`` to
  float32 tolerance (here: bitwise, it is the identical float program);
* out-of-matrix rows predicted through the sampled Macau link matrices
  (``mu_s + beta_s^T f`` per sample) recover planted held-out rows;
* a chain resumed from the last on-disk sample is THE SAME chain —
  final factors bitwise equal to the uninterrupted run (counter-based
  RNG + full state round-trip).

Plus the ``SessionResult.mean_from_samples`` consistency satellite:
kept samples reproduce the accumulator mean exactly.
"""
import os

import numpy as np
import pytest

from repro.core import (AdaptiveGaussian, ModelBuilder, PredictSession,
                        TrainSession, from_coo, smurff)
from repro.data.synthetic import chembl_like


def _macau_data(seed=0, n_c=64, n_t=24, n_feat=8, rank=3, noise=0.1,
                hold_out=4):
    """Planted linear feature->latent data; the last ``hold_out``
    compounds are NEVER in the training matrix (cold rows)."""
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(n_c, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    U = F @ B
    T = rng.normal(size=(n_t, rank)).astype(np.float32)
    act = (U @ T.T + noise * rng.normal(size=(n_c, n_t))) \
        .astype(np.float32)
    n_warm = n_c - hold_out
    obs = rng.random((n_warm, n_t)) < 0.5
    i, j = np.nonzero(obs)
    perm = rng.permutation(len(i))
    i, j = i[perm], j[perm]
    v = act[i, j]
    n_test = len(i) // 5
    mat = from_coo(i[n_test:], j[n_test:], v[n_test:], (n_warm, n_t))
    test = (i[:n_test], j[:n_test], v[:n_test])
    return F, mat, test, act, n_warm


def test_save_freq_requires_dir():
    with pytest.raises(ValueError, match="save_dir"):
        b = ModelBuilder(3).add_entity("r", 8).add_entity("c", 4)
        b.add_block("r", "c", np.zeros((8, 4), np.float32))
        b.session(save_freq=1)


def test_missing_store_raises_helpfully(tmp_path):
    with pytest.raises(ValueError, match="save_freq"):
        PredictSession(str(tmp_path))


def test_reload_reproduces_in_session_rmse(tmp_path):
    """The acceptance contract: PredictSession reloaded from disk
    reproduces the in-session rmse_test of the same chain."""
    F, mat, test, act, n_warm = _macau_data()
    b = ModelBuilder(num_latent=4)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])
    b.add_entity("target", mat.shape[1])
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian(),
                test=test)
    res = b.session(burnin=10, nsamples=12, seed=0, save_freq=1,
                    save_dir=str(tmp_path)).run()

    p = PredictSession(str(tmp_path))
    assert p.num_samples == 12
    # every saved step is post-burnin, in chain order
    assert p.steps == list(range(11, 23))
    pred = p.predict(test[0], test[1])
    np.testing.assert_allclose(pred, res.predictions, rtol=1e-6,
                               atol=1e-7)
    rmse_disk = float(np.sqrt(np.mean((pred - test[2]) ** 2)))
    np.testing.assert_allclose(rmse_disk, res.rmse_test, rtol=1e-6)
    # variance channel agrees too
    _, var = p.predict(test[0], test[1], return_var=True)
    np.testing.assert_allclose(var, res.pred_var, rtol=1e-5, atol=1e-6)
    # predict_all covers the same cells
    dense = p.predict_all(block=("compound", "target"))
    np.testing.assert_allclose(dense[test[0], test[1]], pred,
                               rtol=1e-5, atol=1e-6)


def test_save_freq_subsamples_chain(tmp_path):
    F, mat, test, _, n_warm = _macau_data()
    b = ModelBuilder(num_latent=4)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])
    b.add_entity("target", mat.shape[1])
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian(),
                test=test)
    b.session(burnin=4, nsamples=9, seed=0, save_freq=3,
              save_dir=str(tmp_path)).run()
    p = PredictSession(str(tmp_path))
    # samples 3, 6, 9 of the post-burnin phase (global sweeps 7,10,13)
    assert p.steps == [7, 10, 13]


def test_out_of_matrix_prediction_recovers_held_out_rows(tmp_path):
    """Whole rows never present in training, predicted through the
    sampled Macau beta link — must beat the predict-zero baseline on
    the planted data by a wide margin."""
    F, mat, test, act, n_warm = _macau_data()
    b = ModelBuilder(num_latent=4)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])
    b.add_entity("target", mat.shape[1])
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian(),
                test=test)
    b.session(burnin=25, nsamples=25, seed=0, save_freq=1,
              save_dir=str(tmp_path)).run()

    p = PredictSession(str(tmp_path))
    cold = p.predict_new("compound", F[n_warm:])
    assert cold.shape == (act.shape[0] - n_warm, act.shape[1])
    truth = act[n_warm:]
    rmse_cold = float(np.sqrt(np.mean((cold - truth) ** 2)))
    rmse_zero = float(np.sqrt(np.mean(truth ** 2)))
    assert rmse_cold < 0.5 * rmse_zero, (rmse_cold, rmse_zero)
    # a single held-out row works and matches the batch row
    one = p.predict_new("compound", F[n_warm])
    np.testing.assert_allclose(one[0], cold[0], rtol=1e-6)


def test_block_tuple_order_sets_orientation(tmp_path):
    """A tuple ``block`` addresses (i, j) in the ORDER it names the
    entities: naming the pair reversed transposes the addressing
    rather than silently reinterpreting indices in the stored
    orientation."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(16, 8)).astype(np.float32)
    b = ModelBuilder(3).add_entity("r", 16).add_entity("c", 8)
    b.add_block("r", "c", X)
    b.session(burnin=2, nsamples=4, seed=0, save_freq=1,
              save_dir=str(tmp_path)).run()
    p = PredictSession(str(tmp_path))
    i, j = np.array([3, 5]), np.array([1, 7])
    fwd = p.predict(i, j, block=("r", "c"))
    rev = p.predict(j, i, block=("c", "r"))
    np.testing.assert_array_equal(fwd, rev)
    np.testing.assert_array_equal(p.predict_all(block=("c", "r")),
                                  p.predict_all(block=("r", "c")).T)


def test_prior_instance_num_latent_mismatch_rejected():
    from repro.core import NormalPrior
    b = ModelBuilder(4)
    with pytest.raises(ValueError, match="num_latent=2"):
        b.add_entity("a", 16, prior=NormalPrior(2))


def test_predict_new_requires_macau(tmp_path):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(16, 8)).astype(np.float32)
    b = ModelBuilder(3).add_entity("r", 16).add_entity("c", 8)
    b.add_block("r", "c", X)
    b.session(burnin=1, nsamples=2, seed=0, save_freq=1,
              save_dir=str(tmp_path)).run()
    p = PredictSession(str(tmp_path))
    with pytest.raises(ValueError, match="Macau"):
        p.predict_new("r", np.zeros((1, 4), np.float32))
    with pytest.raises(ValueError, match="unknown entity"):
        p.predict_new("bogus", np.zeros((1, 4), np.float32))


def test_resume_from_checkpoint_is_same_chain(tmp_path):
    """An interrupted chain resumed from the on-disk store ends on
    BITWISE the same state as the uninterrupted chain."""
    mat, test, _ = chembl_like(5, n_compounds=48, n_proteins=24,
                               density=0.3, rank=3, noise=0.2)
    d_full = str(tmp_path / "full")
    d_cut = str(tmp_path / "cut")

    def sess(nsamples, save_dir):
        s = TrainSession(num_latent=3, burnin=3, nsamples=nsamples,
                         seed=2, save_freq=1, save_dir=save_dir)
        s.add_train_and_test(mat, test=test, noise=AdaptiveGaussian())
        return s

    full = sess(8, d_full).run()
    sess(3, d_cut).run()                       # "interrupted" after 3
    resumed = sess(8, d_cut).run(resume=True)  # continue to 8
    for a, b in zip(full.state.factors, resumed.state.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(resumed.state.step) == int(full.state.step) == 11
    # and the store now holds the full chain's samples
    p = PredictSession(d_cut)
    assert p.num_samples == 8
    step, st = p.restore_latest()
    assert step == 11 and int(st.step) == 11


def test_mean_from_samples_matches_accumulator_exactly():
    """keep_samples=True samples reproduce acc.mean EXACTLY — the
    posterior-mean-from-samples consistency satellite."""
    mat, test, _ = chembl_like(1, n_compounds=48, n_proteins=24,
                               density=0.3, rank=3, noise=0.2)
    s = TrainSession(num_latent=3, burnin=5, nsamples=7, seed=4)
    s.add_train_and_test(mat, test=test, noise=None)
    res = s.run(keep_samples=True)
    assert len(res.samples) == 7
    m = res.mean_from_samples(test)
    np.testing.assert_array_equal(m, res.predictions)
    with pytest.raises(ValueError, match="keep_samples"):
        s.run().mean_from_samples(test)


def test_checkpoint_keep_none_retains_all(tmp_path):
    from repro.checkpoint import CheckpointManager, list_steps
    mgr = CheckpointManager(str(tmp_path), keep=None)
    for s in range(1, 6):
        mgr.save(s, {"x": np.full((2,), s, np.float32)}, blocking=True)
    assert mgr.all_steps() == [1, 2, 3, 4, 5]
    # the keep-N mode still garbage-collects
    mgr2 = CheckpointManager(str(tmp_path / "n2"), keep=2)
    for s in range(1, 6):
        mgr2.save(s, {"x": np.full((2,), s, np.float32)}, blocking=True)
    assert mgr2.all_steps() == [4, 5]
    assert list_steps(str(tmp_path)) == [1, 2, 3, 4, 5]


def test_model_spec_roundtrip(tmp_path):
    """model.json captures the full static graph: priors with their
    hyper-parameters, noises, entity names — spec_to_model inverts
    model_to_spec."""
    from repro.core.modelspec import (model_to_spec, spec_to_model,
                                      state_template)
    F, mat, test, _, n_warm = _macau_data()
    b = ModelBuilder(num_latent=4)
    b.add_entity("compound", n_warm, side_info=F[:n_warm],
                 beta_precision=3.5, sample_beta_precision=False)
    b.add_entity("target", mat.shape[1], prior="spikeandslab")
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian())
    model, data, _ = b.build()
    spec = model_to_spec(model)
    model2 = spec_to_model(spec)
    assert model2 == model
    # the rebuilt template matches a live state leaf for leaf
    import jax
    from repro.core import init_state
    live = init_state(model, data, 0)
    t_leaves, t_def = jax.tree.flatten(state_template(model2))
    l_leaves, l_def = jax.tree.flatten(live)
    assert t_def == l_def
    for t, l in zip(t_leaves, l_leaves):
        assert np.shape(t) == np.shape(l)


def test_smurff_forwards_mesh_pipeline_and_save(tmp_path):
    """``smurff()`` forwards mesh=/pipeline= (previously dropped) and
    save_freq=/save_dir= — the one-call API reaches the full knob
    set."""
    from repro.launch.mesh import make_mesh
    mat, test, _ = chembl_like(2, n_compounds=48, n_proteins=24,
                               density=0.3, rank=3, noise=0.2)
    ref = smurff(mat, test=test, num_latent=3, burnin=3, nsamples=3,
                 seed=0)
    mesh = make_mesh((1,), ("data",))
    for pipe in ("eager", "ring"):
        res = smurff(mat, test=test, num_latent=3, burnin=3, nsamples=3,
                     seed=0, mesh=mesh, pipeline=pipe)
        np.testing.assert_allclose(res.rmse_train_trace,
                                   ref.rmse_train_trace, rtol=1e-5,
                                   err_msg=pipe)
    with pytest.raises(ValueError, match="valid pipelines"):
        smurff(mat, test=test, num_latent=3, burnin=1, nsamples=1,
               seed=0, mesh=mesh, pipeline="warp")
    d = str(tmp_path / "s")
    res = smurff(mat, test=test, num_latent=3, burnin=2, nsamples=4,
                 seed=0, save_freq=2, save_dir=d)
    p = PredictSession(d)
    assert p.num_samples == 2
    pred = p.predict(test[0], test[1])
    np.testing.assert_allclose(
        float(np.sqrt(np.mean((pred - test[2]) ** 2))),
        res.rmse_test, rtol=0.5)   # 2-of-4 subsample, same ballpark

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode (CPU container; TPU is the target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container without dev deps — see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gram import gram_pallas
from repro.kernels.sddmm import sddmm_pallas


@pytest.mark.parametrize("R,T,K", [
    (1, 1, 1), (3, 5, 7), (8, 128, 16), (32, 24, 16),
    (7, 130, 8), (64, 256, 128), (13, 257, 33), (100, 64, 64),
])
def test_gram_matches_ref(R, T, K):
    key = jax.random.PRNGKey(R * 1000 + T * 10 + K)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g1, r1 = ops.gram_and_rhs(vg, val, mask, use_pallas=True)
    g2, r2 = ref.gram_ref(vg, val, mask)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (16, 32, 8)).astype(dtype)
    val = jax.random.normal(k2, (16, 32)).astype(dtype)
    mask = (jax.random.uniform(k3, (16, 32)) > 0.5).astype(dtype)
    g1, r1 = ops.gram_and_rhs(vg, val, mask, use_pallas=True)
    g2, r2 = ref.gram_ref(vg, val, mask)
    assert g1.dtype == jnp.float32  # fp32 accumulation contract
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(g1, g2, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r2, rtol=tol, atol=tol)


@pytest.mark.parametrize("E,K", [(1, 3), (100, 16), (512, 128),
                                 (1025, 64), (5, 200)])
def test_sddmm_matches_ref(E, K):
    key = jax.random.PRNGKey(E + K)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (E, K), jnp.float32)
    b = jax.random.normal(k2, (E, K), jnp.float32)
    np.testing.assert_allclose(
        ops.sddmm(a, b, use_pallas=True), ref.sddmm_ref(a, b),
        rtol=1e-5, atol=1e-4)


def test_gram_block_shapes():
    """Explicit BlockSpec tiling choices agree with the oracle."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    R, T, K = 16, 256, 16
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g_ref, r_ref = ref.gram_ref(vg, val, mask)
    for br, bt in [(4, 64), (8, 128), (16, 256), (2, 32)]:
        g, r = gram_pallas(vg, val, mask, block_rows=br, block_nnz=bt,
                           interpret=True)
        np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(r, r_ref, rtol=1e-5, atol=1e-4)


def test_sddmm_block_shapes():
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    E, K = 1024, 128
    a = jax.random.normal(k1, (E, K), jnp.float32)
    b = jax.random.normal(k2, (E, K), jnp.float32)
    expect = ref.sddmm_ref(a, b)
    for be, bk in [(128, 32), (512, 128), (1024, 64)]:
        out = sddmm_pallas(a, b, block_e=be, block_k=bk, interpret=True)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)


# -- properties -----------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 20), st.integers(1, 40), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_gram_psd_and_mask_zero(R, T, K, seed):
    """gram is PSD; fully-masked rows give exactly zero gram/rhs."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.5).astype(jnp.float32)
    mask = mask.at[0].set(0.0)          # row 0 fully padded
    g, r = ref.gram_ref(vg, val, mask)
    assert np.allclose(g[0], 0) and np.allclose(r[0], 0)
    eig = np.linalg.eigvalsh(np.asarray(g))
    assert eig.min() > -1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 32), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_gram_padding_invariance(R, T, K, seed):
    """Appending masked padding never changes the result."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g1, r1 = ref.gram_ref(vg, val, mask)
    pad = 13
    vg2 = jnp.pad(vg, ((0, 0), (0, pad), (0, 0)),
                  constant_values=3.14)   # garbage under the mask
    val2 = jnp.pad(val, ((0, 0), (0, pad)), constant_values=-2.7)
    mask2 = jnp.pad(mask, ((0, 0), (0, pad)))
    g2, r2 = ref.gram_ref(vg2, val2, mask2)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-5)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode (CPU container; TPU is the target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container without dev deps — see requirements-dev.txt
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gram import gram_pallas
from repro.kernels.sddmm import sddmm_pallas


@pytest.mark.parametrize("R,T,K", [
    (1, 1, 1), (3, 5, 7), (8, 128, 16), (32, 24, 16),
    (7, 130, 8), (64, 256, 128), (13, 257, 33), (100, 64, 64),
])
def test_gram_matches_ref(R, T, K):
    key = jax.random.PRNGKey(R * 1000 + T * 10 + K)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g1, r1 = ops.gram_and_rhs(vg, val, mask, use_pallas=True)
    g2, r2 = ref.gram_ref(vg, val, mask)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (16, 32, 8)).astype(dtype)
    val = jax.random.normal(k2, (16, 32)).astype(dtype)
    mask = (jax.random.uniform(k3, (16, 32)) > 0.5).astype(dtype)
    g1, r1 = ops.gram_and_rhs(vg, val, mask, use_pallas=True)
    g2, r2 = ref.gram_ref(vg, val, mask)
    assert g1.dtype == jnp.float32  # fp32 accumulation contract
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(g1, g2, rtol=tol, atol=tol)
    np.testing.assert_allclose(r1, r2, rtol=tol, atol=tol)


@pytest.mark.parametrize("E,K", [(1, 3), (100, 16), (512, 128),
                                 (1025, 64), (5, 200)])
def test_sddmm_matches_ref(E, K):
    key = jax.random.PRNGKey(E + K)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (E, K), jnp.float32)
    b = jax.random.normal(k2, (E, K), jnp.float32)
    np.testing.assert_allclose(
        ops.sddmm(a, b, use_pallas=True), ref.sddmm_ref(a, b),
        rtol=1e-5, atol=1e-4)


def test_gram_block_shapes():
    """Explicit BlockSpec tiling choices agree with the oracle."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    R, T, K = 16, 256, 16
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g_ref, r_ref = ref.gram_ref(vg, val, mask)
    for br, bt in [(4, 64), (8, 128), (16, 256), (2, 32)]:
        g, r = gram_pallas(vg, val, mask, block_rows=br, block_nnz=bt,
                           interpret=True)
        np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(r, r_ref, rtol=1e-5, atol=1e-4)


def test_sddmm_block_shapes():
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    E, K = 1024, 128
    a = jax.random.normal(k1, (E, K), jnp.float32)
    b = jax.random.normal(k2, (E, K), jnp.float32)
    expect = ref.sddmm_ref(a, b)
    for be, bk in [(128, 32), (512, 128), (1024, 64)]:
        out = sddmm_pallas(a, b, block_e=be, block_k=bk, interpret=True)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)


# -- properties -----------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 20), st.integers(1, 40), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_gram_psd_and_mask_zero(R, T, K, seed):
    """gram is PSD; fully-masked rows give exactly zero gram/rhs."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.5).astype(jnp.float32)
    mask = mask.at[0].set(0.0)          # row 0 fully padded
    g, r = ref.gram_ref(vg, val, mask)
    assert np.allclose(g[0], 0) and np.allclose(r[0], 0)
    eig = np.linalg.eigvalsh(np.asarray(g))
    assert eig.min() > -1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 32), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
def test_gram_padding_invariance(R, T, K, seed):
    """Appending masked padding never changes the result."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    vg = jax.random.normal(k1, (R, T, K), jnp.float32)
    val = jax.random.normal(k2, (R, T), jnp.float32)
    mask = (jax.random.uniform(k3, (R, T)) > 0.3).astype(jnp.float32)
    g1, r1 = ref.gram_ref(vg, val, mask)
    pad = 13
    vg2 = jnp.pad(vg, ((0, 0), (0, pad), (0, 0)),
                  constant_values=3.14)   # garbage under the mask
    val2 = jnp.pad(val, ((0, 0), (0, pad)), constant_values=-2.7)
    mask2 = jnp.pad(mask, ((0, 0), (0, pad)))
    g2, r2 = ref.gram_ref(vg2, val2, mask2)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-5)


# -- topk_score: the serving kernel ---------------------------------------

def _topk_both(us, v, k, excl=None):
    """ops.topk_score through both paths; kernel in interpret mode."""
    a = ops.topk_score(us, v, k, exclude=excl, use_pallas=False)
    b = ops.topk_score(us, v, k, exclude=excl, use_pallas=True)
    return a, b


def _assert_bitwise(a, b):
    """Exact equality per field; NaN slots (invalid tail) must match
    positionally."""
    for x, y in zip(a, b):
        x, y = np.asarray(x), np.asarray(y)
        nx, ny = np.isnan(x), np.isnan(y)
        np.testing.assert_array_equal(nx, ny)
        np.testing.assert_array_equal(x[~nx], y[~ny])


@pytest.mark.parametrize("B,S,N,K,k", [
    (1, 1, 1, 1, 1), (2, 8, 64, 16, 10), (5, 8, 130, 16, 7),
    (3, 16, 256, 8, 300), (4, 4, 33, 12, 5), (2, 50, 512, 16, 20),
])
def test_topk_kernel_matches_ref_bitwise(B, S, N, K, k):
    """The serving contract: fused kernel == argsort oracle BITWISE in
    fp32 (ids, posterior mean, posterior std), uneven n_items
    included (both paths see the same item padding)."""
    key = jax.random.PRNGKey(B * 7 + N)
    k1, k2, k3 = jax.random.split(key, 3)
    us = jax.random.normal(k1, (B, S, K), jnp.float32)
    v = jax.random.normal(k2, (S, N, K), jnp.float32)
    excl = (jax.random.uniform(k3, (B, N)) < 0.2).astype(jnp.float32)
    a, b = _topk_both(us, v, k, excl)
    assert a[0].shape == (B, min(k, N))   # K > n_items clamps
    _assert_bitwise(a, b)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 12), st.integers(1, 200),
       st.integers(1, 12), st.integers(1, 30),
       st.integers(0, 2**31 - 1))
def test_topk_property_kernel_equals_ref(B, S, N, K, k, seed):
    """Property sweep over uneven n_items / K > n_items / exclusion
    density (up to whole rows excluded): bitwise agreement, -1/NaN
    invalid-tail contract included."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    us = jax.random.normal(k1, (B, S, K), jnp.float32)
    v = jax.random.normal(k2, (S, N, K), jnp.float32)
    dens = jax.random.uniform(k4, (B, 1))   # some rows ~fully excluded
    excl = (jax.random.uniform(k3, (B, N)) < dens).astype(jnp.float32)
    a, b = _topk_both(us, v, k, excl)
    _assert_bitwise(a, b)
    ids, mean, std = (np.asarray(x) for x in a)
    n_valid = int(np.sum(np.asarray(excl)[0] <= 0))
    k_eff = min(k, N)
    assert ids.shape == (B, k_eff)
    # invalid tail: id -1 slots carry NaN mean/std, exactly past n_valid
    assert (ids[0, n_valid:k_eff] == -1).all()
    assert np.isnan(mean[0, min(n_valid, k_eff):]).all()
    valid = ids[0, :min(n_valid, k_eff)]
    assert (valid >= 0).all() and len(set(valid.tolist())) == len(valid)


def test_topk_tied_scores_rank_by_lowest_id():
    """Tie-break contract vs an independent numpy oracle: integer
    latents make the posterior means exact in fp32, so ties are exact
    and must rank by LOWEST item id on both paths (the stable-argsort
    order)."""
    rng = np.random.default_rng(3)
    B, S, N, K, k = 3, 4, 57, 8, 12
    us = rng.integers(-2, 3, (B, S, K)).astype(np.float32)
    v = rng.integers(-2, 3, (S, N, K)).astype(np.float32)
    a, b = _topk_both(jnp.asarray(us), jnp.asarray(v), k)
    _assert_bitwise(a, b)
    mean_o = np.einsum("bsk,snk->bsn", us, v).mean(axis=1)  # exact ints
    for row in range(B):
        oracle = np.argsort(-mean_o[row], kind="stable")[:k]
        np.testing.assert_array_equal(np.asarray(a[0])[row], oracle)
    assert len(np.unique(mean_o[0])) < N   # ties actually occurred


def test_topk_all_tied_is_identity_prefix():
    """Fully degenerate scores (all zero) must return items 0..k-1."""
    us = jnp.zeros((2, 4, 8), jnp.float32)
    v = jnp.zeros((4, 100, 8), jnp.float32)
    a, b = _topk_both(us, v, 5)
    _assert_bitwise(a, b)
    np.testing.assert_array_equal(np.asarray(a[0]),
                                  np.tile(np.arange(5), (2, 1)))


def test_topk_bf16_stack_matches_ref():
    """bf16 factor stacks: both paths keep operands bf16 into the
    contraction (f32 accumulation) and still agree bitwise; the means
    stay close to the f32 computation."""
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    us = jax.random.normal(k1, (3, 8, 16), jnp.float32)
    v = jax.random.normal(k2, (8, 130, 16), jnp.float32)
    a, b = _topk_both(us.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                      6)
    _assert_bitwise(a, b)
    f32, _ = _topk_both(us, v, 6)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(f32[1]),
                               rtol=0.05, atol=0.15)


def test_topk_validation_errors():
    us = jnp.zeros((2, 3, 4), jnp.float32)
    v = jnp.zeros((3, 10, 4), jnp.float32)
    with pytest.raises(ValueError, match="k must be"):
        ops.topk_score(us, v, 0)
    with pytest.raises(ValueError, match="exclude shape"):
        ops.topk_score(us, v, 2, exclude=jnp.zeros((3, 10)))


# -- pad_to_blocks: the ONE padding path -----------------------------------

@pytest.mark.parametrize("shape,multiples,expect", [
    ((13,), {0: 8}, (16,)),
    ((13, 257), {0: 8, 1: 128}, (16, 384)),
    ((8, 256), {0: 8, 1: 128}, (8, 256)),          # already aligned
    ((3, 5, 7), {1: 4}, (3, 8, 7)),                # untouched axes keep
    ((1, 1), {0: 16, 1: 16}, (16, 16)),
    ((130,), {0: 1}, (130,)),                      # multiple 1 = no-op
])
def test_pad_to_blocks_shapes(shape, multiples, expect):
    x = jnp.ones(shape, jnp.float32)
    y = ops.pad_to_blocks(x, multiples)
    assert y.shape == expect


def test_pad_to_blocks_aligned_is_identity():
    """The aligned fast path returns the SAME array — no pad op."""
    x = jnp.ones((8, 256), jnp.float32)
    assert ops.pad_to_blocks(x, {0: 8, 1: 128}) is x


def test_pad_to_blocks_zero_fills_tail():
    x = jnp.full((5, 3), 7.0)
    y = ops.pad_to_blocks(x, {0: 4, 1: 4})
    assert y.shape == (8, 4)
    np.testing.assert_array_equal(np.asarray(y[:5, :3]), np.asarray(x))
    assert float(jnp.sum(jnp.abs(y[5:, :]))) == 0.0
    assert float(jnp.sum(jnp.abs(y[:, 3:]))) == 0.0


def test_pad_to_blocks_rejects_bad_multiple():
    with pytest.raises(ValueError, match="must be >= 1"):
        ops.pad_to_blocks(jnp.ones((4,)), {0: 0})


# -- flash attention vs the plain-softmax oracle ---------------------------

def _flash_both(q, k, v, **kw):
    from repro.kernels.flash import flash_fwd_pallas
    a = ref.attention_ref(q, k, v, **kw)
    b = flash_fwd_pallas(q, k, v, interpret=True, **kw)
    return a, b


@pytest.mark.parametrize("B,S,H,KVH,hd", [
    (1, 8, 1, 1, 4), (2, 64, 4, 2, 16), (1, 128, 8, 2, 32),
    (2, 32, 6, 3, 8),
])
def test_flash_causal_matches_attention_ref(B, S, H, KVH, hd):
    """Interpret-mode parity vs the materialized-score oracle: causal
    masking over MHA and GQA layouts (oracle-parity pattern, same as
    the topk tests above)."""
    key = jax.random.PRNGKey(B * 100 + S + H)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KVH, hd), jnp.float32)
    a, b = _flash_both(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window,q_offset", [
    (16, 0), (32, 64), (8, 120), (128, 192),
])
def test_flash_windowed_matches_attention_ref(window, q_offset):
    """Sliding-window decode: Sq < Sk with a query offset, so the
    position arithmetic (qpos = q_offset + row) is what's under test."""
    key = jax.random.PRNGKey(window + q_offset)
    k1, k2, k3 = jax.random.split(key, 3)
    B, Sq, Sk, H, KVH, hd = 2, 64, 256, 4, 2, 16
    q = jax.random.normal(k1, (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, Sk, KVH, hd), jnp.float32)
    v = jax.random.normal(k3, (B, Sk, KVH, hd), jnp.float32)
    a, b = _flash_both(q, k, v, causal=True, window=window,
                       q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                               rtol=1e-5, atol=1e-5)


def test_flash_noncausal_block_sweep_matches_attention_ref():
    """Explicit block-size choices agree with the oracle (the same
    discipline as test_gram_block_shapes)."""
    key = jax.random.PRNGKey(21)
    k1, k2, k3 = jax.random.split(key, 3)
    B, S, H, KVH, hd = 1, 128, 2, 1, 8
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KVH, hd), jnp.float32)
    expect = ref.attention_ref(q, k, v, causal=False)
    from repro.kernels.flash import flash_fwd_pallas
    for bq, bk in [(32, 32), (64, 128), (128, 16)]:
        out = flash_fwd_pallas(q, k, v, causal=False, block_q=bq,
                               block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


def test_flash_bf16_accumulates_f32():
    """bf16 q/k/v: output dtype follows q, accuracy follows the f32
    accumulation contract (close to the f32 oracle, not bf16-sloppy)."""
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    B, S, H, KVH, hd = 1, 64, 2, 2, 16
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KVH, hd), jnp.float32)
    f32 = ref.attention_ref(q, k, v, causal=True)
    a, b = _flash_both(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16), causal=True)
    assert b.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(b, np.float32), np.asarray(f32),
        rtol=0.05, atol=0.05)

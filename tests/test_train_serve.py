"""LM substrate: training loss decreases; serving paths are coherent."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import TokenStream, make_lm_batch
from repro.launch.serve import BatchedServer, generate
from repro.launch.train import train
from repro.models import init_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def test_train_loss_decreases():
    cfg = get_smoke("smollm_135m")
    out = train(cfg, steps=30, batch=4, seq=64, log_every=0,
                opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                    total_steps=30))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.2, (first, last)


def test_token_stream_deterministic_and_seekable():
    s = TokenStream(512, seed=3)
    a = s.batch(10, 4, 16)
    b = s.batch(10, 4, 16)
    np.testing.assert_array_equal(a, b)
    c = s.batch(11, 4, 16)
    assert not np.array_equal(a, c)
    # a fresh stream object seeks to the same batch
    s2 = TokenStream(512, seed=3)
    np.testing.assert_array_equal(a, s2.batch(10, 4, 16))


def test_adamw_step_and_decay():
    cfg = get_smoke("smollm_135m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    oc = AdamWConfig(lr=1e-2, weight_decay=0.1, total_steps=10)
    g = jax.tree.map(jnp.ones_like, params)
    p2, opt2, m = adamw_update(oc, params, g, opt)
    assert int(opt2.step) == 1
    assert float(m["grad_norm"]) > 0
    # params moved against the gradient
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


def test_generate_greedy_consistency():
    cfg = get_smoke("smollm_135m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = generate(cfg, params, prompts, max_new=6)
    assert out.shape == (2, 14)
    np.testing.assert_array_equal(out[:, :8], prompts)
    # deterministic
    out2 = generate(cfg, params, prompts, max_new=6)
    np.testing.assert_array_equal(out, out2)


def test_batched_server_completes_requests():
    cfg = get_smoke("smollm_135m")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    srv = BatchedServer(cfg, params, slots=2, max_len=64)
    for r in range(5):
        srv.submit(rng.integers(0, cfg.vocab_size, (6,)), max_new=4,
                   req_id=f"req{r}")
    done = srv.run()
    assert len(done) == 5
    assert all(len(d["generated"]) == 4 for d in done)
    # more requests than slots => continuous batching actually cycled
    assert {d["id"] for d in done} == {f"req{r}" for r in range(5)}


def test_make_lm_batch_shapes():
    cfg = get_smoke("internvl2_2b")
    s = TokenStream(cfg.vocab_size, seed=0)
    b = make_lm_batch(s, 0, 2, 32,
                      frontend_tokens=cfg.n_frontend_tokens,
                      d_model=cfg.d_model)
    assert b["tokens"].shape == (2, 32)
    if cfg.n_frontend_tokens:
        assert b["frontend"].shape == (2, cfg.n_frontend_tokens,
                                       cfg.d_model)

"""The Recorder: spans, counters, gauges, histograms, trace export.

Determinism contract (the reason this subsystem exists as *one*
module instead of ad-hoc timers):

- A disabled Recorder never reads the clock.  Every public method
  checks ``self.enabled`` before anything else, so ``REPRO_OBS``
  unset costs one attribute load + branch per call site.
- Wall-clock values are only ever *recorded*, never fed back into a
  computation, and timing always happens outside jitted code (span
  ends are fenced with ``jax.block_until_ready`` by the caller).
  Together these make sampled chains bitwise-invariant to
  instrumentation — asserted in tests/test_golden_chain.py and
  tests/test_multichain.py.
- All mutation happens under one lock: the checkpoint manager's
  background save thread and the serving loop write into the same
  Recorder concurrently.

Span timestamps are relative to the Recorder's construction (its
trace epoch), exported in Chrome trace-event microseconds.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Sequence

from . import clock
from .metrics import (Histogram, METRICS_FORMAT, TRACE_FORMAT,
                      latency_buckets, prometheus_text, write_json_atomic)


def obs_enabled() -> bool:
    """True when the ``REPRO_OBS`` env var opts into observability."""
    return os.environ.get("REPRO_OBS", "").strip().lower() in (
        "1", "true", "yes", "on")


class Recorder:
    """Collects trace spans + metrics for one run/server.

    Construct with ``enabled=False`` (or via ``resolve_recorder(None)``
    with ``REPRO_OBS`` unset) for a no-op recorder: no clock reads, no
    allocations beyond the instance itself.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}
        self._tids: Dict[int, int] = {}
        self._epoch = clock.perf_counter() if self.enabled else 0.0
        self._kind: Optional[str] = None

    # -- internals ---------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = len(self._tids)
            self._tids[ident] = tid
        return tid

    def _push(self, event: dict) -> None:
        with self._lock:
            event["tid"] = self._tid()
            self._events.append(event)

    # -- spans -------------------------------------------------------

    def now(self) -> float:
        """Span start timestamp; 0.0 when disabled (never read then)."""
        return clock.perf_counter() if self.enabled else 0.0

    def complete(self, name: str, start: float, end: Optional[float] = None,
                 cat: str = "obs", **args: Any) -> None:
        """Record a complete ('X') span from an explicit start time.

        ``start``/``end`` are ``clock.perf_counter()`` readings — pass
        ``end`` explicitly when the span must stop at a fence (e.g.
        right after ``block_until_ready``) rather than at call time.
        """
        if not self.enabled:
            return
        if end is None:
            end = clock.perf_counter()
        self._push({"name": name, "cat": cat, "ph": "X",
                    "ts": (start - self._epoch) * 1e6,
                    "dur": max(end - start, 0.0) * 1e6,
                    "pid": 0, "args": args})

    @contextmanager
    def span(self, name: str, cat: str = "obs", **args: Any):
        """Context-manager span for non-hot paths (cache warm, restore)."""
        if not self.enabled:
            yield
            return
        t0 = clock.perf_counter()
        try:
            yield
        finally:
            self.complete(name, t0, cat=cat, **args)

    def instant(self, name: str, cat: str = "obs", **args: Any) -> None:
        if not self.enabled:
            return
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": (clock.perf_counter() - self._epoch) * 1e6,
                    "pid": 0, "args": args})

    # -- metrics -----------------------------------------------------

    def add(self, name: str, n: float = 1.0) -> None:
        """Increment a monotonically-increasing counter."""
        if not self.enabled:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + n

    def gauge(self, name: str, value: float) -> None:
        """Set a point-in-time gauge (queue depth, resident bytes)."""
        if not self.enabled:
            return
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float,
                bounds: Optional[Sequence[float]] = None) -> None:
        """Observe into the fixed-bucket histogram ``name``, creating
        it with ``bounds`` (default: latency buckets) on first use.
        Later ``bounds`` arguments are ignored — buckets are fixed."""
        if not self.enabled:
            return
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = Histogram(latency_buckets() if bounds is None else bounds)
                self._hists[name] = h
            h.observe(value)

    def histogram(self, name: str) -> Optional[Histogram]:
        with self._lock:
            return self._hists.get(name)

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def reset(self) -> None:
        """Drop all recorded data (e.g. after a benchmark warm-up) and
        restart the trace epoch. Bucket layouts are not preserved."""
        with self._lock:
            self._events.clear()
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            if self.enabled:
                self._epoch = clock.perf_counter()

    def set_kind(self, kind: str) -> None:
        """Label the producing layer ('session', 'serve', …); stamped
        into exports so the schema audit can apply per-kind checks."""
        self._kind = kind

    # -- export ------------------------------------------------------

    def trace(self) -> dict:
        """Chrome trace-event JSON object (load in chrome://tracing or
        https://ui.perfetto.dev)."""
        with self._lock:
            events = [dict(e) for e in self._events]
        out = {"traceEvents": events, "displayTimeUnit": "ms",
               "repro": {"format": TRACE_FORMAT}}
        if self._kind:
            out["repro"]["kind"] = self._kind
        return out

    def metrics(self) -> dict:
        """JSON metrics snapshot: counters, gauges, histograms."""
        with self._lock:
            out = {"format": METRICS_FORMAT,
                   "counters": dict(self._counters),
                   "gauges": dict(self._gauges),
                   "histograms": {k: h.to_dict()
                                  for k, h in self._hists.items()}}
        if self._kind:
            out["kind"] = self._kind
        return out

    def prometheus(self) -> str:
        """The same snapshot in Prometheus text exposition format."""
        with self._lock:
            return prometheus_text(dict(self._counters), dict(self._gauges),
                                   dict(self._hists))

    def write_trace(self, path: str) -> None:
        write_json_atomic(path, self.trace())

    def write_metrics(self, path: str) -> None:
        write_json_atomic(path, self.metrics())


def resolve_recorder(recorder: Optional[Recorder]) -> Recorder:
    """Standard constructor-argument plumbing: an explicit Recorder
    wins; otherwise a fresh one, enabled iff ``REPRO_OBS`` is set.

    Fresh (not a global singleton) so two runs in one process never
    interleave their traces; layers that must share a recorder
    (session → its checkpoint savers) pass it down explicitly.
    """
    if recorder is not None:
        return recorder
    return Recorder(enabled=obs_enabled())

"""Fixed-bucket histograms and metrics-snapshot formats.

Histograms here are the *shared* latency primitive: the serving layer
(`launch/serve.py`), the checkpoint manager, the session sweep loop,
and `benchmarks/serve_latency.py` all observe into the same
fixed-bucket structure, and percentiles come out of one
:meth:`Histogram.percentile` implementation instead of N hand-rolled
``np.sort`` variants.

Buckets are fixed at construction (Prometheus-style `le` bounds), so
merging, serializing, and diffing snapshots across runs is exact:
two snapshots of the same metric always share bucket edges.
"""
from __future__ import annotations

import json
import math
import os
import re
import tempfile
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence

# Format tags stamped into every snapshot; the schema audit
# (repro.analysis.obsschema) keys on them.
METRICS_FORMAT = "repro-obs-metrics-v1"
TRACE_FORMAT = "repro-obs-trace-v1"


def latency_buckets(lo: float = 1e-4, hi: float = 120.0,
                    ratio: float = 1.25) -> List[float]:
    """Geometric latency bounds in seconds: 100 µs … 120 s.

    ratio=1.25 keeps worst-case interpolation error well under the
    run-to-run noise of any wall-clock measurement while staying at
    ~63 buckets per histogram — small enough to commit snapshots.
    """
    bounds = [lo]
    while bounds[-1] < hi:
        bounds.append(bounds[-1] * ratio)
    return bounds


def integer_buckets(n: int) -> List[float]:
    """Bounds that give every integer in [0, n] its own bucket.

    Used for batch occupancy: ``bisect(bounds, k)`` lands value ``k``
    in bucket ``k`` exactly, so the histogram is a lossless count per
    occupancy level and ``mean()`` is exact.
    """
    return [i + 0.5 for i in range(n + 1)]


class Histogram:
    """Fixed-bucket histogram with linear-interpolated percentiles.

    ``counts`` has ``len(bounds) + 1`` entries: one per ``le`` bound
    plus a final overflow bucket. ``sum``/``total`` make the snapshot
    a valid Prometheus histogram (``_sum`` / ``_count``).
    """

    __slots__ = ("bounds", "counts", "total", "sum")

    def __init__(self, bounds: Sequence[float]):
        b = [float(x) for x in bounds]
        if not b or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError("histogram bounds must be non-empty and "
                             "strictly increasing, got %r" % (bounds,))
        self.bounds: List[float] = b
        self.counts: List[int] = [0] * (len(b) + 1)
        self.total: int = 0
        self.sum: float = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v

    def mean(self) -> float:
        return self.sum / self.total if self.total else math.nan

    def percentile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) by linear
        interpolation inside the bucket holding the target rank.

        The overflow bucket cannot be interpolated; it reports its
        lower edge (the largest finite bound) — a deliberate
        underestimate that keeps the value finite.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1], got %r" % (q,))
        if self.total == 0:
            return math.nan
        target = q * self.total
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                if i >= len(self.bounds):  # overflow bucket
                    return self.bounds[-1]
                hi = self.bounds[i]
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.bounds[-1]

    def to_dict(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "total": self.total, "sum": self.sum}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(d["bounds"])
        counts = [int(c) for c in d["counts"]]
        if len(counts) != len(h.counts):
            raise ValueError("counts length %d does not match bounds "
                             "(%d + overflow)" % (len(counts), len(h.bounds)))
        h.counts = counts
        h.total = int(d["total"])
        h.sum = float(d["sum"])
        return h


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _PROM_BAD.sub("_", name)


def prometheus_text(counters: Dict[str, float], gauges: Dict[str, float],
                    histograms: Dict[str, Histogram]) -> str:
    """Render a snapshot in the Prometheus text exposition format."""
    lines: List[str] = []
    for name in sorted(counters):
        p = _prom_name(name)
        lines.append("# TYPE %s counter" % p)
        lines.append("%s %g" % (p, counters[name]))
    for name in sorted(gauges):
        p = _prom_name(name)
        lines.append("# TYPE %s gauge" % p)
        lines.append("%s %g" % (p, gauges[name]))
    for name in sorted(histograms):
        h = histograms[name]
        p = _prom_name(name)
        lines.append("# TYPE %s histogram" % p)
        cum = 0
        for bound, count in zip(h.bounds, h.counts):
            cum += count
            lines.append('%s_bucket{le="%g"} %d' % (p, bound, cum))
        lines.append('%s_bucket{le="+Inf"} %d' % (p, h.total))
        lines.append("%s_sum %g" % (p, h.sum))
        lines.append("%s_count %d" % (p, h.total))
    return "\n".join(lines) + "\n"


def write_json_atomic(path: str, payload: dict) -> None:
    """Write JSON via a same-directory temp file + ``os.replace`` so a
    crashed exporter never leaves a half-written snapshot."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def percentile_summary(h: Optional[Histogram]) -> dict:
    """Small JSON-able digest of a histogram (used by benchmark and
    serving reports where the full bucket vector would be noise)."""
    if h is None or h.total == 0:
        return {"p50": None, "p99": None, "mean": None, "count": 0}
    return {"p50": h.percentile(0.50), "p99": h.percentile(0.99),
            "mean": h.mean(), "count": h.total}

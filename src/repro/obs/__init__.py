"""repro.obs — deterministic-safe observability (PR 10).

One subsystem for spans, counters, fixed-bucket latency histograms,
Chrome-trace export, and JSON/Prometheus metrics snapshots, shared by
the session sweep loop, the checkpoint manager, the posterior cache,
the serving layer, and the benchmarks.  See README.md in this
directory for the span/metric catalogue and the determinism contract.
"""
from . import clock  # noqa: F401  (the sanctioned wall-clock module)
from .metrics import (Histogram, METRICS_FORMAT, TRACE_FORMAT,
                      integer_buckets, latency_buckets, percentile_summary,
                      prometheus_text, write_json_atomic)
from .recorder import Recorder, obs_enabled, resolve_recorder

__all__ = [
    "Histogram", "METRICS_FORMAT", "TRACE_FORMAT", "Recorder", "clock",
    "integer_buckets", "latency_buckets", "obs_enabled",
    "percentile_summary", "prometheus_text", "resolve_recorder",
    "write_json_atomic",
]

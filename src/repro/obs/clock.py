"""The sanctioned wall-clock import point for the repro tree.

Every other module is banned from calling ``time.perf_counter`` /
``time.monotonic`` directly (lint rule ``timing-outside-obs``; in
``core/`` the stricter ``nondeterminism-in-core`` applies): ad-hoc
timing scattered through the runtime is how PR 10 found compile time
silently charged to sweep time and three half-compatible latency
stamps in the serving layer.  Timing flows through this module — via
:class:`~repro.obs.recorder.Recorder` spans for anything that should
land in traces/metrics, or these bare re-exports for the few places
that only need a duration (dry-run lowering/compile splits).

Nothing here may feed back into a computation: wall-clock values are
only ever *reported*, which is what keeps sampled chains bitwise
invariant to instrumentation (asserted in tests/test_golden_chain.py
and tests/test_multichain.py).
"""
from __future__ import annotations

import time as _time


def perf_counter() -> float:
    """Monotonic high-resolution timer for durations (seconds)."""
    return _time.perf_counter()


def monotonic() -> float:
    """Monotonic timer for request timestamps (seconds)."""
    return _time.monotonic()

"""AST invariant linter for the repro tree.

Every rule here encodes an invariant a previous PR *earned the hard
way* — see ``analysis/README.md`` for the catalogue (which PR, why,
and how to suppress).  The linter is purely static: it parses source
with :mod:`ast`, never imports the module under inspection, and
reports ``file:line``, a rule id, and a fix hint per finding.

Suppression
-----------
Append ``# repro-lint: disable=<rule-id>[,<rule-id>...]`` (or
``disable=all``) to the offending line, or put it on a comment-only
line directly above.  Fixture files may carry a
``# repro-lint: treat-as=<relpath>`` pragma in their first lines so
path-scoped rules can be self-tested outside ``src/repro``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

# the repro package root (…/src/repro); default lint target
REPRO_ROOT = Path(__file__).resolve().parents[1]

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\-]+)")
_TREAT_AS_RE = re.compile(r"#\s*repro-lint:\s*treat-as=(\S+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str
    hint: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.message}\n    fix: {self.hint}")


@dataclasses.dataclass(frozen=True)
class LintRule:
    id: str
    description: str
    why: str                         # provenance: which PR earned it
    check: Callable[["_Ctx"], Iterable[Finding]]


RULES: Dict[str, LintRule] = {}


def rule(rule_id: str, description: str, why: str):
    """Register a lint rule (decorator over ``check(ctx)``)."""
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = LintRule(rule_id, description, why, fn)
        return fn
    return deco


def resolve_rules(spec: Optional[str] = "all") -> List[LintRule]:
    """``'all'`` or a comma-separated id list -> rule objects."""
    if spec in (None, "", "all"):
        return list(RULES.values())
    ids = [s.strip() for s in spec.split(",") if s.strip()]
    unknown = [i for i in ids if i not in RULES]
    if unknown:
        raise ValueError(
            f"unknown rule(s) {', '.join(unknown)}; "
            f"valid rules: {', '.join(sorted(RULES))}")
    return [RULES[i] for i in ids]


class _Ctx:
    """Everything a rule needs about one file, parsed once."""

    def __init__(self, src: str, path: str, relpath: str):
        self.src = src
        self.path = path
        self.relpath = relpath
        self.tree = ast.parse(src)
        self.lines = src.splitlines()
        # nearest enclosing named function for every node
        self._enclosing: Dict[int, Optional[str]] = {}
        self._map_functions(self.tree, None)

    def _map_functions(self, node: ast.AST, fname: Optional[str]):
        self._enclosing[id(node)] = fname
        inner = fname
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = node.name
        for child in ast.iter_child_nodes(node):
            self._map_functions(child, inner)

    def enclosing_function(self, node: ast.AST) -> Optional[str]:
        return self._enclosing.get(id(node))

    def finding(self, node: ast.AST, rule_id: str, message: str,
                hint: str) -> Finding:
        return Finding(self.path, getattr(node, "lineno", 1),
                       rule_id, message, hint)


# ---------------------------------------------------------------------------
# rule 1: counter-based RNG on the sweep path
# ---------------------------------------------------------------------------

_SWEEP_MODULES = {"core/gibbs.py", "core/priors.py", "core/noise.py"}
# batch-shaped draw kinds that fork chains under sharding
_BATCH_DRAWS = {"normal", "uniform", "bernoulli", "truncated_normal"}
# init / replicated-hyper / documented single-device helpers
_RNG_WHITELIST = {
    "init_state",                 # pre-sweep init, replicated key
    "row_normals", "row_uniforms",  # the counter-based primitives
    "sample_mvn_from_precision",  # replicated hyper draw (K-sized)
    "sample_wishart",             # replicated hyper draw (K×K)
    "sample_hyper_moments",       # Macau beta draw, replicated
    "_truncnorm",                 # documented single-device helper
}
_RANDOM_CALL_RE = re.compile(
    r"(?:^|\.)random\.(normal|uniform|bernoulli|truncated_normal)$")


@rule(
    "batch-rng-in-sweep-path",
    "batch-shaped jax.random draws in sweep-path modules must go "
    "through the counter-based row_* primitives",
    "PR 3: a batch-shaped jax.random.bernoulli in the spike-and-slab "
    "update silently forked chains under sharding; shard draws are "
    "bitwise slices of the single-device chain only when every "
    "per-row draw folds the global row index into the key",
)
def _check_batch_rng(ctx: _Ctx) -> Iterable[Finding]:
    if ctx.relpath not in _SWEEP_MODULES:
        return
    # names imported directly: from jax.random import normal [as n]
    direct: Dict[str, str] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and \
                node.module == "jax.random":
            for a in node.names:
                if a.name in _BATCH_DRAWS:
                    direct[a.asname or a.name] = a.name
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        func_src = ast.unparse(node.func)
        m = _RANDOM_CALL_RE.search(func_src)
        draw = m.group(1) if m else direct.get(func_src)
        if draw is None:
            continue
        fname = ctx.enclosing_function(node)
        if fname in _RNG_WHITELIST:
            continue
        where = f"in {fname}()" if fname else "at module level"
        yield ctx.finding(
            node, "batch-rng-in-sweep-path",
            f"direct jax.random.{draw} draw {where} on the sweep path",
            "use gibbs.row_normals/row_uniforms/row_bernoulli (they "
            "fold the global row index into the key) or, for genuine "
            "init/replicated-hyper code, add the function to the "
            "whitelist in analysis/invariants.py")


# ---------------------------------------------------------------------------
# rule 2: version-sensitive imports live in compat.py
# ---------------------------------------------------------------------------

_IMPORT_EXEMPT_PREFIXES = ("kernels/",)
_GATED_PREFIXES = ("jax.experimental", "jax._src")


@rule(
    "experimental-import-outside-compat",
    "jax.experimental / jax._src imports are allowed only in "
    "compat.py and the Pallas kernels",
    "PR 2: shard_map moved between jax.experimental and jax core "
    "across versions; every version-gated import is routed through "
    "compat.py so exactly one module breaks on a JAX upgrade",
)
def _check_experimental_imports(ctx: _Ctx) -> Iterable[Finding]:
    if ctx.relpath == "compat.py" or \
            ctx.relpath.startswith(_IMPORT_EXEMPT_PREFIXES):
        return
    hint = ("import via repro.compat (add a shim there if one is "
            "missing); only compat.py and kernels/ may touch "
            "jax.experimental / jax._src")
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith(_GATED_PREFIXES):
                    yield ctx.finding(
                        node, "experimental-import-outside-compat",
                        f"direct import of {a.name}", hint)
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module.startswith(_GATED_PREFIXES):
                yield ctx.finding(
                    node, "experimental-import-outside-compat",
                    f"direct import from {node.module}", hint)
            elif node.module == "jax":
                for a in node.names:
                    if a.name in ("experimental", "_src"):
                        yield ctx.finding(
                            node,
                            "experimental-import-outside-compat",
                            f"direct import of jax.{a.name}", hint)


# ---------------------------------------------------------------------------
# rule 3: registry errors name the valid choices
# ---------------------------------------------------------------------------

@rule(
    "registry-error-without-choices",
    "a `x not in registry` ValueError must name the valid choices",
    "PR 5: session._prior_by_name / distributed.resolve_pipeline "
    "established the tell-you-the-right-knobs contract — a typo'd "
    "name fails fast listing what WOULD have worked, instead of "
    "after a 256-chip lowering",
)
def _check_registry_errors(ctx: _Ctx) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.If)
                and isinstance(node.test, ast.Compare)
                and len(node.test.ops) == 1
                and isinstance(node.test.ops[0], ast.NotIn)):
            continue
        registry_src = ast.unparse(node.test.comparators[0])
        # the choices may be formatted on a helper line feeding the
        # message, so inspect the whole if-body, not just the raise
        body_src = "\n".join(ast.unparse(s) for s in node.body)
        if ".join(" in body_src or registry_src in body_src:
            continue
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if not (isinstance(sub, ast.Raise) and sub.exc
                        and isinstance(sub.exc, ast.Call)):
                    continue
                f = sub.exc.func
                exc_name = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else "")
                if exc_name != "ValueError":
                    continue
                yield ctx.finding(
                    sub, "registry-error-without-choices",
                    f"ValueError after `not in {registry_src}` does "
                    "not name the valid choices",
                    "include the registry keys in the message, e.g. "
                    "f\"unknown x {name!r}; valid: "
                    "{', '.join(sorted(" + registry_src + "))}\"")


# ---------------------------------------------------------------------------
# rule 4: no wall-clock / global-RNG nondeterminism in core/
# ---------------------------------------------------------------------------

_CLOCK_CALL_RE = re.compile(
    r"(?:^|\.)time\.(?:time|time_ns|perf_counter|perf_counter_ns|"
    r"monotonic|monotonic_ns)$"
    r"|(?:^|\.)datetime\.(?:now|utcnow)$"
    r"|(?:^|\.)date\.today$")
_NP_RANDOM_RE = re.compile(r"(?:^|\.)(?:np|numpy)\.random\.(\w+)$")


@rule(
    "nondeterminism-in-core",
    "core/ must not draw from global np.random state or read "
    "wall-clock time",
    "PR 1: bitwise reproducibility of the Gibbs chain is the repo's "
    "north star; seeds flow through jax.random keys and explicit "
    "default_rng(seed) only — clocks and process-global RNG state "
    "make runs unrepeatable",
)
def _check_nondeterminism(ctx: _Ctx) -> Iterable[Finding]:
    if not ctx.relpath.startswith("core/"):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        func_src = ast.unparse(node.func)
        m = _NP_RANDOM_RE.search(func_src)
        if m:
            attr = m.group(1)
            if attr == "default_rng" and (node.args or node.keywords):
                continue  # explicitly seeded generator is fine
            what = ("unseeded np.random.default_rng()"
                    if attr == "default_rng"
                    else f"global-state np.random.{attr}(...)")
            yield ctx.finding(
                node, "nondeterminism-in-core", what,
                "thread a seed explicitly: jax.random keys on device "
                "paths, np.random.default_rng(seed) on host paths")
        elif _CLOCK_CALL_RE.search(func_src):
            yield ctx.finding(
                node, "nondeterminism-in-core",
                f"wall-clock read {func_src}(...)",
                "core/ results must be a pure function of (model, "
                "data, seed); record timing through a repro.obs "
                "Recorder span or obs.clock (only ever reported, "
                "never fed back into a computation)")


# ---------------------------------------------------------------------------
# rule 5: serving request paths never touch the checkpoint loader
# ---------------------------------------------------------------------------

_SERVING_MODULES = ("launch/serve.py",)
_CKPT_LOADERS = {"load_pytree", "load_sample", "restore_latest",
                 "samples", "load_model_spec"}


@rule(
    "checkpoint-load-in-serving-request-path",
    "serving modules may load the sample store only at construction "
    "(__init__ / warm*-prefixed functions), never per request",
    "PR 7: PredictSession re-read the ENTIRE sample store from disk "
    "on every predict call (R requests = R x S checkpoint loads); "
    "the resident posterior cache fixed it, and this rule keeps the "
    "per-request reload structurally unrepresentable in the server",
)
def _check_serving_loads(ctx: _Ctx) -> Iterable[Finding]:
    if ctx.relpath not in _SERVING_MODULES:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else "")
        if name not in _CKPT_LOADERS:
            continue
        fname = ctx.enclosing_function(node)
        if fname == "__init__" or (fname or "").startswith("warm"):
            continue
        where = f"in {fname}()" if fname else "at module level"
        yield ctx.finding(
            node, "checkpoint-load-in-serving-request-path",
            f"checkpoint load {name}(...) {where}, a serving request "
            "path",
            "load the store ONCE at construction (warm_cache() in "
            "__init__) and serve every request from the resident "
            "PosteriorCache; lazy streaming belongs in core/predict, "
            "not the server")


# ---------------------------------------------------------------------------
# rule 6: wall-clock timing goes through repro.obs
# ---------------------------------------------------------------------------

# the wall-clock readers obs.clock wraps; `time.sleep` is not a read
_WALL_CLOCK_FNS = ("time", "time_ns", "perf_counter", "perf_counter_ns",
                   "monotonic", "monotonic_ns", "process_time",
                   "process_time_ns", "thread_time", "thread_time_ns")
_TIME_ATTR_RE = re.compile(
    r"(?:^|\.)time\.(?:" + "|".join(_WALL_CLOCK_FNS) + r")$")


@rule(
    "timing-outside-obs",
    "wall-clock reads (time.perf_counter / time.monotonic / ...) "
    "outside repro/obs — route timing through the obs subsystem "
    "(Recorder spans, or obs.clock for bare durations)",
    "PR 10: Session.run's inline perf_counter pair charged jit "
    "compilation to sweep time and SlotServer stamped raw monotonic "
    "dicts nothing else could read; centralizing timing in repro.obs "
    "makes instrumentation uniform, no-op when disabled, and provably "
    "outside jitted code — scattered ad-hoc timers are how those "
    "regressions crept in unnoticed",
)
def _check_timing_outside_obs(ctx: _Ctx) -> Iterable[Finding]:
    # obs/ IS the sanctioned home; core/ clock reads are already
    # findings under the stricter nondeterminism-in-core rule (one
    # finding per defect, not two)
    if ctx.relpath.startswith(("obs/", "core/")):
        return
    hint = ("time a span with repro.obs.Recorder (complete()/span()) "
            "so it lands in traces and metrics, or import the bare "
            "clock from repro.obs (obs.clock.perf_counter / "
            "obs.clock.monotonic) for a plain duration")
    # direct-call aliases: `from time import perf_counter [as pc]`
    aliases = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name in _WALL_CLOCK_FNS:
                    aliases[a.asname or a.name] = a.name
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        func_src = ast.unparse(node.func)
        if _TIME_ATTR_RE.search(func_src):
            yield ctx.finding(
                node, "timing-outside-obs",
                f"wall-clock read {func_src}(...) outside repro/obs",
                hint)
        elif isinstance(node.func, ast.Name) and \
                node.func.id in aliases:
            yield ctx.finding(
                node, "timing-outside-obs",
                f"wall-clock read {node.func.id}(...) (from time "
                f"import {aliases[node.func.id]}) outside repro/obs",
                hint)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def repo_relpath(path: Path) -> str:
    """Path of a file relative to the repro package (posix), or its
    basename when outside the package (fixtures use ``treat-as``)."""
    try:
        return path.resolve().relative_to(REPRO_ROOT).as_posix()
    except ValueError:
        return path.name


def _suppressions(lines: Sequence[str]) -> Dict[int, set]:
    out: Dict[int, set] = {}
    for i, line in enumerate(lines, start=1):
        m = _DISABLE_RE.search(line)
        if m:
            out[i] = {s.strip() for s in m.group(1).split(",")}
    return out


def _suppressed(finding: Finding, lines: Sequence[str],
                supp: Dict[int, set]) -> bool:
    def hit(ids):
        return ids is not None and \
            ("all" in ids or finding.rule in ids)
    if hit(supp.get(finding.line)):
        return True
    prev = finding.line - 1
    if prev >= 1 and prev <= len(lines) and \
            lines[prev - 1].lstrip().startswith("#"):
        return hit(supp.get(prev))
    return False


def lint_source(src: str, path: str = "<string>",
                rules: Optional[Sequence[LintRule]] = None
                ) -> List[Finding]:
    """Lint one source string; ``path`` is used for reporting and —
    unless a ``treat-as`` pragma overrides it — rule scoping."""
    relpath = repo_relpath(Path(path))
    for line in src.splitlines()[:10]:
        m = _TREAT_AS_RE.search(line)
        if m:
            relpath = m.group(1)
            break
    ctx = _Ctx(src, path, relpath)
    supp = _suppressions(ctx.lines)
    findings: List[Finding] = []
    for r in (rules if rules is not None else RULES.values()):
        findings.extend(f for f in r.check(ctx)
                        if not _suppressed(f, ctx.lines, supp))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(
                f for f in p.rglob("*.py")
                if "__pycache__" not in f.parts))
        else:
            out.append(p)
    return out


def lint_paths(paths: Optional[Sequence[Path]] = None,
               rules: Optional[Sequence[LintRule]] = None
               ) -> List[Finding]:
    """Lint files/directories (default: the whole repro package)."""
    files = iter_py_files([REPRO_ROOT] if paths is None else paths)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_source(
            f.read_text(), path=str(f), rules=rules))
    return findings

"""CLI for the static-analysis passes.

Usage::

    python -m repro.analysis                    # lint src/repro AND
                                                # audit results/dryrun
    python -m repro.analysis path1.py dir2/     # lint specific paths
    python -m repro.analysis --rules batch-rng-in-sweep-path
    python -m repro.analysis --contracts results/dryrun
    python -m repro.analysis --list-rules

Exit status is 0 when no findings, 1 otherwise — CI runs this on
every push.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import invariants

# repo root when run from a source checkout (…/src/repro/analysis)
_REPO_ROOT = Path(__file__).resolve().parents[3]
_DEFAULT_DRYRUN = _REPO_ROOT / "results" / "dryrun"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant linter + communication-contract "
                    "checker for the repro tree.")
    ap.add_argument(
        "paths", nargs="*", type=Path,
        help="files/directories to lint (default: the whole "
             "repro package)")
    ap.add_argument(
        "--rules", default="all",
        help="comma-separated rule ids, or 'all' (default)")
    ap.add_argument(
        "--contracts", metavar="DIR", type=Path, default=None,
        help="audit dry-run JSONs in DIR against freshly derived "
             "contracts (given alone, skips the lint pass); the "
             "no-argument invocation audits results/dryrun if present")
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in invariants.RULES.values():
            print(f"{r.id}\n    {r.description}\n    why: {r.why}\n")
        return 0

    try:
        rules = invariants.resolve_rules(args.rules)
    except ValueError as e:
        ap.error(str(e))

    n = 0
    run_lint = bool(args.paths) or args.contracts is None
    if run_lint:
        findings = invariants.lint_paths(args.paths or None, rules)
        for f in findings:
            print(f.format())
        n += len(findings)

    contracts_dir = args.contracts
    if contracts_dir is None and not args.paths \
            and _DEFAULT_DRYRUN.is_dir():
        contracts_dir = _DEFAULT_DRYRUN
    if contracts_dir is not None:
        from .contract import dryrun_contract_findings
        jsons = sorted(Path(contracts_dir).glob("*.json"))
        if not jsons:
            print(f"{contracts_dir}: no dry-run JSONs to audit",
                  file=sys.stderr)
        for j in jsons:
            for msg in dryrun_contract_findings(j):
                print(msg)
                n += 1

    print(f"repro.analysis: {n} finding(s)", file=sys.stderr)
    return 1 if n else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:     # e.g. `... --list-rules | head`
        sys.exit(0)

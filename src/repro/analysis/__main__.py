"""CLI for the static-analysis passes.

Usage::

    python -m repro.analysis                    # lint src/repro AND
                                                # audit results/dryrun
    python -m repro.analysis path1.py dir2/     # lint specific paths
    python -m repro.analysis --rules batch-rng-in-sweep-path
    python -m repro.analysis --contracts results/dryrun
    python -m repro.analysis --obs results/obs  # schema-audit
                                                # committed obs
                                                # trace/metrics samples
    python -m repro.analysis --kernels          # Pallas kernel
                                                # contract verifier
    python -m repro.analysis --kernels fix1.py  # verify standalone
                                                # kernel files (their
                                                # own KERNELS registry)
    python -m repro.analysis --json             # machine-readable
                                                # findings (CI turns
                                                # these into GitHub
                                                # annotations)
    python -m repro.analysis --list-rules

Exit status is 0 when no findings, 1 otherwise — CI runs this on
every push.
"""
from __future__ import annotations

import argparse
import json as _json
import sys
from pathlib import Path

from . import invariants

# repo root when run from a source checkout (…/src/repro/analysis)
_REPO_ROOT = Path(__file__).resolve().parents[3]
_DEFAULT_DRYRUN = _REPO_ROOT / "results" / "dryrun"
_DEFAULT_OBS = _REPO_ROOT / "results" / "obs"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant linter + communication-contract "
                    "checker + Pallas kernel contract verifier for "
                    "the repro tree.")
    ap.add_argument(
        "paths", nargs="*", type=Path,
        help="files/directories to lint (default: the whole "
             "repro package); with --kernels, standalone kernel "
             "files to verify instead")
    ap.add_argument(
        "--rules", default="all",
        help="comma-separated rule ids, or 'all' (default)")
    ap.add_argument(
        "--contracts", metavar="DIR", type=Path, default=None,
        help="audit dry-run JSONs in DIR against freshly derived "
             "contracts (given alone, skips the lint pass); the "
             "no-argument invocation audits results/dryrun if present")
    ap.add_argument(
        "--obs", metavar="DIR", type=Path, default=None,
        help="schema-audit committed repro.obs trace/metrics JSONs in "
             "DIR (given alone, skips the lint pass); the no-argument "
             "invocation audits results/obs if present")
    ap.add_argument(
        "--kernels", action="store_true",
        help="run the Pallas kernel contract verifier over the "
             "kernels.ops registry (given alone, skips the lint and "
             "contract passes); with paths, verifies those files' "
             "own KERNELS registries instead of linting them")
    ap.add_argument(
        "--json", action="store_true",
        help="emit findings as one JSON object on stdout "
             "({findings: [{path, line, rule, message, hint}], "
             "count}) instead of text lines")
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in invariants.RULES.values():
            print(f"{r.id}\n    {r.description}\n    why: {r.why}\n")
        return 0

    try:
        rules = invariants.resolve_rules(args.rules)
    except ValueError as e:
        ap.error(str(e))

    findings = []           # Finding objects
    contract_msgs = []      # plain strings from the contract audit
    obs_msgs = []           # plain strings from the obs schema audit

    if args.kernels:
        from . import kernelcheck
        if args.paths:
            findings.extend(
                kernelcheck.check_kernel_paths(args.paths, rules))
        else:
            findings.extend(kernelcheck.check_kernels(rules=rules))
    else:
        run_lint = bool(args.paths) or (
            args.contracts is None and args.obs is None)
        if run_lint:
            findings.extend(
                invariants.lint_paths(args.paths or None, rules))

        contracts_dir = args.contracts
        if contracts_dir is None and not args.paths \
                and args.obs is None and _DEFAULT_DRYRUN.is_dir():
            contracts_dir = _DEFAULT_DRYRUN
        if contracts_dir is not None:
            from .contract import dryrun_contract_findings
            jsons = sorted(Path(contracts_dir).glob("*.json"))
            if not jsons:
                print(f"{contracts_dir}: no dry-run JSONs to audit",
                      file=sys.stderr)
            for j in jsons:
                for msg in dryrun_contract_findings(j):
                    contract_msgs.append((j, msg))

        obs_dir = args.obs
        if obs_dir is None and not args.paths \
                and args.contracts is None and _DEFAULT_OBS.is_dir():
            obs_dir = _DEFAULT_OBS
        if obs_dir is not None:
            from .obsschema import obs_schema_findings
            jsons = sorted(Path(obs_dir).glob("*.json"))
            if not jsons:
                print(f"{obs_dir}: no obs JSONs to audit",
                      file=sys.stderr)
            for j in jsons:
                for msg in obs_schema_findings(j):
                    obs_msgs.append((j, msg))

    n = len(findings) + len(contract_msgs) + len(obs_msgs)
    if args.json:
        recs = [{"path": f.path, "line": f.line, "rule": f.rule,
                 "message": f.message, "hint": f.hint}
                for f in findings]
        recs += [{"path": str(j), "line": 0, "rule": "dryrun-contract",
                  "message": msg,
                  "hint": "regenerate via python -m "
                          "repro.launch.mf_dryrun"}
                 for j, msg in contract_msgs]
        recs += [{"path": str(j), "line": 0, "rule": "obs-schema",
                  "message": msg,
                  "hint": "regenerate via python "
                          "scripts_dev/gen_obs_samples.py"}
                 for j, msg in obs_msgs]
        print(_json.dumps({"findings": recs, "count": n}, indent=1))
    else:
        for f in findings:
            print(f.format())
        for _, msg in contract_msgs:
            print(msg)
        for _, msg in obs_msgs:
            print(msg)

    print(f"repro.analysis: {n} finding(s)", file=sys.stderr)
    return 1 if n else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:     # e.g. `... --list-rules | head`
        sys.exit(0)

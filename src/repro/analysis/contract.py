"""Declarative communication contracts for the sharded Gibbs sweep.

A :class:`CommContract` states, per compiled sweep step, exactly what
may cross the interconnect — the limited-communication guarantee of
the subset-parallel MCMC literature (arXiv:2004.02561) that PR 4's
ring pipeline made exact:

* ``all_gathers``       — full-factor gathers per sweep: one per
                          entity in eager mode, **zero** in ring mode;
* ``collective_permutes`` — ring hops: ``E * (S - 1)`` in ring mode;
* ``all_reduces``       — hyper-moment + metric psums: per entity
                          2 (Normal), 4 (Macau), 2 (SpikeAndSlab),
                          0 (FixedNormal), plus 2 scalar psums per
                          block (SSE, nnz);
* ``max_reduce_elems``  — largest all-reduce payload in elements
                          (K² Normal/Wishart moments, max(K², D·K)
                          Macau, K SpikeAndSlab) — this is the pin
                          that keeps e.g. the Macau FtF (D×D) product
                          hoisted out of the psum;
* ``wire_dtype``        — exchange dtype on gather/permute wires
                          (``bf16`` when ``ModelDef.bf16_gather``);
* ``chains``            — chains swept PER SHARD GROUP per step call
                          (``distributed.make_multi_chain_step`` maps
                          the per-chain sweep with ``lax.map``): every
                          count above is the total across those local
                          chains, while per-op payloads are unchanged
                          (each chain runs its own psums).  With a
                          chain mesh axis the chains spread over it,
                          so the local multiplier drops to
                          ``C / axis_size`` and the row-shard count
                          ``S`` shrinks to the per-chain shard group.

:func:`contract_for` *derives* the contract from any ``ModelDef`` —
no per-model pins — and the two checkers verify it against StableHLO
(exact op counts before backend scheduling) and compiled HLO (via
:func:`repro.launch.hlo_cost.parse_module`, trip-count-aware so
scan-rolled rings at 256 shards count correctly; all-reduce *counts*
are not checked on compiled HLO because backends may legally combine
payloads, but payload *sizes* are).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.distributed import RING_UNROLL_MAX, resolve_pipeline
from ..core.priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                           SpikeAndSlabPrior)
from ..launch.hlo_cost import COLLECTIVES, _called, _trip_count, \
    op_kind, parse_module


class ContractViolation(AssertionError):
    """Raised by :func:`assert_contract` with one line per violation."""


@dataclasses.dataclass(frozen=True)
class CommContract:
    pipeline: str
    n_shards: int
    all_gathers: int            # full-factor gathers per sweep
    collective_permutes: int    # ring hops per sweep
    all_reduces: int            # hyper-moment + metric psums
    max_reduce_elems: int       # largest all-reduce payload (elems)
    wire_dtype: str             # "f32" | "bf16" on gather/permute
    chains: int = 1             # local chains per shard group; the
    #                             counts above are totals across them

    def asdict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _prior_reduce_profile(prior) -> Tuple[int, int]:
    """(all-reduce count, max payload elems) for one entity's hyper
    moments, as emitted by ``distributed._psum_hyper``."""
    K = getattr(prior, "num_latent", 0)
    if isinstance(prior, MacauPrior):
        D = prior.num_features
        # sum_U (K), moment (K,K), side moment (D,K), side norm (D)
        return 4, max(K * K, D * K, D, K)
    if isinstance(prior, SpikeAndSlabPrior):
        return 2, K                    # slab mass (K) + counts (K)
    if isinstance(prior, FixedNormalPrior):
        return 0, 0                    # no hypers to resample
    if isinstance(prior, NormalPrior):
        return 2, K * K                # sum_U (K) + moment (K,K)
    raise ValueError(
        f"no communication profile for prior {type(prior).__name__}; "
        "supported priors: "
        + ", ".join(sorted(c.__name__ for c in (
            NormalPrior, MacauPrior, SpikeAndSlabPrior,
            FixedNormalPrior))))


def contract_for(model, mesh_shape: Sequence[int],
                 pipeline: Optional[str] = "eager",
                 chains: int = 1,
                 chain_axis_size: Optional[int] = None) -> CommContract:
    """Derive the expected communication contract for one sweep of
    ``model`` sharded over ``mesh_shape`` under ``pipeline``.

    Pure arithmetic over the ModelDef — E entities, M blocks,
    S = prod(mesh_shape) shards — so it needs no devices and works
    for any model the builder can express.

    ``chains=C`` (``make_multi_chain_step``): every shard sweeps its
    local chains serially (``lax.map``), so collective COUNTS scale by
    the local chain multiplier while per-op payloads stay fixed.
    ``chain_axis_size`` declares that ``mesh_shape`` includes a chain
    mesh axis of that size: rows then shard over only the remaining
    ``S = prod(mesh_shape) / chain_axis_size`` devices and each shard
    group sweeps ``C / chain_axis_size`` chains — how chains x shards
    fills a pod without inflating the per-group census.
    """
    pipeline = resolve_pipeline(pipeline)
    n_shards = math.prod(mesh_shape)
    chains = int(chains)
    if chains < 1:
        raise ValueError(f"chains must be >= 1, got {chains}")
    if chain_axis_size is not None:
        if n_shards % chain_axis_size:
            raise ValueError(
                f"chain_axis_size={chain_axis_size} does not divide "
                f"the {n_shards}-device mesh {tuple(mesh_shape)}")
        if chains % chain_axis_size:
            raise ValueError(
                f"chains={chains} does not divide over a chain axis "
                f"of size {chain_axis_size}")
        n_shards //= chain_axis_size
        local = chains // chain_axis_size
    else:
        local = chains
    E, M = len(model.entities), len(model.blocks)
    ar, elems = 0, 0
    for ent in model.entities:
        n, e = _prior_reduce_profile(ent.prior)
        ar += n
        elems = max(elems, e)
    ar += 2 * M                        # SSE + nnz scalars per block
    elems = max(elems, 1) if ar else elems
    if pipeline == "ring":
        ag, cp = 0, E * (n_shards - 1)
    else:
        ag, cp = E, 0
    return CommContract(
        pipeline=pipeline, n_shards=n_shards, all_gathers=ag * local,
        collective_permutes=cp * local, all_reduces=ar * local,
        max_reduce_elems=elems,
        wire_dtype="bf16" if model.bf16_gather else "f32",
        chains=local)


def contract_wire_bytes(model, contract: CommContract) -> int:
    """Estimated bytes RECEIVED per device per sweep under ``contract``.

    The number the obs subsystem stamps into every sweep span
    (``args.bytes_on_wire``), so traces carry the expected collective
    volume next to the measured wall time.  Derivation, per shard:

    * fixed-factor exchange — eager all-gather and ring ppermute move
      the same total: each entity's full factor minus the shard's own
      rows, ``n_rows * K * itemsize * (S-1)/S``, once per local chain;
    * all-reduces — ``all_reduces`` ops (already scaled by local
      chains) of at most ``max_reduce_elems`` f32 elements each, ring
      cost ``(S-1)/S`` per pass (one-pass estimate: an upper bound on
      payload, a lower bound on passes — collectives on real fabrics
      are within a small factor either way).

    ``S == 1`` (or no mesh) → 0: nothing crosses a wire.
    """
    S = contract.n_shards
    if S <= 1:
        return 0
    frac = (S - 1) / S
    item = 2 if contract.wire_dtype == "bf16" else 4
    fixed_elems = sum(e.n_rows * model.num_latent
                      for e in model.entities)
    exchange = fixed_elems * item * frac * contract.chains
    reduces = contract.all_reduces * contract.max_reduce_elems * 4 * frac
    return int(exchange + reduces)


# ---------------------------------------------------------------------------
# StableHLO check (pre-backend: exact op counts)
# ---------------------------------------------------------------------------

def check_lowered(contract: CommContract, text: str) -> List[str]:
    """Verify a StableHLO module (``lowered.as_text()``) against the
    contract.  Counts are exact here — nothing has been combined or
    split yet.  Note: ring pipelines above ``RING_UNROLL_MAX`` shards
    lower to ``stablehlo.while`` loops; use :func:`check_compiled`
    (trip-count-aware) for those.

    A multi-chain sweep (``contract.chains > 1``) ``lax.map``-rolls
    the per-chain body into ONE ``stablehlo.while``, so the text holds
    per-iteration counts — the contract's totals divided by the local
    chain count (compiled HLO recovers the trip count and checks the
    totals directly).
    """
    lines = text.splitlines()
    ag = [ln for ln in lines if "stablehlo.all_gather" in ln]
    cp = [ln for ln in lines if "stablehlo.collective_permute" in ln]
    ar = sum(ln.count("stablehlo.all_reduce") for ln in lines)
    rolled_ring = (contract.pipeline == "ring"
                   and contract.n_shards > RING_UNROLL_MAX)
    local = max(1, contract.chains)
    out: List[str] = []
    if len(ag) * local != contract.all_gathers:
        out.append(f"stablehlo: {len(ag)} all-gathers per chain "
                   f"iteration, contract says "
                   f"{contract.all_gathers} across {local} chain(s)")
    if not rolled_ring and len(cp) * local \
            != contract.collective_permutes:
        out.append(f"stablehlo: {len(cp)} collective-permutes per "
                   f"chain iteration, contract says "
                   f"{contract.collective_permutes} across {local} "
                   "chain(s)")
    if ar * local != contract.all_reduces:
        out.append(f"stablehlo: {ar} all-reduces per chain iteration, "
                   f"contract says {contract.all_reduces} across "
                   f"{local} chain(s)")
    want_bf16 = contract.wire_dtype == "bf16"
    for ln in ag + cp:
        if ("bf16" in ln) != want_bf16:
            out.append("stablehlo: exchange wire is not "
                       f"{contract.wire_dtype}: {ln.strip()[:100]}")
    return out


# ---------------------------------------------------------------------------
# compiled-HLO check (post-SPMD: trip-count-aware, parse_module-based)
# ---------------------------------------------------------------------------

def _collect_collectives(text: str):
    """Trip-count-aware collective census over compiled HLO text:
    ``({kind: count}, {kind: max payload elems})``.  Built on
    ``hlo_cost.parse_module`` — async ``-start``/``-done`` pairs are
    counted once, ``while`` bodies multiply by the recovered trip
    count (how a scan-rolled ring at S=256 still counts E*(S-1))."""
    comps = parse_module(text)
    cache: Dict[str, Tuple[Dict[str, float], Dict[str, int]]] = {}

    def merge(counts, elems, sub, mult=1):
        sc, se = sub
        for k, v in sc.items():
            counts[k] = counts.get(k, 0) + mult * v
        for k, v in se.items():
            elems[k] = max(elems.get(k, 0), v)

    def visit(name: str):
        if name in cache:
            return cache[name]
        counts: Dict[str, float] = {}
        elems: Dict[str, int] = {}
        cache[name] = (counts, elems)   # guards (impossible) cycles
        for ins in comps.get(name, []):
            kind = op_kind(ins.op)
            if kind in COLLECTIVES and not ins.op.endswith("-done"):
                counts[kind] = counts.get(kind, 0) + 1
                m = max((s.elems for s in ins.shapes), default=0)
                elems[kind] = max(elems.get(kind, 0), m)
            if ins.op == "while":
                mt = re.search(
                    r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"',
                    ins.attrs)
                trip = int(mt.group(1)) if mt else None
                cond = _called(ins.attrs, "condition")
                if trip is None and cond and cond in comps:
                    trip = _trip_count(comps[cond])
                trip = trip if trip else 1
                for key in ("body", "condition"):
                    callee = _called(ins.attrs, key)
                    if callee:
                        merge(counts, elems, visit(callee), trip)
            elif ins.op == "fusion":
                callee = _called(ins.attrs, "calls")
                if callee:
                    merge(counts, elems, visit(callee))
            elif ins.op in ("call", "async-start"):
                callee = _called(ins.attrs, "calls") or \
                    _called(ins.attrs, "to_apply")
                if callee:
                    merge(counts, elems, visit(callee))
            elif ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    callee = _called(ins.attrs, key)
                    if callee:
                        merge(counts, elems, visit(callee))
        return counts, elems

    entry = "ENTRY" if "ENTRY" in comps else next(iter(comps), None)
    if entry is None:
        return {}, {}
    return visit(entry)


def check_compiled(contract: CommContract, text: str) -> List[str]:
    """Verify compiled HLO (``compiled.as_text()``) against the
    contract.  all-gather / collective-permute counts are exact (trip
    multiplied); all-reduce payload sizes are bounded by
    ``max_reduce_elems`` (counts may legally differ — backends
    combine psums)."""
    counts, elems = _collect_collectives(text)
    out: List[str] = []
    n_ag = int(counts.get("all-gather", 0))
    n_cp = int(counts.get("collective-permute", 0))
    if n_ag != contract.all_gathers:
        out.append(f"compiled: {n_ag} all-gathers, contract says "
                   f"{contract.all_gathers}")
    if n_cp != contract.collective_permutes:
        out.append(f"compiled: {n_cp} collective-permutes, contract "
                   f"says {contract.collective_permutes}")
    got = elems.get("all-reduce", 0)
    if got > contract.max_reduce_elems:
        out.append(f"compiled: all-reduce payload of {got} elems "
                   f"exceeds contract max {contract.max_reduce_elems}"
                   " (a full-matrix product leaked into a psum?)")
    return out


def assert_contract(contract: CommContract,
                    lowered_text: Optional[str] = None,
                    compiled_text: Optional[str] = None,
                    where: str = "") -> None:
    """Raise :class:`ContractViolation` listing every violation of
    ``contract`` in the given StableHLO and/or compiled HLO text."""
    out: List[str] = []
    if lowered_text is not None:
        out.extend(check_lowered(contract, lowered_text))
    if compiled_text is not None:
        out.extend(check_compiled(contract, compiled_text))
    if out:
        head = f"{where}: " if where else ""
        raise ContractViolation(
            head + f"{len(out)} contract violation(s) against "
            f"{contract}\n  " + "\n  ".join(out))


# ---------------------------------------------------------------------------
# dry-run JSON audit (CI: results/dryrun/*.json carry their contract)
# ---------------------------------------------------------------------------

def dryrun_contract_findings(json_path) -> List[str]:
    """Audit one dry-run record: its stored ``contract`` column must
    match a freshly derived ``contract_for`` and its generation-time
    HLO check must have passed.  Imports ``mf_dryrun`` lazily (the
    module pins a 512-device host platform via XLA_FLAGS at import —
    harmless here, no devices are materialized)."""
    p = Path(json_path)
    rec = json.loads(p.read_text())
    if "error" in rec:
        return [f"{p}: dry-run record is an error record"]
    out: List[str] = []
    if "contract" not in rec:
        return [f"{p}: missing contract column — regenerate with "
                "`python -m repro.launch.mf_dryrun`"]
    from ..launch.mf_dryrun import CELLS, build_model
    arch = rec.get("arch", "")
    name = arch[3:] if arch.startswith("mf_") else arch
    if name not in CELLS:
        return [f"{p}: unknown cell {name!r}; valid cells: "
                f"{', '.join(sorted(CELLS))}"]
    model = build_model(CELLS[name], rec.get("variant", "baseline"))
    mesh_shape = tuple(int(x) for x in rec["mesh"].split("x"))
    derived = contract_for(
        model, mesh_shape, rec.get("pipeline", "eager"),
        chains=rec.get("chains", 1),
        chain_axis_size=rec.get("chain_axis_size")).asdict()
    # records written before the multi-chain column default to one
    # chain per shard group
    stored = {"chains": 1, **rec["contract"]}
    for k, v in derived.items():
        if stored.get(k) != v:
            out.append(f"{p}: contract[{k!r}] = {stored.get(k)!r} "
                       f"but derivation says {v!r}")
    if not rec.get("contract_ok", False):
        out.append(f"{p}: contract_ok is not true — the compiled "
                   "HLO violated its contract at generation time: "
                   f"{rec.get('contract_violations')}")
    out.extend(_kernel_vmem_findings(p, rec))
    return out


def _kernel_vmem_findings(p: Path, rec: dict) -> List[str]:
    """Audit the ``kernel_vmem`` column (PR 8): the per-kernel VMEM
    estimates baked into the record must match a fresh
    ``kernelcheck.vmem_report`` over the shipped registry (memoized —
    one capture pass covers all audited JSONs) and every kernel must
    be inside its budget."""
    out: List[str] = []
    if "kernel_vmem" not in rec:
        return [f"{p}: missing kernel_vmem column — regenerate with "
                "`python -m repro.launch.mf_dryrun`"]
    from .kernelcheck import vmem_report
    fresh = vmem_report()
    stored = rec["kernel_vmem"]
    for name, want in fresh.items():
        got = stored.get(name)
        if got is None:
            out.append(f"{p}: kernel_vmem missing kernel {name!r}")
            continue
        for k in ("peak_bytes", "budget_bytes", "ok"):
            if got.get(k) != want[k]:
                out.append(
                    f"{p}: kernel_vmem[{name!r}][{k!r}] = "
                    f"{got.get(k)!r} but a fresh estimate says "
                    f"{want[k]!r}")
    if not rec.get("kernel_vmem_ok", False):
        out.append(f"{p}: kernel_vmem_ok is not true — a kernel "
                   "blew its VMEM budget at generation time")
    return out

"""Static Pallas kernel contract verifier.

The CPU container only ever runs the kernels in ``kernels/`` in
interpret mode, so grid races, out-of-bounds index maps, and VMEM
overflows would surface for the first time on real TPU hardware.  This
pass closes that gap **without a TPU**: it discovers every
``pl.pallas_call`` site through the :data:`repro.kernels.ops.KERNELS`
registry, concretely enumerates each kernel's grid over its shipped
block-size configurations (the registry probes), and checks four
contracts per kernel:

``kernel-output-race``
    Every output block index is produced by exactly one grid point,
    or — for revisit-accumulate patterns (e.g. ``gram``'s
    ``(r, 0, 0)`` output revisited across ``t``) — the revisited
    output/scratch is provably initialized at the first visit
    (``@pl.when(t == 0)`` guard detected from the kernel AST) before
    any read-modify-write.
``kernel-block-out-of-bounds``
    Every input/output index map stays inside the padded operand
    shape for ALL grid points, uneven tails included — because probes
    drive the public wrappers, the shared ``ops.pad_to_blocks``
    arithmetic is verified as part of the same enumeration.
``kernel-accum-dtype``
    Contractions carry ``preferred_element_type=jnp.float32`` and
    every across-grid accumulator (output or scratch) is fp32 — the
    contract ``topk_score`` and ``gram`` honor so bf16/fp16 operands
    never accumulate in low precision.
``kernel-vmem-budget``
    Per-grid-step resident bytes (double-buffered block tiles +
    scratch) estimated and bounded against the registry's per-kernel
    budget.  :func:`vmem_report` records the estimate into every
    ``results/dryrun/*.json`` (audited by
    ``contract.dryrun_contract_findings``).

How capture works
-----------------
Unlike :mod:`.invariants` (pure AST, never imports), this pass *does*
import the kernel modules: it monkey-patches
``jax.experimental.pallas.pallas_call`` with a recording shim and
traces each probe with ``jax.eval_shape`` — so the grids, BlockSpecs,
index maps, scratch shapes, and padded operand shapes it checks are
exactly the shipped ones, with zero re-declaration drift.  The guard
analysis (``@pl.when``) and dtype checks then run on the kernel
function's AST.  Jitted entry points are cache-cleared around the
capture (a cached real trace would skip ``pallas_call``; a cached
capture trace would poison later real calls).

Findings use the PR 6 format (file:line, rule id, fix hint) and honor
``# repro-lint: disable=<rule>`` suppressions.  Fixture files under
``tests/fixtures/analysis/kernel_bad_*.py`` carry their own
``KERNELS`` registry and are checked via :func:`check_kernel_paths`.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import importlib.util
import inspect
import itertools
import math
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from . import invariants
from .invariants import Finding

# rule ids; registered into invariants.RULES below so --list-rules /
# --rules compose (their per-file lint check is a no-op — the real
# logic needs the registry and runs through check_kernels)
RACE = "kernel-output-race"
BOUNDS = "kernel-block-out-of-bounds"
DTYPE = "kernel-accum-dtype"
VMEM = "kernel-vmem-budget"
KERNEL_RULE_IDS = (RACE, BOUNDS, DTYPE, VMEM)


def _noop_rule(ctx):
    return ()


invariants.rule(
    RACE,
    "every Pallas output block is written by exactly one grid point, "
    "or revisit-accumulate with a @pl.when(t == 0) first-visit init",
    "PR 8: the CPU container never executes the compiled grid, so an "
    "uninitialized revisited accumulator or a doubly-written block "
    "would surface for the first time on real TPU hardware",
)(_noop_rule)
invariants.rule(
    BOUNDS,
    "every Pallas index map stays inside the padded operand shape for "
    "all grid points (uneven tails included)",
    "PR 8: block-index arithmetic against ops.pad_to_blocks padding "
    "is enumerated concretely — an off-by-one tail reads garbage (or "
    "faults) only on hardware",
)(_noop_rule)
invariants.rule(
    DTYPE,
    "kernel contractions carry preferred_element_type=jnp.float32 and "
    "across-grid accumulators are fp32",
    "PR 8: bf16 operands must accumulate in fp32 (the contract gram/"
    "topk_score honor); a bf16 accumulator loses the posterior mean "
    "at catalogue scale",
)(_noop_rule)
invariants.rule(
    VMEM,
    "per-grid-step resident bytes (double-buffered block tiles + "
    "scratch) stay under the kernel's registry VMEM budget",
    "PR 8: ~16 MB of VMEM per core; an over-budget block config "
    "compiles fine in interpret mode and OOMs only on the TPU",
)(_noop_rule)


# ---------------------------------------------------------------------------
# capture: record every pl.pallas_call a probe trace reaches
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PallasCapture:
    """One recorded ``pl.pallas_call`` site, fully concrete."""
    kernel_name: str
    src_path: str                  # file defining the kernel function
    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    out_shapes: List[Any]          # ShapeDtypeStructs
    operands: Tuple[Any, ...]      # padded ShapeDtypeStructs
    scratch: List[Tuple[Tuple[int, ...], Any]]   # (shape, dtype)
    probe_label: str = ""


def _as_list(x) -> List[Any]:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _unwrap_kernel(kernel):
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    return kernel


def _clear(fns) -> None:
    for fn in fns:
        clear = getattr(fn, "clear_cache", None)
        if callable(clear):
            clear()


def capture_probe(probe) -> List[PallasCapture]:
    """Trace one registry probe under a recording ``pallas_call`` shim
    and return every call site it reached."""
    import jax
    import jax.numpy as jnp
    # the capture works by patching the very module the kernels call
    # into — this is the one justified use outside compat.py/kernels/
    import jax.experimental.pallas as plmod  # repro-lint: disable=experimental-import-outside-compat

    caps: List[PallasCapture] = []
    orig = plmod.pallas_call

    def shim(kernel, **kw):
        kfn = _unwrap_kernel(kernel)
        scratch = []
        for s in kw.get("scratch_shapes") or ():
            scratch.append((tuple(getattr(s, "shape", ())),
                            jnp.dtype(getattr(s, "dtype", jnp.float32))))
        cap = PallasCapture(
            kernel_name=kfn.__name__,
            src_path=inspect.getsourcefile(kfn) or "<unknown>",
            grid=tuple(int(g) for g in _as_list(kw.get("grid"))),
            in_specs=_as_list(kw.get("in_specs")),
            out_specs=_as_list(kw.get("out_specs")),
            out_shapes=_as_list(kw.get("out_shape")),
            operands=(), scratch=scratch,
            probe_label=probe.label)
        caps.append(cap)
        single_out = not isinstance(kw.get("out_shape"), (list, tuple))

        def run(*operands):
            cap.operands = tuple(
                jax.ShapeDtypeStruct(jnp.shape(o), o.dtype)
                for o in operands)
            outs = [jnp.zeros(s.shape, s.dtype) for s in cap.out_shapes]
            return outs[0] if single_out else type(
                kw["out_shape"])(outs)

        return run

    plmod.pallas_call = shim
    try:
        # trace through a FRESH wrapper: jax.eval_shape keys its trace
        # cache on the function object, so re-tracing probe.call
        # directly would silently hit a cached trace and skip the shim
        jax.eval_shape(lambda *a: probe.call(*a), *probe.args)
    finally:
        plmod.pallas_call = orig
    return caps


def capture_spec(spec) -> List[PallasCapture]:
    """All captures for one registry entry (cache-cleared around each
    probe so stale jit traces neither skip nor poison the capture)."""
    caps: List[PallasCapture] = []
    for probe in spec.probes:
        _clear(spec.jit_fns)
        try:
            caps.extend(capture_probe(probe))
        finally:
            _clear(spec.jit_fns)
    return caps


# ---------------------------------------------------------------------------
# kernel-function AST analysis: program ids, guarded writes/reads, dots
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Access:
    line: int
    kind: str            # "assign" | "aug" | "read"
    guard: Tuple[str, Optional[int]]   # (class, grid axis) — class in
    #                     {"eq0","ne0","eq","other","none"}


class KernelAst:
    """Guard-aware access analysis of one kernel function."""

    def __init__(self, path: str, fn_name: str):
        self.path = path
        src = Path(path).read_text()
        self.fn: Optional[ast.FunctionDef] = None
        for node in ast.walk(ast.parse(src)):
            if isinstance(node, ast.FunctionDef) and \
                    node.name == fn_name:
                self.fn = node
                break
        self.params: List[str] = []
        self.pid_axes: Dict[str, int] = {}     # var -> grid axis
        self.access: Dict[str, List[_Access]] = {}
        self.dots: List[Tuple[int, bool]] = []  # (line, has f32 pref)
        self.pallas_line = 1
        if self.fn is None:
            return
        a = self.fn.args
        self.params = [p.arg for p in a.posonlyargs + a.args]
        self._when_calls = self._collect_when_calls(self.fn)
        self._collect_program_ids(self.fn)
        self._walk(self.fn, ("none", None))

    # -- collection helpers -------------------------------------------------

    @staticmethod
    def _is_when(call: ast.AST) -> Optional[ast.expr]:
        """``pl.when(cond)`` (or bare ``when(cond)``) -> cond."""
        if not (isinstance(call, ast.Call) and call.args):
            return None
        f = call.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else "")
        return call.args[0] if name == "when" else None

    def _collect_when_calls(self, fn) -> Dict[str, ast.expr]:
        """``pl.when(cond)(inner)`` call-style guards: name -> cond."""
        out: Dict[str, ast.expr] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and len(node.args) == 1 and \
                    isinstance(node.args[0], ast.Name):
                cond = self._is_when(node.func)
                if cond is not None:
                    out[node.args[0].id] = cond
        return out

    def _collect_program_ids(self, fn) -> None:
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                continue
            f = node.value.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name == "program_id" and node.value.args and \
                    isinstance(node.value.args[0], ast.Constant):
                self.pid_axes[node.targets[0].id] = \
                    int(node.value.args[0].value)

    def _classify(self, cond: ast.expr) -> Tuple[str, Optional[int]]:
        """Map a ``pl.when`` condition onto (class, grid axis)."""
        involved = sorted({self.pid_axes[n.id]
                           for n in ast.walk(cond)
                           if isinstance(n, ast.Name)
                           and n.id in self.pid_axes})
        axis = involved[-1] if involved else None
        if isinstance(cond, ast.Compare) and len(cond.ops) == 1:
            lhs, rhs = cond.left, cond.comparators[0]
            var, other = (lhs, rhs) if isinstance(lhs, ast.Name) \
                else (rhs, lhs)
            if isinstance(var, ast.Name) and var.id in self.pid_axes:
                ax = self.pid_axes[var.id]
                zero = isinstance(other, ast.Constant) and \
                    other.value == 0
                if isinstance(cond.ops[0], ast.Eq):
                    return ("eq0" if zero else "eq"), ax
                if isinstance(cond.ops[0], ast.NotEq) and zero:
                    return "ne0", ax
        return ("other" if axis is not None else "none"), axis

    # -- guarded walk -------------------------------------------------------

    def _walk(self, node: ast.AST, guard) -> None:
        for child in ast.iter_child_nodes(node):
            g = guard
            if isinstance(child, ast.FunctionDef) and child is not self.fn:
                cond = None
                for deco in child.decorator_list:
                    cond = self._is_when(deco)
                    if cond is not None:
                        break
                if cond is None:
                    cond = self._when_calls.get(child.name)
                g = self._classify(cond) if cond is not None else guard
            self._record(child, g)
            self._walk(child, g)

    def _record(self, node: ast.AST, guard) -> None:
        def ref_of(target) -> Optional[str]:
            if isinstance(target, ast.Subscript) and \
                    isinstance(target.value, ast.Name) and \
                    target.value.id in self.params:
                return target.value.id
            return None

        if isinstance(node, ast.Assign):
            for t in node.targets:
                name = ref_of(t)
                if name:
                    self.access.setdefault(name, []).append(
                        _Access(node.lineno, "assign", guard))
        elif isinstance(node, ast.AugAssign):
            name = ref_of(node.target)
            if name:
                self.access.setdefault(name, []).append(
                    _Access(node.lineno, "aug", guard))
        elif isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, ast.Load):
            if isinstance(node.value, ast.Name) and \
                    node.value.id in self.params:
                self.access.setdefault(node.value.id, []).append(
                    _Access(node.lineno, "read", guard))
        elif isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name in ("dot_general", "einsum", "dot", "matmul"):
                pref = any(
                    kw.arg == "preferred_element_type"
                    and "float32" in ast.unparse(kw.value)
                    for kw in node.keywords)
                self.dots.append((node.lineno, pref))


def _pallas_call_line(path: str, kernel_name: str) -> int:
    """Line of the ``pl.pallas_call`` site referencing ``kernel_name``
    in ``path`` (anchor for VMEM findings)."""
    try:
        tree = ast.parse(Path(path).read_text())
    except (OSError, SyntaxError):
        return 1
    fallback = 0
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name != "pallas_call":
                continue
            fallback = fallback or node.lineno
            if node.args and kernel_name in ast.unparse(node.args[0]):
                return node.lineno
    return fallback or 1


# ---------------------------------------------------------------------------
# the four contract checks over one capture
# ---------------------------------------------------------------------------

def _block_index(spec, gp) -> Tuple[int, ...]:
    return tuple(int(i) for i in spec.index_map(*gp))


def _spec_line(spec, default: int) -> int:
    code = getattr(spec.index_map, "__code__", None)
    return code.co_firstlineno if code is not None else default


def _revisit_axes(spec, grid) -> List[int]:
    """Grid axes whose variation leaves the output block index fixed
    (probed at unit steps — all shipped index maps are affine)."""
    origin = _block_index(spec, (0,) * len(grid))
    out = []
    for a, n in enumerate(grid):
        if n < 2:
            continue
        probe = [0] * len(grid)
        probe[a] = 1
        if _block_index(spec, tuple(probe)) == origin:
            out.append(a)
    return out


def _check_race(cap: PallasCapture, ka: KernelAst) -> Iterable[Finding]:
    grid_pts = list(itertools.product(*(range(g) for g in cap.grid)))
    n_in, n_out = len(cap.in_specs), len(cap.out_shapes)

    def ref_name(pos: int) -> str:
        return ka.params[pos] if pos < len(ka.params) else f"<arg{pos}>"

    # (ref name, minor revisit axis, anchor line) needing init analysis
    revisited: List[Tuple[str, int, int]] = []
    for j, (spec, oshape) in enumerate(zip(cap.out_specs,
                                           cap.out_shapes)):
        line = _spec_line(spec, 1)
        seen: Dict[Tuple[int, ...], int] = {}
        for gp in grid_pts:
            idx = _block_index(spec, gp)
            seen[idx] = seen.get(idx, 0) + 1
        nblocks = tuple(
            max(1, -(-s // b))
            for s, b in zip(oshape.shape, spec.block_shape))
        uncovered = next(
            (blk for blk in itertools.product(
                *(range(n) for n in nblocks)) if blk not in seen),
            None)
        if uncovered is not None:
            yield Finding(
                cap.src_path, line, RACE,
                f"output {j} of {cap.kernel_name} "
                f"[{cap.probe_label}]: block {uncovered} is never "
                "written by any grid point",
                "make the output index map cover every block of the "
                "padded output, or shrink the out_shape")
        counts = set(seen.values())
        if len(counts) > 1:
            yield Finding(
                cap.src_path, line, RACE,
                f"output {j} of {cap.kernel_name} "
                f"[{cap.probe_label}]: irregular grid coverage "
                f"(visit counts {sorted(counts)})",
                "the output index map must visit every block the "
                "same number of times — revisit axes must be "
                "independent of the block index")
        if counts and max(counts) > 1:
            axes = _revisit_axes(spec, cap.grid)
            if axes:
                revisited.append((ref_name(n_in + j), max(axes), line))
    # scratch accumulators persist across the minor grid axis
    if cap.scratch and len(cap.grid) > 0:
        minor = len(cap.grid) - 1
        if cap.grid[minor] > 1:
            for s in range(len(cap.scratch)):
                revisited.append(
                    (ref_name(n_in + n_out + s), minor, 1))

    for name, t_axis, line in revisited:
        acc = ka.access.get(name, [])
        writes = [a for a in acc if a.kind in ("assign", "aug")]
        rmw = [a for a in acc if a.kind in ("read", "aug")]
        if not writes:
            continue
        var = next((v for v, ax in ka.pid_axes.items()
                    if ax == t_axis), None)
        inits = [a for a in writes
                 if a.kind == "assign" and a.guard == ("eq0", t_axis)]
        if rmw:
            if var is None or not inits:
                first = min(rmw, key=lambda a: a.line)
                yield Finding(
                    cap.src_path, first.line, RACE,
                    f"{name} in {cap.kernel_name} is revisited across "
                    f"grid axis {t_axis} and read/accumulated without "
                    "a first-visit init",
                    "initialize under @pl.when(pl.program_id("
                    f"{t_axis}) == 0) before any read-modify-write "
                    "(the kernels/gram.py revisiting pattern)")
                continue
            init_line = min(i.line for i in inits)
            # accesses that can run at the first visit must follow the
            # init textually (ne0/eq-guarded ones never see t == 0)
            unsafe = [a for a in rmw
                      if a.guard not in (("ne0", t_axis),
                                         ("eq", t_axis))
                      and a.line < init_line]
            if unsafe:
                first = min(unsafe, key=lambda a: a.line)
                yield Finding(
                    cap.src_path, first.line, RACE,
                    f"{name} in {cap.kernel_name} is read before its "
                    f"@pl.when == 0 init (line {init_line})",
                    "move the first-visit init above every "
                    "read-modify-write of the revisited ref")
        else:
            unguarded = [a for a in writes
                         if a.guard[1] != t_axis
                         or a.guard[0] in ("other", "none")]
            if unguarded:
                first = min(unguarded, key=lambda a: a.line)
                yield Finding(
                    cap.src_path, first.line, RACE,
                    f"{name} in {cap.kernel_name} is overwritten on "
                    f"every revisit of grid axis {t_axis} (no guard "
                    "on the revisit axis)",
                    "guard the write on the revisit axis (e.g. "
                    "@pl.when(t == n_blocks - 1) for a final-visit "
                    "write, as kernels/flash.py does) or accumulate "
                    "with a first-visit init")


def _check_bounds(cap: PallasCapture) -> Iterable[Finding]:
    grid_pts = list(itertools.product(*(range(g) for g in cap.grid)))
    shapes = [o.shape for o in cap.operands] + \
        [o.shape for o in cap.out_shapes]
    specs = list(cap.in_specs) + list(cap.out_specs)
    kinds = [f"input {i}" for i in range(len(cap.in_specs))] + \
        [f"output {i}" for i in range(len(cap.out_specs))]
    for spec, shape, kind in zip(specs, shapes, kinds):
        bshape = tuple(spec.block_shape)
        line = _spec_line(spec, 1)
        if len(bshape) != len(shape):
            yield Finding(
                cap.src_path, line, BOUNDS,
                f"{kind} of {cap.kernel_name} [{cap.probe_label}]: "
                f"block rank {len(bshape)} != operand rank "
                f"{len(shape)}",
                "block shape and operand must have the same rank")
            continue
        ragged = [d for d, (s, b) in enumerate(zip(shape, bshape))
                  if s % b]
        if ragged:
            yield Finding(
                cap.src_path, line, BOUNDS,
                f"{kind} of {cap.kernel_name} [{cap.probe_label}]: "
                f"operand shape {tuple(shape)} is not a multiple of "
                f"block {bshape} on axes {ragged}",
                "pad the operand through ops.pad_to_blocks before "
                "the pallas_call (padding must carry an exact no-op "
                "value, e.g. mask 0)")
            continue
        for gp in grid_pts:
            idx = _block_index(spec, gp)
            oob = [d for d, (i, b, s) in
                   enumerate(zip(idx, bshape, shape))
                   if i < 0 or (i + 1) * b > s]
            if oob:
                yield Finding(
                    cap.src_path, line, BOUNDS,
                    f"{kind} of {cap.kernel_name} "
                    f"[{cap.probe_label}]: grid point {gp} maps to "
                    f"block index {idx}, outside operand shape "
                    f"{tuple(shape)} on axes {oob}",
                    "fix the index map or the grid arithmetic — the "
                    "grid must be padded_shape // block, with the "
                    "padding done by ops.pad_to_blocks")
                break


def _check_dtype(cap: PallasCapture, ka: KernelAst,
                 seen_dots: set) -> Iterable[Finding]:
    import jax.numpy as jnp
    for line, pref in ka.dots:
        if (cap.src_path, line) in seen_dots:
            continue
        seen_dots.add((cap.src_path, line))
        if not pref:
            yield Finding(
                cap.src_path, line, DTYPE,
                f"contraction in {cap.kernel_name} without "
                "preferred_element_type=jnp.float32",
                "pass preferred_element_type=jnp.float32 so bf16/f16 "
                "operands accumulate in fp32 on the MXU")
    # across-grid accumulators (revisited outputs / scratch with
    # read-modify-write) must be fp32 when floating
    n_in, n_out = len(cap.in_specs), len(cap.out_shapes)
    refs: List[Tuple[int, Any, bool]] = []       # (pos, dtype, revisited)
    for j, (spec, oshape) in enumerate(zip(cap.out_specs,
                                           cap.out_shapes)):
        revis = bool(_revisit_axes(spec, cap.grid))
        refs.append((n_in + j, oshape.dtype, revis))
    minor_revis = len(cap.grid) > 0 and cap.grid[-1] > 1
    for s, (_, dt) in enumerate(cap.scratch):
        refs.append((n_in + n_out + s, dt, minor_revis))
    for pos, dt, revis in refs:
        if not revis:
            continue
        name = ka.params[pos] if pos < len(ka.params) else f"<arg{pos}>"
        acc = ka.access.get(name, [])
        if not any(a.kind in ("read", "aug") for a in acc):
            continue
        if jnp.issubdtype(dt, jnp.floating) and \
                jnp.dtype(dt) != jnp.dtype(jnp.float32):
            first = min((a for a in acc if a.kind in ("aug", "assign")),
                        key=lambda a: a.line, default=None)
            yield Finding(
                cap.src_path, first.line if first else 1, DTYPE,
                f"{name} in {cap.kernel_name} accumulates across the "
                f"grid in {jnp.dtype(dt).name}",
                "accumulate in a float32 ref (out_shape / scratch) "
                "and cast once at the final visit, as "
                "kernels/flash.py does for its bf16 output")


def _step_bytes(cap: PallasCapture) -> Dict[str, int]:
    """Per-grid-step resident VMEM estimate: Pallas double-buffers
    every in/out block (pipeline prefetch), scratch is single."""
    import jax.numpy as jnp

    def nbytes(shape, dtype):
        return math.prod(shape) * jnp.dtype(dtype).itemsize

    blocks = 0
    for spec, op in zip(cap.in_specs, cap.operands):
        blocks += nbytes(tuple(spec.block_shape), op.dtype)
    for spec, out in zip(cap.out_specs, cap.out_shapes):
        blocks += nbytes(tuple(spec.block_shape), out.dtype)
    scratch = sum(nbytes(s, d) for s, d in cap.scratch)
    return {"block_bytes": blocks, "scratch_bytes": scratch,
            "peak_bytes": 2 * blocks + scratch}


def _check_vmem(cap: PallasCapture, budget: int) -> Iterable[Finding]:
    est = _step_bytes(cap)
    if est["peak_bytes"] > budget:
        yield Finding(
            cap.src_path,
            _pallas_call_line(cap.src_path, cap.kernel_name), VMEM,
            f"{cap.kernel_name} [{cap.probe_label}]: estimated "
            f"{est['peak_bytes']} resident bytes per grid step "
            f"(2x{est['block_bytes']} double-buffered blocks + "
            f"{est['scratch_bytes']} scratch) exceeds the "
            f"{budget}-byte budget",
            "shrink the block sizes (the minor-axis tile is usually "
            "the lever) or raise the kernel's vmem_budget in the "
            "KERNELS registry with a measured justification")


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _rule_ids(rules) -> set:
    if rules is None:
        return set(KERNEL_RULE_IDS)
    ids = {getattr(r, "id", r) for r in rules}
    return ids & set(KERNEL_RULE_IDS)


def check_spec(spec, rules=None) -> List[Finding]:
    """Run the four contract checks over one registry entry."""
    want = _rule_ids(rules)
    if not want:
        return []
    findings: List[Finding] = []
    seen_dots: set = set()
    asts: Dict[Tuple[str, str], KernelAst] = {}
    for cap in capture_spec(spec):
        key = (cap.src_path, cap.kernel_name)
        if key not in asts:
            asts[key] = KernelAst(*key)
        ka = asts[key]
        if ka.fn is None:
            findings.append(Finding(
                cap.src_path, 1, RACE,
                f"kernel function {cap.kernel_name} not found in "
                "source — guard analysis impossible",
                "define the kernel as a module-level def in the file "
                "that issues its pallas_call"))
            continue
        if RACE in want:
            findings.extend(_check_race(cap, ka))
        if BOUNDS in want:
            findings.extend(_check_bounds(cap))
        if DTYPE in want:
            findings.extend(_check_dtype(cap, ka, seen_dots))
        if VMEM in want:
            findings.extend(_check_vmem(cap, spec.vmem_budget))
    return _dedupe_suppress(findings)


def _dedupe_suppress(findings: Sequence[Finding]) -> List[Finding]:
    """Drop duplicate (path, line, rule) findings across probes and
    honor ``# repro-lint: disable=`` comments in the kernel source."""
    lines_cache: Dict[str, List[str]] = {}
    supp_cache: Dict[str, Dict[int, set]] = {}
    out: List[Finding] = []
    seen = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        key = (f.path, f.line, f.rule)
        if key in seen:
            continue
        seen.add(key)
        if f.path not in lines_cache:
            try:
                lines_cache[f.path] = \
                    Path(f.path).read_text().splitlines()
            except OSError:
                lines_cache[f.path] = []
            supp_cache[f.path] = invariants._suppressions(
                lines_cache[f.path])
        if invariants._suppressed(f, lines_cache[f.path],
                                  supp_cache[f.path]):
            continue
        out.append(f)
    return out


def check_kernels(registry=None, rules=None) -> List[Finding]:
    """Verify every registered kernel (default: the shipped
    ``repro.kernels.ops.KERNELS`` registry)."""
    if registry is None:
        from ..kernels.ops import KERNELS as registry
    findings: List[Finding] = []
    for spec in registry.values():
        findings.extend(check_spec(spec, rules))
    return findings


def _load_registry(path: Path):
    """Import a standalone kernel file (fixtures) and return its
    ``KERNELS`` registry."""
    name = f"_repro_kernel_fixture_{path.stem}"
    modspec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(modspec)
    modspec.loader.exec_module(mod)
    registry = getattr(mod, "KERNELS", None)
    if not isinstance(registry, dict) or not registry:
        raise ValueError(
            f"{path}: kernel files must define a KERNELS registry "
            "(dict of repro.kernels.ops.KernelSpec); see "
            "tests/fixtures/analysis/kernel_bad_*.py")
    return registry


def check_kernel_paths(paths: Sequence[Path],
                       rules=None) -> List[Finding]:
    """Verify standalone kernel files carrying their own ``KERNELS``
    registry (how the seeded-violation fixtures are checked)."""
    findings: List[Finding] = []
    for p in paths:
        findings.extend(check_kernels(_load_registry(Path(p)), rules))
    return findings


# ---------------------------------------------------------------------------
# VMEM report for the dry-run records
# ---------------------------------------------------------------------------

_VMEM_MEMO: Dict[int, Dict[str, Dict[str, object]]] = {}


def vmem_report(registry=None) -> Dict[str, Dict[str, object]]:
    """Per-kernel worst-case VMEM estimate over the registry probes —
    the ``kernel_vmem`` column of every ``results/dryrun/*.json``
    (``contract.dryrun_contract_findings`` re-derives and audits it).
    """
    if registry is None:
        from ..kernels.ops import KERNELS as registry
    memo_key = id(registry)
    if memo_key in _VMEM_MEMO:
        return _VMEM_MEMO[memo_key]
    report: Dict[str, Dict[str, object]] = {}
    for name, spec in registry.items():
        peak = {"peak_bytes": 0, "block_bytes": 0, "scratch_bytes": 0}
        config = ""
        for cap in capture_spec(spec):
            est = _step_bytes(cap)
            if est["peak_bytes"] > peak["peak_bytes"]:
                peak, config = est, cap.probe_label
        report[name] = {
            **peak, "config": config,
            "budget_bytes": spec.vmem_budget,
            "ok": peak["peak_bytes"] <= spec.vmem_budget}
    _VMEM_MEMO[memo_key] = report
    return report

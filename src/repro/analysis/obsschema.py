"""Schema audit for committed observability samples (results/obs).

Mirrors ``contract.dryrun_contract_findings``: every trace/metrics
JSON the repo commits is re-validated in CI against the formats
``repro.obs`` actually emits, so a recorder change that silently
drifts the export schema (a renamed span, dropped ``bytes_on_wire``
annotation, non-monotone histogram buckets) fails the lint job
instead of surfacing when someone's Perfetto load breaks.

Values are NOT pinned — wall-clock numbers differ per run by nature;
only structure, formats, and the invariants that make the files
consumable are.  Regenerate samples via
``python scripts_dev/gen_obs_samples.py``.
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import List

from ..obs import METRICS_FORMAT, TRACE_FORMAT

_EVENT_PHASES = {"X", "i", "C"}
_SWEEP_PHASES = {"burnin", "sample"}
_REGEN = ("regenerate with `python scripts_dev/gen_obs_samples.py`")


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool) \
        and math.isfinite(x)


def _trace_findings(p: Path, doc: dict) -> List[str]:
    out: List[str] = []
    meta = doc.get("repro")
    if not isinstance(meta, dict) or meta.get("format") != TRACE_FORMAT:
        out.append(f"{p}: missing/unknown repro.format (expected "
                   f"{TRACE_FORMAT!r}) — {_REGEN}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        out.append(f"{p}: traceEvents must be a non-empty list — "
                   f"{_REGEN}")
        return out
    sweep_spans = 0
    compile_spans = 0
    for i, ev in enumerate(events):
        where = f"{p}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            out.append(f"{where}: event is not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            out.append(f"{where}: missing event name")
            continue
        ph = ev.get("ph")
        if ph not in _EVENT_PHASES:
            out.append(f"{where} ({name}): ph {ph!r} not one of "
                       f"{sorted(_EVENT_PHASES)}")
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            out.append(f"{where} ({name}): ts must be a finite "
                       "number >= 0 (µs from the trace epoch)")
        if ph == "X" and (not _num(ev.get("dur")) or ev["dur"] < 0):
            out.append(f"{where} ({name}): complete event needs "
                       "dur >= 0 µs")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                out.append(f"{where} ({name}): {k} must be an int")
        if name == "session/compile":
            compile_spans += 1
        if name == "sweep":
            sweep_spans += 1
            args = ev.get("args")
            if not isinstance(args, dict):
                out.append(f"{where}: sweep span has no args")
                continue
            bow = args.get("bytes_on_wire")
            if not isinstance(bow, int) or bow < 0:
                out.append(
                    f"{where}: sweep span args.bytes_on_wire must be "
                    "a contract-derived int >= 0 (see "
                    "analysis.contract.contract_wire_bytes)")
            if args.get("phase") not in _SWEEP_PHASES:
                out.append(f"{where}: sweep span args.phase "
                           f"{args.get('phase')!r} not in "
                           f"{sorted(_SWEEP_PHASES)}")
            if not isinstance(args.get("sweep"), int):
                out.append(f"{where}: sweep span args.sweep must be "
                           "the int sweep index")
    if isinstance(meta, dict) and meta.get("kind") == "session":
        if sweep_spans == 0:
            out.append(f"{p}: a session trace must carry at least one "
                       f"'sweep' span — {_REGEN}")
        if compile_spans == 0:
            out.append(f"{p}: a session trace must carry the "
                       f"'session/compile' span (the compile_s / "
                       f"runtime_s split) — {_REGEN}")
    return out


def _metrics_findings(p: Path, doc: dict) -> List[str]:
    out: List[str] = []
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(section), dict):
            out.append(f"{p}: missing {section} object — {_REGEN}")
            return out
    for name, v in doc["counters"].items():
        if not _num(v) or v < 0:
            out.append(f"{p}: counter {name!r} must be a finite "
                       "number >= 0")
    for name, v in doc["gauges"].items():
        if not _num(v):
            out.append(f"{p}: gauge {name!r} must be a finite number")
    for name, h in doc["histograms"].items():
        where = f"{p}: histogram {name!r}"
        if not isinstance(h, dict):
            out.append(f"{where}: not an object")
            continue
        bounds = h.get("bounds")
        counts = h.get("counts")
        if not isinstance(bounds, list) or not bounds or \
                not all(_num(b) for b in bounds) or \
                any(y <= x for x, y in zip(bounds, bounds[1:])):
            out.append(f"{where}: bounds must be a non-empty strictly "
                       "increasing list of finite numbers")
            continue
        if not isinstance(counts, list) or \
                len(counts) != len(bounds) + 1 or \
                not all(isinstance(c, int) and c >= 0 for c in counts):
            out.append(f"{where}: counts must be {len(bounds) + 1} "
                       "ints >= 0 (one per le-bound + overflow)")
            continue
        if h.get("total") != sum(counts):
            out.append(f"{where}: total {h.get('total')!r} != "
                       f"sum(counts) = {sum(counts)}")
        if not _num(h.get("sum")):
            out.append(f"{where}: sum must be a finite number")
    if doc.get("kind") == "serve":
        hists = set(doc["histograms"])
        for required in ("serve.queue_wait_s", "serve.execute_s",
                         "serve.batch_occupancy"):
            if required not in hists:
                out.append(
                    f"{p}: a serve metrics snapshot must carry the "
                    f"{required!r} histogram (the queue-wait/execute/"
                    f"occupancy split RecommendServer.metrics_snapshot "
                    f"exposes) — {_REGEN}")
    return out


def obs_schema_findings(json_path) -> List[str]:
    """Audit one committed obs sample (trace or metrics snapshot,
    detected by content).  Returns human-readable findings; empty
    means the file is a well-formed ``repro.obs`` export."""
    p = Path(json_path)
    try:
        doc = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{p}: unreadable JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{p}: expected a JSON object, got "
                f"{type(doc).__name__}"]
    if "traceEvents" in doc:
        return _trace_findings(p, doc)
    if doc.get("format") == METRICS_FORMAT:
        return _metrics_findings(p, doc)
    return [f"{p}: neither a Chrome trace (traceEvents) nor a "
            f"{METRICS_FORMAT!r} metrics snapshot — {_REGEN}"]

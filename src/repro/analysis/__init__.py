"""Static analysis for the repro tree: invariant linter + HLO
communication-contract checker.

Two passes, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.invariants` — AST lint rules encoding the
  invariants earned by PRs 1–5 (counter-based sweep RNG, compat-only
  version-gated imports, choices-naming registry errors, no
  nondeterminism in ``core/``).  See ``analysis/README.md`` for the
  catalogue and suppression syntax.
* :mod:`repro.analysis.contract` — :class:`CommContract` derived from
  any ``ModelDef`` by :func:`contract_for` and verified against
  StableHLO + compiled HLO, replacing the hand-copied collective
  regexes that used to live in ``tests/test_distributed.py``.
* :mod:`repro.analysis.kernelcheck` — Pallas kernel contract verifier
  (PR 8): enumerates every registered kernel's grid over its shipped
  block configs without a TPU and proves race-freedom, block bounds,
  fp32 accumulation, and the per-grid-step VMEM budget
  (``python -m repro.analysis --kernels``).
"""
from .contract import (CommContract, ContractViolation,  # noqa: F401
                       assert_contract, check_compiled, check_lowered,
                       contract_for, dryrun_contract_findings)
from .invariants import (RULES, Finding, LintRule,  # noqa: F401
                         lint_paths, lint_source, resolve_rules)
from .kernelcheck import (KERNEL_RULE_IDS, check_kernel_paths,  # noqa: F401
                          check_kernels, vmem_report)

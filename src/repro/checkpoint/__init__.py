from .ckpt import (CheckpointManager, latest_step, list_steps,
                   load_pytree, save_pytree)

__all__ = ["CheckpointManager", "latest_step", "list_steps",
           "load_pytree", "save_pytree"]

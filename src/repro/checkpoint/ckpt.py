"""Fault-tolerant checkpointing: atomic, async, keep-N, auto-resume.

Design for the 1000+-node posture:

* **atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` into
  place — a preempted writer never corrupts the latest checkpoint;
* **async**: the host-side serialization runs on a background thread;
  the train loop only blocks if a previous save is still in flight
  (one outstanding save, bounded memory);
* **keep-N**: old steps garbage-collected after a successful save;
* **auto-resume**: ``latest_step`` scans the directory so a restarted
  job continues from the last complete checkpoint — combined with the
  seekable data stream and counter-based RNG, restart is bit-exact;
* **multi-host**: each process saves only the shards it owns
  (``process_index`` suffix); on this single-process container that is
  one file.  Restore reassembles and re-shards via
  ``jax.device_put`` with the target sharding.

Format: one ``npz`` per (step, process) holding flattened leaves +
a JSON treedef sidecar.  No external deps (orbax is not available
offline), but the same layout discipline.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional

import jax
import numpy as np

from ..obs import resolve_recorder


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(tree: Any, path: str) -> None:
    """Synchronous atomic save of one pytree to ``path`` (a directory)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrs = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, f"shard_{jax.process_index()}.npz"),
             **arrs)
    with open(os.path.join(tmp, "treedef.json"), "w") as f:
        json.dump({"treedef": str(treedef), "n_leaves": len(leaves)}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def load_pytree(template: Any, path: str) -> Any:
    """Load into the structure of ``template`` (shapes must match)."""
    leaves, treedef = _flatten(template)
    with np.load(os.path.join(
            path, f"shard_{jax.process_index()}.npz")) as z:
        new = [z[f"leaf_{i}"] for i in range(len(leaves))]
    for t, n in zip(leaves, new):
        if hasattr(t, "shape") and tuple(t.shape) != tuple(n.shape):
            raise ValueError(f"shape mismatch {t.shape} vs {n.shape}")
    return jax.tree.unflatten(treedef, new)


_STEP_RE = re.compile(r"^step_(\d+)$")


def list_steps(directory: str) -> List[int]:
    """Sorted steps with a COMPLETE checkpoint under ``directory``.

    Completeness = the treedef sidecar exists (it is written last,
    before the atomic rename); a preempted writer's half-saved step
    never shows up.  Used by both ``CheckpointManager`` and
    ``core.predict.PredictSession`` (which replays every saved
    posterior sample rather than just the latest state).
    """
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(directory)
                  if (m := _STEP_RE.match(d))
                  and os.path.exists(os.path.join(directory, d,
                                                  "treedef.json")))


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return max(steps) if steps else None


class CheckpointManager:
    """Async keep-N checkpoint manager.

    ``keep=None`` disables garbage collection entirely — every saved
    step stays on disk.  That is the posterior-sample store mode: a
    session streaming samples via ``save_freq`` must retain ALL of
    them for ``PredictSession`` to average, unlike the rolling-restart
    checkpoints which only need the last few.
    """

    def __init__(self, directory: str, keep: Optional[int] = 3,
                 recorder: Any = None):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # obs: save/restore durations, queue depth, bytes written.
        # The session passes its own Recorder down so checkpoint spans
        # land in the run's trace; standalone managers resolve a fresh
        # one (enabled iff REPRO_OBS=1).
        self.obs = resolve_recorder(recorder)

    def _raise_pending(self) -> None:
        """Re-raise an exception captured on the saver thread.

        A disk-full / permission error during a background save must
        not be silently lost (the sample store would be incomplete and
        nobody would know) — it surfaces from the NEXT ``save()`` or
        ``wait()`` on the training thread.  The pending error is
        cleared on raise so a handled failure doesn't re-raise forever.
        """
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"background checkpoint save into {self.dir!r} failed: "
                f"{err!r}") from err

    def _gc(self) -> None:
        if self.keep is None:
            return
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := _STEP_RE.match(d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        # materialize on host *before* handing to the thread so the
        # device buffers can be donated/freed by the train loop
        host = jax.tree.map(np.asarray, tree)
        nbytes = sum(int(x.nbytes) for x in jax.tree.leaves(host))

        def work():
            t0 = self.obs.now()
            save_pytree(host, os.path.join(self.dir, f"step_{step}"))
            self._gc()
            self.obs.complete("ckpt/save", t0, cat="ckpt", step=step,
                              bytes=nbytes)
            self.obs.observe("ckpt.save_s", self.obs.now() - t0)
            self.obs.add("ckpt.saves")
            self.obs.add("ckpt.bytes_written", nbytes)

        if blocking:
            work()
        else:
            def guarded():
                try:
                    work()
                except BaseException as e:  # noqa: BLE001 — must not die silently
                    self._error = e

            # queue depth gauge: one outstanding background save max
            # (save() always wait()s first); 1 while in flight
            self.obs.gauge("ckpt.queue_depth", 1)
            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            self.obs.gauge("ckpt.queue_depth", 0)
        self._raise_pending()

    def restore_latest(self, template: Any):
        """(step, tree) of the newest complete checkpoint, or None."""
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None
        t0 = self.obs.now()
        tree = load_pytree(template,
                           os.path.join(self.dir, f"step_{step}"))
        self.obs.complete("ckpt/restore", t0, cat="ckpt", step=step)
        self.obs.observe("ckpt.restore_s", self.obs.now() - t0)
        self.obs.add("ckpt.restores")
        return step, tree

    def restore_step(self, template: Any, step: int) -> Any:
        """Load one specific saved step (multi-chain resume restores
        every chain at the HIGHEST COMMON step, not each chain's own
        latest — an interrupted run may have chains one save apart)."""
        self.wait()
        t0 = self.obs.now()
        tree = load_pytree(template,
                           os.path.join(self.dir, f"step_{step}"))
        self.obs.complete("ckpt/restore", t0, cat="ckpt", step=step)
        self.obs.observe("ckpt.restore_s", self.obs.now() - t0)
        self.obs.add("ckpt.restores")
        return tree

    def all_steps(self) -> List[int]:
        return list_steps(self.dir)

"""grok-1-314b [moe]: 64L d=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2 [hf:xai-org/grok-1].
"""
from ..models.config import LayerSpec, ModelConfig

_MOE = (LayerSpec(mixer="attn", mlp="moe"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", d_model=6144, n_layers=64, vocab_size=131072,
        n_heads=48, n_kv_heads=8, head_dim=128,
        n_experts=8, top_k=2, d_ff_expert=32768,
        pattern=_MOE, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        n_experts=4, top_k=2, d_ff_expert=128, router_group=64,
        pattern=_MOE)

"""The assigned input-shape set and arch x shape applicability."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason.

    long_500k needs sub-quadratic attention: only SSM/hybrid archs run
    it (full-attention archs would need an O(S^2) prefill and an O(S)
    per-token cache that the architecture was never trained for);
    skips are recorded in DESIGN.md §Arch-applicability.
    """
    if shape.name == "long_500k":
        has_ssm = any(s.mixer == "mamba2"
                      for s in cfg.pattern + cfg.prologue)
        if not has_ssm:
            return "full-attention arch: 500k decode skipped (quadratic)"
    return None

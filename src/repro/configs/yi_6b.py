"""yi-6b [dense]: 32L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

llama-arch GQA [arXiv:2403.04652].
"""
from ..models.config import LayerSpec, ModelConfig

_DENSE = (LayerSpec(mixer="attn", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", d_model=4096, n_layers=32, vocab_size=64000,
        n_heads=32, n_kv_heads=4, head_dim=128, d_ff=11008,
        pattern=_DENSE, rope_theta=5_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160, pattern=_DENSE)

"""mamba2-130m [ssm]: 24L d=768 attn-free vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060].  d_inner = 2*768 = 1536,
head_dim 64 -> 24 SSD heads.  Vocab padded 50280 -> 50432 (tiling).
"""
from ..models.config import LayerSpec, ModelConfig

_SSM = (LayerSpec(mixer="mamba2", mlp="none"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", d_model=768, n_layers=24, vocab_size=50432,
        ssm_state=128, ssm_heads=24, ssm_head_dim=64, ssm_chunk=256,
        pattern=_SSM, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", d_model=64, n_layers=2, vocab_size=512,
        ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=32,
        pattern=_SSM, tie_embeddings=True)

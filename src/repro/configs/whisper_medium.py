"""whisper-medium [audio]: enc-dec, 24L enc + 24L dec, d=1024 16H
(MHA kv=16) d_ff=4096 vocab=51865 [arXiv:2212.04356].

The conv frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (B, frames, d).  LayerNorm + GELU MLP + absolute
sinusoidal positions (no RoPE), faithful to whisper.  Vocab padded
51865 -> 51872.

Shape interpretation for enc-dec (documented in DESIGN.md): the
brief's ``seq_len`` drives the *audio* axis (the long axis for speech):
train/prefill run ``seq_len`` encoder frames with a 448-token decoder;
decode cells attend over a ``seq_len`` cross-attention cache with the
standard 448-position decoder self-cache.
"""
from ..models.config import LayerSpec, ModelConfig

_DEC = (LayerSpec(mixer="attn", mlp="dense", cross=True),)

DECODER_LEN = 448


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", d_model=1024, n_layers=24,
        vocab_size=51872,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
        pattern=_DEC, is_encoder_decoder=True, n_encoder_layers=24,
        encoder_frames=1500, mlp_gelu=True, use_layernorm=True,
        use_rope=False, max_seq_len=65536)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        pattern=_DEC, is_encoder_decoder=True, n_encoder_layers=2,
        encoder_frames=32, mlp_gelu=True, use_layernorm=True,
        use_rope=False, max_seq_len=4096)

"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2, Mamba:attn 7:1 interleave
[arXiv:2403.19887].

Pattern (period 8, matching the paper's Jamba block): attention at
index 3, Mamba elsewhere; MoE replaces the MLP on every other layer
(odd indices).  Jamba-v0.1 uses Mamba-1 internally; we implement the
mixer as a Mamba-2/SSD block (state 16, head_dim 64, d_inner 8192 ->
128 heads) — the TPU-native chunked-dual form; noted in DESIGN.md
§Hardware adaptation.

``long_500k`` runs with the attention layers switched to a 4096-token
sliding window (``config(long_context=True)``) — the SSM layers carry
the long-range state.
"""
from ..models.config import LayerSpec, ModelConfig


def _pattern(window: int):
    specs = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba2"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, mlp=mlp,
                               window=window if mixer == "attn" else 0))
    return tuple(specs)


def config(long_context: bool = False) -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", d_model=4096, n_layers=32,
        vocab_size=65536,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        n_experts=16, top_k=2, d_ff_expert=14336,
        ssm_state=16, ssm_heads=128, ssm_head_dim=64, ssm_chunk=256,
        pattern=_pattern(4096 if long_context else 0))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", d_model=64, n_layers=8, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        n_experts=4, top_k=2, d_ff_expert=128, router_group=64,
        ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=32,
        pattern=_pattern(0))

"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H d_ff_expert=1408
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed experts top-6
[arXiv:2405.04434].

Layer 0 is a dense-MLP MLA layer (prologue); layers 1..26 are MLA+MoE.
MLA dims: qk_nope=128, qk_rope=64, v_head=128; dense d_ff=10944.
"""
from ..models.config import LayerSpec, ModelConfig

_MOE = (LayerSpec(mixer="mla", mlp="moe"),)
_PRO = (LayerSpec(mixer="mla", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", d_model=2048, n_layers=27,
        vocab_size=102400, n_heads=16, head_dim=192, d_ff=10944,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
        pattern=_MOE, prologue=_PRO, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", d_model=64, n_layers=3, vocab_size=512,
        n_heads=4, head_dim=24, d_ff=160,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=4, n_shared_experts=1, top_k=2, d_ff_expert=64,
        router_group=64, pattern=_MOE, prologue=_PRO)

"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152.

llama-arch small [hf:HuggingFaceTB/SmolLM-135M].
"""
from ..models.config import LayerSpec, ModelConfig

_DENSE = (LayerSpec(mixer="attn", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", d_model=576, n_layers=30, vocab_size=49152,
        n_heads=9, n_kv_heads=3, head_dim=64, d_ff=1536,
        pattern=_DENSE, tie_embeddings=True, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        pattern=_DENSE, tie_embeddings=True)

"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT + InternLM2 [arXiv:2404.16821].  The ViT frontend is a STUB
per the brief: ``input_specs()`` provides 256 precomputed patch
embeddings per image, prepended to the text sequence.  Vocab padded
92553 -> 92560 (model-axis tiling).
"""
from ..models.config import LayerSpec, ModelConfig

_DENSE = (LayerSpec(mixer="attn", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", d_model=2048, n_layers=24, vocab_size=92560,
        n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192,
        n_frontend_tokens=256, pattern=_DENSE, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
        n_frontend_tokens=8, pattern=_DENSE)

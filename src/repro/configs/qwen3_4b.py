"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm, GQA [hf:Qwen/Qwen3].
"""
from ..models.config import LayerSpec, ModelConfig

_DENSE = (LayerSpec(mixer="attn", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", d_model=2560, n_layers=36, vocab_size=151936,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
        qk_norm=True, pattern=_DENSE, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160, qk_norm=True,
        pattern=_DENSE)

"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

One module per assigned architecture; each exposes ``config()`` (the
exact published sizes) and ``smoke_config()`` (same family, tiny — used
by the per-arch CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec, applicable, shape_by_name

ARCHS: List[str] = [
    "jamba_v01_52b",
    "grok_1_314b",
    "deepseek_v2_lite_16b",
    "qwen25_32b",
    "smollm_135m",
    "yi_6b",
    "qwen3_4b",
    "mamba2_130m",
    "internvl2_2b",
    "whisper_medium",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def _mod(name: str):
    name = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).config().validate()


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).smoke_config().validate()


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "applicable",
           "shape_by_name", "get_config", "get_smoke"]

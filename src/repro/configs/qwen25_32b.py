"""qwen2.5-32b [dense]: 64L d=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.  GQA, QKV bias [hf:Qwen/Qwen2.5].
"""
from ..models.config import LayerSpec, ModelConfig

_DENSE = (LayerSpec(mixer="attn", mlp="dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", d_model=5120, n_layers=64, vocab_size=152064,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648,
        qkv_bias=True, pattern=_DENSE, rope_theta=1_000_000.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen25-smoke", d_model=64, n_layers=2, vocab_size=512,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192, qkv_bias=True,
        pattern=_DENSE)

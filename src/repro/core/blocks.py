"""Multi-block matrix composition (paper Figure 2, GFA).

A SMURFF model is a set of *entities* (things with a latent factor
matrix: users, movies, compounds, proteins, samples, views ...) and a
set of *blocks*, each relating two entities through an observed matrix
R_b ~ U_row U_col^T.  BMF is one block; GFA is one shared row entity
against M view entities; tensor-style models chain further blocks.

Static structure (entity/block graph, prior and noise *types*) lives in
frozen dataclasses so the Gibbs step can be jit-compiled once per model
shape; the numerical payload (factors, hyper-state, matrices) is pytree
state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import jax.tree_util
import numpy as np

from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .priors import MacauPrior, NormalPrior, SpikeAndSlabPrior
from .sparse import SparseMatrix, from_coo  # noqa: F401  (re-export)

Prior = Any    # NormalPrior | MacauPrior | SpikeAndSlabPrior
               # | FixedNormalPrior
Noise = Any    # FixedGaussian | AdaptiveGaussian | ProbitNoise


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseBlock:
    """A fully- or densely-observed matrix block.

    ``fully`` (static) marks every cell observed ("dense-dense" /
    "sparse fully known" in the paper's taxonomy) which lets the factor
    update share one Gram matrix across all rows.

    Both orientations are stored (``X``/``mask`` row-major for the
    row-entity half-sweep, ``XT``/``maskT`` for the column-entity one),
    mirroring ``SparseMatrix.rows``/``cols``: each half-sweep reads its
    operand along axis 0, so BOTH leading axes can be row-sharded by
    the distributed layer and a shard never needs the transpose of
    another shard's slice.
    """

    X: jnp.ndarray              # (n_rows, n_cols) f32
    mask: jnp.ndarray           # (n_rows, n_cols) f32; ones when fully
    XT: jnp.ndarray             # (n_cols, n_rows) f32 == X.T
    maskT: jnp.ndarray          # (n_cols, n_rows) f32 == mask.T
    fully: bool

    def tree_flatten(self):
        return (self.X, self.mask, self.XT, self.maskT), (self.fully,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, fully=aux[0])

    def oriented(self, as_row: bool):
        """(values, mask) with the updating entity along axis 0."""
        if as_row:
            return self.X, self.mask
        return self.XT, self.maskT

    @property
    def shape(self):
        return self.X.shape

    @property
    def nnz(self):
        return self.mask.sum()


def dense_block(X: np.ndarray, mask: Optional[np.ndarray] = None
                ) -> DenseBlock:
    """Host-side DenseBlock constructor (concrete arrays, not tracers).

    An explicit mask that is all-ones is detected and treated exactly
    like ``mask=None``: ``fully=True`` selects the shared-(K, K) Gram
    path in the factor update instead of the per-row masked Gram — the
    two constructions produce identical sweeps.
    """
    X = jnp.asarray(X, jnp.float32)
    if mask is None or bool(np.all(np.asarray(mask) == 1.0)):
        ones = jnp.ones_like(X)
        return DenseBlock(X, ones, X.T, ones.T, fully=True)
    mask = jnp.asarray(mask, jnp.float32)
    return DenseBlock(X, mask, X.T, mask.T, fully=False)


@dataclasses.dataclass(frozen=True)
class EntityDef:
    """Static description of one latent-factor entity."""

    name: str
    n_rows: int
    prior: Prior


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """Static description of one observed block R_b ~ U_row U_col^T."""

    row_entity: int
    col_entity: int
    noise: Noise
    sparse: bool          # SparseMatrix payload vs DenseBlock payload

    def other(self, e: int) -> int:
        return self.col_entity if self.row_entity == e else self.row_entity


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """The full static model graph; hashable, closed over at jit time.

    ``bf16_gather``: cast the *fixed* factor to bf16 before the padded
    gather in each half-sweep.  On a sharded mesh the cast happens
    before the all-gather, halving the dominant collective payload;
    the Gram/rhs accumulation still runs in f32 (the conditioning
    values carry ~1e-3 relative noise — immaterial to a Gibbs chain,
    validated in tests/test_distributed.py).
    """

    entities: Tuple[EntityDef, ...]
    blocks: Tuple[BlockDef, ...]
    num_latent: int
    use_pallas: bool = False
    bf16_gather: bool = False

    def blocks_touching(self, e: int):
        """[(block_index, True-if-e-is-the-row-entity)]"""
        out = []
        for bi, b in enumerate(self.blocks):
            if b.row_entity == e:
                out.append((bi, True))
            if b.col_entity == e:
                out.append((bi, False))
        return out

    @property
    def entity_names(self) -> Tuple[str, ...]:
        return tuple(e.name for e in self.entities)

    def entity_index(self, entity) -> int:
        """Resolve an entity by name or index, with a naming error.

        The builder API addresses entities by name; everything
        engine-side is positional.  Unknown names/indices raise a
        ValueError listing the valid choices (the ``_PRIORS``-style
        contract of the session layer).
        """
        if isinstance(entity, str):
            names = self.entity_names
            if entity not in names:
                raise ValueError(
                    f"unknown entity {entity!r}; entities in this "
                    f"model: {', '.join(names)}")
            return names.index(entity)
        i = int(entity)
        if not 0 <= i < len(self.entities):
            raise ValueError(
                f"entity index {i} out of range; this model has "
                f"{len(self.entities)} entities: "
                f"{', '.join(self.entity_names)}")
        return i

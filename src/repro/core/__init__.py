"""SMURFF-JAX core: composable Bayesian Matrix Factorization.

Public API (mirrors the smurff Python package where sensible):

    ModelBuilder, Session                     — compose any
                                                entity/block graph
    TrainSession, GFASession, smurff          — classic session shapes
                                                (thin builder wrappers)
    PredictSession                            — averaged prediction
                                                from saved posterior
                                                samples (save_freq),
                                                resident-cached, with
                                                batched top-K
                                                recommendation
    NormalPrior, MacauPrior, SpikeAndSlabPrior — priors
    FixedGaussian, AdaptiveGaussian, ProbitNoise — noise models
    SparseMatrix, from_coo, from_dense, dense_block — inputs
    ModelDef / MFData / MFState / gibbs_step  — low-level engine
"""
from .blocks import (BlockDef, DenseBlock, EntityDef, ModelDef,
                     dense_block)
from .gibbs import MFData, MFState, gibbs_step, init_state, run_sweeps
from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .predict import (PosteriorCache, PredictAccumulator,
                      PredictSession, RecResult, TestSet, auc,
                      make_test_set, predict_one, rmse)
from .priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                     SpikeAndSlabPrior)
from .session import (BlockResult, GFASession, ModelBuilder, Session,
                      SessionResult, SweepInfo, TrainSession, smurff)
from .sparse import (PaddedRows, SparseMatrix, from_coo, from_dense,
                     gather_predict, random_sparse)

__all__ = [
    "BlockDef", "DenseBlock", "EntityDef", "ModelDef", "dense_block",
    "MFData", "MFState", "gibbs_step", "init_state", "run_sweeps",
    "AdaptiveGaussian", "FixedGaussian", "ProbitNoise",
    "PosteriorCache", "PredictAccumulator", "PredictSession",
    "RecResult", "TestSet", "auc",
    "make_test_set", "predict_one", "rmse",
    "FixedNormalPrior", "MacauPrior", "NormalPrior", "SpikeAndSlabPrior",
    "BlockResult", "GFASession", "ModelBuilder", "Session",
    "SessionResult", "SweepInfo", "TrainSession", "smurff",
    "PaddedRows", "SparseMatrix", "from_coo", "from_dense",
    "gather_predict", "random_sparse",
]

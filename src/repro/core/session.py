"""High-level session API: compose any multi-relation model, run it.

The paper's claim is a *framework*: priors x noise x matrix types x
side information compose freely (Table 1).  The engine underneath
(``ModelDef``/``BlockDef``/``EntityDef`` + ``gibbs_step`` + the
shard_map sweep in ``distributed.py``) always handled arbitrary
entity/block graphs; this module exposes that through a declarative
builder instead of hardcoded session shapes:

    import repro.core as smurff

    b = smurff.ModelBuilder(num_latent=16)
    b.add_entity("compound", 5000, side_info=ecfp)      # -> Macau
    b.add_entity("target", 600)
    b.add_entity("cellline", 60)
    b.add_block("compound", "target", ic50, test=(i, j, v),
                noise=smurff.AdaptiveGaussian())
    b.add_block("compound", "cellline", viability)      # shares entity
    session = b.session(burnin=200, nsamples=400, seed=0,
                        save_freq=10, save_dir="run0",
                        mesh=mesh, pipeline="ring")
    result = session.run()
    result.rmse_test, result.blocks[1].rmse_train_trace

    p = smurff.PredictSession("run0")                    # from disk
    p.predict(i_new, j_new)                              # in-matrix
    p.predict_new("compound", ecfp_new)                  # out-of-matrix

Validation is eager: unknown entity names, duplicate blocks, and
shape mismatches raise ValueErrors naming the valid choices at
``add_*`` time, not as shape errors deep inside jit.

``TrainSession`` (one R matrix, two entities) and ``GFASession``
(star of dense views) remain as thin wrappers over the builder — they
compose the same ``ModelDef`` graphs they always did, so their sampled
chains are unchanged (pinned by tests/test_golden_chain.py's wrapper
replay).  ``save_freq`` streams posterior samples through
``checkpoint.CheckpointManager``; ``PredictSession`` (core/predict.py)
reloads them for averaged prediction and ``Session.run(resume=True)``
continues an interrupted chain from the last complete sample.
"""
from __future__ import annotations

import dataclasses
import os
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import clock, resolve_recorder
from .blocks import (BlockDef, DenseBlock, EntityDef, ModelDef,
                     dense_block)
from .diagnostics import (Diagnostics, compute_diagnostics,
                          save_diagnostics, split_rhat)
from .gibbs import (MFData, MFState, gibbs_step, init_chain_states,
                    init_state, multi_chain_step_jit, stack_states,
                    unstack_state)
from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .predict import PredictAccumulator, TestSet, make_test_set
from .priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                     SpikeAndSlabPrior)
from .sparse import SparseMatrix


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockResult:
    """Per-block view of a run: traces + posterior-mean test metrics."""

    block: int
    entities: Tuple[str, str]
    rmse_train_trace: List[float]
    rmse_test_trace: List[float]
    rmse_test: Optional[float]
    auc_test: Optional[float]
    predictions: Optional[np.ndarray]
    pred_var: Optional[np.ndarray]


@dataclasses.dataclass
class SessionResult:
    """Result of one run (one chain, or ``chains=C`` stacked chains).

    The scalar fields mirror the first block carrying a test set
    (block 0's train trace for back-compat); ``blocks`` holds every
    block's traces and metrics for multi-relation models.  With
    ``chains=C > 1``:

    * test metrics / ``predictions`` pool the posterior draws of ALL
      chains (step-major, chain-minor summation order — the same order
      ``PredictSession`` replays from a multi-chain store);
    * ``blocks``' train traces follow chain 0; ``chain_blocks[c]``
      carries every chain's per-block traces;
    * ``state`` and ``factor_means`` entries gain a leading ``(C,)``
      chain axis;
    * ``diagnostics`` holds split-R-hat / bulk-ESS per monitored
      quantity (``core.diagnostics``), also written to
      ``save_dir/diagnostics.json`` when streaming samples;
    * ``resumed_from`` records the completed-sweep count a
      ``run(resume=True)`` continued from (``None`` for a fresh run) —
      traces and accumulators cover only post-resume sweeps.
    """

    rmse_test: Optional[float]
    auc_test: Optional[float]
    predictions: Optional[np.ndarray]
    pred_var: Optional[np.ndarray]
    rmse_train_trace: List[float]
    rmse_test_trace: List[float]
    nsamples: int
    runtime_s: float
    state: MFState
    samples: Optional[List[Tuple[np.ndarray, ...]]] = None
    blocks: List[BlockResult] = dataclasses.field(default_factory=list)
    factor_means: Optional[List[np.ndarray]] = None
    save_dir: Optional[str] = None
    n_chains: int = 1
    chain_blocks: Optional[List[List[BlockResult]]] = None
    diagnostics: Optional[Diagnostics] = None
    resumed_from: Optional[int] = None
    # PR 10 split: ``runtime_s`` is sweep wall time ONLY; the one-time
    # jit compilation (plus the discarded warm-up sweep that triggers
    # it) lands here instead of silently inflating the first sweep.
    compile_s: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able scalar summary of the run.

        Keeps the ``runtime_s`` key (pre-PR-10 consumers read it; it
        now means sweep time only) alongside the ``compile_s`` split;
        ``total_s`` is their sum — what the old ``runtime_s`` used to
        (approximately) report.
        """
        return {
            "rmse_test": self.rmse_test,
            "auc_test": self.auc_test,
            "nsamples": self.nsamples,
            "n_chains": self.n_chains,
            "runtime_s": self.runtime_s,
            "compile_s": self.compile_s,
            "total_s": self.compile_s + self.runtime_s,
            "rmse_train_trace": [float(v) for v in
                                 self.rmse_train_trace],
            "rmse_test_trace": [float(v) for v in self.rmse_test_trace],
            "save_dir": self.save_dir,
            "resumed_from": self.resumed_from,
            "diagnostics": (self.diagnostics.to_dict()
                            if self.diagnostics is not None else None),
        }

    def mean_from_samples(self, test: TestSet, row_entity: int = 0,
                          col_entity: int = 1) -> np.ndarray:
        """Posterior-mean predictions recomputed from kept samples.

        Replays the in-session accumulator over ``samples`` (requires
        ``run(keep_samples=True)``) — same ``predict_one`` kernel, same
        summation order — so for the same test set this reproduces
        ``predictions`` EXACTLY, not just statistically (asserted in
        tests/test_predict_session.py).
        """
        if self.samples is None:
            raise ValueError("no samples kept; run(keep_samples=True)")
        if not isinstance(test, TestSet):
            test = make_test_set(*test)
        acc = PredictAccumulator(test)
        for fs in self.samples:
            acc.update(jnp.asarray(fs[row_entity]),
                       jnp.asarray(fs[col_entity]))
        return np.asarray(acc.mean)


class SweepInfo(NamedTuple):
    """What a per-sweep callback sees (after the sweep completed).

    ``metrics`` are always chain-0 SCALARS (existing single-chain
    callbacks keep working under ``chains=C``); a multi-chain run
    additionally exposes the full stacked ``(C,)`` metrics as
    ``chain_metrics`` (``None`` when ``chains == 1``).  ``state`` is
    the full post-sweep state — chain-stacked for a multi-chain run.
    """

    sweep: int          # 0-based global sweep index
    phase: str          # "burnin" | "sample"
    state: MFState      # post-sweep sampler state (device arrays)
    metrics: Dict[str, jnp.ndarray]   # rmse_train_<b> / alpha_<b>
    chain_metrics: Optional[Dict[str, jnp.ndarray]] = None


_PRIORS = {"normal": NormalPrior, "spikeandslab": SpikeAndSlabPrior,
           "fixednormal": FixedNormalPrior}


def resolve_chains(chains: Optional[int] = None) -> int:
    """Validate the chain-count knob, defaulting from the
    ``REPRO_CHAINS`` environment variable (CI runs a chains=4 smoke
    leg that way), else 1."""
    if chains is None:
        chains = int(os.environ.get("REPRO_CHAINS", "1"))
    chains = int(chains)
    if chains < 1:
        raise ValueError(f"chains must be >= 1, got {chains}")
    return chains


def _prior_by_name(name: str, num_latent: int):
    if name not in _PRIORS:
        raise ValueError(
            f"unknown prior {name!r}; valid priors: "
            f"{', '.join(sorted(_PRIORS))} (side information selects "
            "the macau prior automatically)")
    return _PRIORS[name](num_latent)


def _place_step(model: ModelDef, data: MFData, state: MFState,
                mesh: Any, pipeline: Optional[str]):
    """(data, state, step) — distributed through ``mesh`` when given.

    Shared by every session flavor: builds the explicit shard_map
    sweep with the requested exchange ``pipeline``
    ("eager"/"ring"/None-for-REPRO_PIPELINE) and places data/state on
    the mesh; without a mesh the single-device ``gibbs_step`` runs.
    Warns — naming the offending model piece — when the model falls
    outside the sharded subset: the pjit fallback still samples the
    same chain, just with partitioner-placed collectives.  The
    ``pipeline`` knob is validated even without a mesh (a typo must
    raise, not silently run the single-device sweep), and asking for a
    pipeline WITH no mesh to run it on warns — there is no exchange to
    pipeline.
    """
    from .distributed import (distributed_unsupported_reason,
                              make_distributed_step, resolve_pipeline)
    resolve_pipeline(pipeline)
    if mesh is None:
        if pipeline is not None:
            import warnings
            warnings.warn(
                f"pipeline={pipeline!r} has no effect without mesh=: "
                "the session runs the single-device sweep",
                stacklevel=3)
        return data, state, (lambda d, s: gibbs_step(model, d, s))
    reason = distributed_unsupported_reason(model, mesh, data)
    if reason is not None:
        import warnings
        warnings.warn(
            f"model is outside the sharded subset on this mesh "
            f"({reason}); falling back to auto-partitioned pjit",
            stacklevel=3)
    step, ds, ss = make_distributed_step(model, mesh, data, state,
                                         pipeline=pipeline)
    return jax.device_put(data, ds), jax.device_put(state, ss), step


def _place_multi_step(model: ModelDef, data: MFData, stacked: MFState,
                      mesh: Any, pipeline: Optional[str],
                      chains: int, chain_axis: Optional[str]):
    """``_place_step`` for a chain-stacked state (``chains > 1``).

    Single-device: ``lax.map`` of ``gibbs_step`` over the chain axis
    (bitwise-identical per-chain subgraphs — see
    ``gibbs.multi_chain_step``).  With a mesh: the chain-stacked
    shard_map sweep (``distributed.make_multi_chain_step``), sharding
    chains over ``chain_axis`` when given.
    """
    from .distributed import (distributed_unsupported_reason,
                              make_multi_chain_step, resolve_pipeline)
    resolve_pipeline(pipeline)
    if mesh is None:
        if pipeline is not None:
            import warnings
            warnings.warn(
                f"pipeline={pipeline!r} has no effect without mesh=: "
                "the session runs the single-device sweep",
                stacklevel=3)
        return data, stacked, (
            lambda d, s: multi_chain_step_jit(model, d, s))
    reason = distributed_unsupported_reason(model, mesh, data)
    if reason is not None:
        import warnings
        warnings.warn(
            f"model is outside the sharded subset on this mesh "
            f"({reason}); falling back to auto-partitioned pjit",
            stacklevel=3)
    step, ds, ss = make_multi_chain_step(model, mesh, data, stacked,
                                         pipeline=pipeline,
                                         chains=chains,
                                         chain_axis=chain_axis)
    return jax.device_put(data, ds), jax.device_put(stacked, ss), step


# ---------------------------------------------------------------------------
# the declarative builder
# ---------------------------------------------------------------------------

class ModelBuilder:
    """Compose an arbitrary entity/block graph, validated eagerly.

    * ``add_entity(name, n, prior=..., side_info=...)`` declares a
      latent-factor entity.  ``prior`` is a registry name ("normal",
      "spikeandslab", "fixednormal") or a prior instance; passing
      ``side_info`` (an (n, D) feature matrix) selects the Macau
      prior with a sampled link matrix instead.
    * ``add_block(ent_a, ent_b, data, noise=..., test=...)`` relates
      two entities through an observed matrix — a ``SparseMatrix``,
      a dense ndarray (optionally with ``mask=``), or a prebuilt
      ``DenseBlock``.  ``test=(i, j, v)`` attaches per-block test
      triplets evaluated by posterior-mean prediction.

    Entities may be shared by any number of blocks (the two-relation
    compound x target / compound x cell-line layout, GFA's view star,
    tensor-style chains ...).  Every mistake — unknown or duplicate
    names, shape mismatches, self-blocks — raises a ValueError naming
    the valid choices at ``add_*`` time.

    ``build()`` returns the engine triple; ``session(...)`` wraps it
    in a runnable :class:`Session` carrying the ``mesh=``/``pipeline=``
    distribution knobs, ``save_freq``/``save_dir`` posterior-sample
    streaming, and per-sweep ``callbacks``.
    """

    def __init__(self, num_latent: int = 16, use_pallas: bool = False,
                 bf16_gather: bool = False):
        self.num_latent = num_latent
        self.use_pallas = use_pallas
        self.bf16_gather = bf16_gather
        self._entities: List[Tuple[str, int, Any,
                                   Optional[np.ndarray]]] = []
        self._blocks: List[Tuple[str, str, Any, Any,
                                 Optional[TestSet]]] = []

    # -- entities ----------------------------------------------------------

    def _names(self) -> List[str]:
        return [name for name, *_ in self._entities]

    def add_entity(self, name: str, n: int,
                   prior: Union[str, Any] = "normal",
                   side_info: Optional[np.ndarray] = None,
                   beta_precision: float = 5.0,
                   sample_beta_precision: bool = True) -> "ModelBuilder":
        if name in self._names():
            raise ValueError(
                f"duplicate entity {name!r}; entities already added: "
                f"{', '.join(self._names())}")
        n = int(n)
        if n <= 0:
            raise ValueError(f"entity {name!r} needs n > 0, got {n}")
        side = None
        if side_info is not None:
            if not isinstance(prior, str) or prior != "normal":
                raise ValueError(
                    f"entity {name!r}: pass either prior= or "
                    "side_info=, not both — side information selects "
                    "the macau prior automatically")
            side = np.asarray(side_info, np.float32)
            if side.ndim != 2 or side.shape[0] != n:
                raise ValueError(
                    f"entity {name!r} side_info must be ({n}, D), got "
                    f"{side.shape}")
            p = MacauPrior(self.num_latent, side.shape[1],
                           beta_precision=beta_precision,
                           sample_beta_precision=sample_beta_precision)
        elif isinstance(prior, str):
            p = _prior_by_name(
                prior.replace("-", "").replace("_", "").lower(),
                self.num_latent)
        else:
            p = prior
            pk = getattr(p, "num_latent", None)
            if pk is not None and pk != self.num_latent:
                raise ValueError(
                    f"entity {name!r} prior {type(p).__name__} has "
                    f"num_latent={pk}, but the builder composes a "
                    f"num_latent={self.num_latent} model")
        self._entities.append((name, n, p, side))
        return self

    # -- blocks ------------------------------------------------------------

    def _entity_index(self, name: str) -> int:
        names = self._names()
        if name not in names:
            known = ", ".join(names) if names else "(none yet)"
            raise ValueError(
                f"unknown entity {name!r}; entities added so far: "
                f"{known} — add_entity first")
        return names.index(name)

    def add_block(self, row_entity: str, col_entity: str, data,
                  noise: Any = None, test=None,
                  mask: Optional[np.ndarray] = None) -> "ModelBuilder":
        ri = self._entity_index(row_entity)
        ci = self._entity_index(col_entity)
        if ri == ci:
            raise ValueError(
                f"block {row_entity!r} x {col_entity!r} relates an "
                "entity to itself; blocks must relate two distinct "
                "entities")
        for r2, c2, *_ in self._blocks:
            if {r2, c2} == {row_entity, col_entity}:
                raise ValueError(
                    f"duplicate block {row_entity!r} x {col_entity!r}: "
                    f"the pair already carries the {r2!r} x {c2!r} "
                    "block (one observed matrix per entity pair)")
        if isinstance(data, (SparseMatrix, DenseBlock)):
            if mask is not None:
                raise ValueError("mask= only applies to raw dense "
                                 "ndarray data")
            payload = data
        else:
            payload = dense_block(np.asarray(data, np.float32), mask)
        want = (self._entities[ri][1], self._entities[ci][1])
        got = tuple(payload.shape)
        if got != want:
            raise ValueError(
                f"block {row_entity!r} x {col_entity!r} data has shape "
                f"{got}, expected {want} "
                f"({row_entity}={want[0]} rows x {col_entity}={want[1]}"
                " cols)")
        ts = None
        if test is not None:
            ts = test if isinstance(test, TestSet) else make_test_set(*test)
        self._blocks.append((row_entity, col_entity, payload,
                             noise if noise is not None
                             else FixedGaussian(5.0), ts))
        return self

    # -- build -------------------------------------------------------------

    def build(self) -> Tuple[ModelDef, MFData, Dict[int, TestSet]]:
        """(ModelDef, MFData, {block_index: TestSet}) for the engine."""
        if not self._entities:
            raise ValueError("empty model: add_entity at least two "
                             "entities and add_block a matrix")
        if not self._blocks:
            raise ValueError(
                "model has no blocks: add_block at least one observed "
                f"matrix between entities {', '.join(self._names())}")
        ents = tuple(EntityDef(name, n, prior)
                     for name, n, prior, _ in self._entities)
        blocks = tuple(
            BlockDef(self._entity_index(r), self._entity_index(c),
                     noise, isinstance(payload, SparseMatrix))
            for r, c, payload, noise, _ in self._blocks)
        model = ModelDef(ents, blocks, self.num_latent, self.use_pallas,
                         self.bf16_gather)
        sides = tuple(None if s is None else jnp.asarray(s)
                      for *_, s in self._entities)
        data = MFData(tuple(p for _, _, p, _, _ in self._blocks), sides)
        tests = {bi: ts for bi, (*_, ts) in enumerate(self._blocks)
                 if ts is not None}
        return model, data, tests

    def session(self, **kwargs) -> "Session":
        model, data, tests = self.build()
        return Session(model, data, tests=tests, **kwargs)


# ---------------------------------------------------------------------------
# the generic run loop
# ---------------------------------------------------------------------------

class Session:
    """Run a Gibbs chain over any built model graph.

    * ``mesh=`` routes through the explicit distributed sweep
      (``make_distributed_step``); ``pipeline`` selects the
      fixed-factor exchange — ``"eager"`` (one all-gather per
      half-sweep) or ``"ring"`` (``n_shards - 1`` double-buffered
      ppermute hops).  ``None`` defers to ``REPRO_PIPELINE``; either
      way the sampled chain matches the single-device one at
      reduction-order tolerance (counter-based per-row RNG — see
      ``core/distributed.py``).
    * ``save_freq=k`` streams every k-th post-burnin sample (the full
      ``MFState``) to ``save_dir`` through
      ``checkpoint.CheckpointManager`` plus a ``model.json`` spec —
      the on-disk layout :class:`~repro.core.predict.PredictSession`
      reloads; ``run(resume=True)`` continues an interrupted chain
      from the last complete sample on disk.
    * ``chains=C`` runs C independent Gibbs chains in ONE compiled
      program (``lax.map`` over a leading chain axis — bitwise equal
      to C separate runs keyed ``gibbs.chain_keys(seed, C)``; chain 0
      IS the single-chain run for the same seed).  ``None`` defers to
      the ``REPRO_CHAINS`` environment variable.  Test metrics pool
      the chains' posterior draws; split-R-hat / bulk-ESS over the
      per-chain traces land in ``SessionResult.diagnostics`` and — when
      streaming — in ``save_dir/diagnostics.json``, which
      ``PredictSession(require_converged=True)`` gates on.  Samples
      stream per chain under ``save_dir/chain_<c>/`` (each a valid
      single-chain store).  ``chain_axis=`` names a mesh axis to shard
      the chains over, so chains x row-shards fills a pod
      (``Mesh(devices.reshape(C, -1), ("chain", "data"))``).
    * ``callbacks`` are called after every sweep with a
      :class:`SweepInfo` (trace collection, convergence monitors,
      extra checkpointing ...).
    """

    def __init__(self, model: ModelDef, data: MFData, *,
                 tests: Optional[Dict[int, TestSet]] = None,
                 burnin: int = 100, nsamples: int = 100, seed: int = 0,
                 mesh: Any = None, pipeline: Optional[str] = None,
                 chains: Optional[int] = None,
                 chain_axis: Optional[str] = None,
                 save_freq: int = 0, save_dir: Optional[str] = None,
                 verbose: int = 0,
                 callbacks: Sequence[Callable[[SweepInfo], None]] = (),
                 init_transform: Optional[Callable[[MFState],
                                                   MFState]] = None,
                 accumulate_factor_means: bool = False,
                 recorder: Any = None):
        self.model = model
        self.data = data
        self.tests = dict(tests or {})
        for bi in self.tests:
            if not 0 <= bi < len(model.blocks):
                raise ValueError(
                    f"test set attached to block {bi}, but the model "
                    f"has blocks 0..{len(model.blocks) - 1}")
        self.burnin = burnin
        self.nsamples = nsamples
        self.seed = seed
        self.mesh = mesh
        self.pipeline = pipeline
        self.chains = resolve_chains(chains)
        self.chain_axis = chain_axis
        if chain_axis is not None and mesh is None:
            raise ValueError(
                f"chain_axis={chain_axis!r} shards chains over a mesh "
                "axis; pass mesh= too")
        self.save_freq = save_freq
        self.save_dir = save_dir
        self.verbose = verbose
        self.callbacks = tuple(callbacks)
        self.init_transform = init_transform
        self.accumulate_factor_means = accumulate_factor_means
        # None -> fresh per-run Recorder at run() time, enabled iff
        # REPRO_OBS=1; an explicit Recorder is shared with the
        # checkpoint savers and exported by the caller
        self.recorder = recorder
        if save_freq and not save_dir:
            raise ValueError(
                "save_freq > 0 streams posterior samples to disk; "
                "pass save_dir= too")

    # -- persistence -------------------------------------------------------

    def _run_spec(self, chain: Optional[int] = None) -> dict:
        run = {"burnin": self.burnin, "nsamples": self.nsamples,
               "save_freq": self.save_freq, "seed": self.seed,
               "chains": self.chains}
        if chain is not None:
            run["chain"] = chain
        return run

    def _spec_at(self, directory: str, chain: Optional[int] = None):
        from .modelspec import (MODEL_SPEC_FILE, model_to_spec,
                                save_model_spec)
        os.makedirs(directory, exist_ok=True)
        spec = model_to_spec(self.model)
        spec["run"] = self._run_spec(chain)
        save_model_spec(os.path.join(directory, MODEL_SPEC_FILE), spec)

    def _make_savers(self, recorder=None):
        """One CheckpointManager per chain.

        ``chains == 1`` keeps the PR 5 layout exactly
        (``save_dir/model.json`` + ``save_dir/samples/step_<s>/``).
        ``chains = C > 1`` nests a full single-chain store per chain —
        ``save_dir/chain_<c>/{model.json, samples/}`` — under a shared
        top-level ``model.json`` whose ``run.chains`` announces the
        layout to ``PredictSession``.
        """
        from ..checkpoint import CheckpointManager
        from .modelspec import SAMPLES_SUBDIR, chain_subdir
        self._spec_at(self.save_dir)
        if self.chains == 1:
            # keep=None: a posterior-sample store retains EVERY step
            return [CheckpointManager(
                os.path.join(self.save_dir, SAMPLES_SUBDIR), keep=None,
                recorder=recorder)]
        savers = []
        for c in range(self.chains):
            cdir = os.path.join(self.save_dir, chain_subdir(c))
            self._spec_at(cdir, chain=c)
            savers.append(CheckpointManager(
                os.path.join(cdir, SAMPLES_SUBDIR), keep=None,
                recorder=recorder))
        return savers

    def _restore(self, savers, state: MFState):
        """(start, state) from the newest checkpoint every chain has.

        Single chain: the latest complete step.  Multi-chain: the
        HIGHEST COMMON step across chains (an interrupted run can leave
        chains one save apart; ``keep=None`` retains every earlier
        step, so the common step always exists on disk).  Returns None
        when any chain store is empty.
        """
        if self.chains == 1:
            return savers[0].restore_latest(state)
        common = None
        for sv in savers:
            steps = set(sv.all_steps())
            common = steps if common is None else (common & steps)
        if not common:
            return None
        step = max(common)
        chains = [sv.restore_step(unstack_state(state, c), step)
                  for c, sv in enumerate(savers)]
        return step, stack_states(chains)

    # -- run ---------------------------------------------------------------

    def _wire_bytes(self) -> int:
        """Contract-derived bytes-on-wire per device per sweep — the
        ``args.bytes_on_wire`` annotation on every sweep span.  Pure
        arithmetic over the ModelDef (``analysis.contract``); 0
        without a mesh."""
        # analysis imports the model zoo; keep it out of core's import
        # graph until observability actually asks for it
        from ..analysis.contract import contract_for, contract_wire_bytes
        if self.mesh is None:
            mesh_shape: Tuple[int, ...] = (1,)
            chain_axis_size = None
        else:
            mesh_shape = tuple(int(s)
                               for s in np.asarray(self.mesh.devices).shape)
            chain_axis_size = (int(self.mesh.shape[self.chain_axis])
                               if self.chain_axis is not None else None)
        c = contract_for(self.model, mesh_shape, self.pipeline,
                         chains=self.chains,
                         chain_axis_size=chain_axis_size)
        return contract_wire_bytes(self.model, c)

    def _export_obs(self, rec) -> None:
        """Write the run's trace + metrics snapshots when enabled.

        Destination: ``REPRO_OBS_DIR`` if set, else ``save_dir/obs``
        when the session streams samples; with neither there is
        nowhere sensible to write and the caller owns the export
        (``rec.write_trace(...)``)."""
        if not rec.enabled:
            return
        dest = os.environ.get("REPRO_OBS_DIR")
        if dest is None and self.save_dir:
            dest = os.path.join(self.save_dir, "obs")
        if dest is None:
            return
        rec.write_trace(os.path.join(dest, "train_trace.json"))
        rec.write_metrics(os.path.join(dest, "train_metrics.json"))

    def run(self, keep_samples: bool = False,
            resume: bool = False) -> SessionResult:
        model, data = self.model, self.data
        rec = resolve_recorder(self.recorder)
        rec.set_kind("session")
        C = self.chains
        if C == 1:
            state = init_state(model, data, self.seed)
            if self.init_transform is not None:
                state = self.init_transform(state)
        else:
            chain_states = init_chain_states(model, data, self.seed, C)
            if self.init_transform is not None:
                chain_states = [self.init_transform(s)
                                for s in chain_states]
            state = stack_states(chain_states)

        savers = []
        start = 0
        resumed_from: Optional[int] = None
        if self.save_freq:
            savers = self._make_savers(recorder=rec)
            if resume:
                restored = self._restore(savers, state)
                if restored is not None:
                    start, state = restored
                    resumed_from = start
        elif resume:
            raise ValueError(
                "resume=True needs save_freq > 0 and a save_dir "
                "holding the interrupted chain's samples")

        if C == 1:
            data, state, step = _place_step(model, data, state,
                                            self.mesh, self.pipeline)
        else:
            data, state, step = _place_multi_step(
                model, data, state, self.mesh, self.pipeline, C,
                self.chain_axis)
        accs = {bi: PredictAccumulator(ts)
                for bi, ts in self.tests.items()}
        total = self.burnin + self.nsamples
        # Compile split: trigger jit compilation with a DISCARDED
        # warm-up sweep before the timed loop, so compile_s and
        # runtime_s separate (the old single perf_counter pair charged
        # compilation to sweep time).  ``step`` is pure (no donated
        # buffers anywhere in gibbs/distributed), so running it once
        # and dropping the result cannot perturb the chain — the
        # recorded sweeps below start from the same (data, state).
        compile_s = 0.0
        if start < total:
            t_c = clock.perf_counter()
            warm = step(data, state)
            jax.block_until_ready(warm)
            del warm
            compile_s = clock.perf_counter() - t_c
            rec.complete("session/compile", t_c, cat="session",
                         phase="compile")
        obs_on = rec.enabled
        bytes_on_wire = self._wire_bytes() if obs_on else 0
        t0 = clock.perf_counter()
        n_blocks = len(model.blocks)
        train_traces: List[List[float]] = [[] for _ in range(n_blocks)]
        chain_train_traces: List[List[List[float]]] = [
            [[] for _ in range(n_blocks)] for _ in range(C)]
        test_traces: Dict[int, List[float]] = {bi: []
                                               for bi in self.tests}
        samples: List[Tuple[np.ndarray, ...]] = []
        sums = None
        if self.accumulate_factor_means:
            lead = () if C == 1 else (C,)
            sums = [jnp.zeros(lead + (e.n_rows, model.num_latent))
                    for e in model.entities]
        n_acc = 0
        # post-burnin traces of the monitored scalars, (C,) per sweep,
        # feeding split-R-hat / bulk-ESS at the end of the run
        diag_traces: Dict[str, List[np.ndarray]] = {}

        for sweep in range(start, total):
            if obs_on:
                t_sweep = rec.now()
            state, metrics = step(data, state)
            if obs_on:
                # fence: device time for THIS sweep, not dispatch time
                jax.block_until_ready((state, metrics))
                t_done = rec.now()
            for bi in range(n_blocks):
                arr = np.atleast_1d(
                    np.asarray(metrics[f"rmse_train_{bi}"]))
                train_traces[bi].append(float(arr[0]))
                for c in range(C):
                    chain_train_traces[c][bi].append(float(arr[c]))
            in_sampling = sweep >= self.burnin
            if in_sampling:
                # pool posterior draws across chains: step-major,
                # chain-minor — the summation order PredictSession
                # replays from a multi-chain store
                for bi, acc in accs.items():
                    blk = model.blocks[bi]
                    if C == 1:
                        acc.update(state.factors[blk.row_entity],
                                   state.factors[blk.col_entity])
                    else:
                        for c in range(C):
                            acc.update(
                                state.factors[blk.row_entity][c],
                                state.factors[blk.col_entity][c])
                    test_traces[bi].append(
                        float(jnp.sqrt(jnp.mean(
                            (acc.mean - acc.test.v) ** 2))))
                if keep_samples:
                    if C == 1:
                        samples.append(tuple(np.asarray(f)
                                             for f in state.factors))
                    else:
                        for c in range(C):
                            samples.append(tuple(np.asarray(f[c])
                                                 for f in state.factors))
                if sums is not None:
                    sums = [s + f for s, f in zip(sums, state.factors)]
                    n_acc += 1
                for nm, v in metrics.items():
                    diag_traces.setdefault(nm, []).append(
                        np.atleast_1d(np.asarray(v, np.float64)))
                for e, ent in enumerate(model.entities):
                    f = state.factors[e]
                    rms = jnp.sqrt(jnp.mean(
                        f * f, axis=None if C == 1 else (1, 2)))
                    diag_traces.setdefault(
                        f"factor_rms_{ent.name}", []).append(
                        np.atleast_1d(np.asarray(rms, np.float64)))
                if savers and \
                        (sweep - self.burnin + 1) % self.save_freq == 0:
                    if C == 1:
                        savers[0].save(sweep + 1, state)
                    else:
                        for c, sv in enumerate(savers):
                            sv.save(sweep + 1, unstack_state(state, c))
            if obs_on:
                span_args = {
                    "sweep": sweep,
                    "phase": "sample" if in_sampling else "burnin",
                    "stage": "first" if sweep == start else "steady",
                    "bytes_on_wire": bytes_on_wire,
                }
                tr = diag_traces.get("rmse_train_0")
                if tr:
                    # streaming convergence: split-R-hat over the
                    # post-burnin draws so far (nan below MIN_DRAWS)
                    rhat = split_rhat(np.stack(tr, axis=1))
                    if np.isfinite(rhat):
                        span_args["rhat_rmse_train_0"] = rhat
                rec.complete("sweep", t_sweep, end=t_done,
                             cat="session", **span_args)
                rec.observe("session.sweep_s", t_done - t_sweep)
                rec.add("session.sweeps")
            if self.verbose and (sweep % max(1, total // 20) == 0):
                ph = "burnin" if sweep < self.burnin else "sample"
                print(f"[{ph} {sweep:4d}] rmse_train="
                      f"{train_traces[0][-1]:.4f}")
            if self.callbacks:
                phase = "sample" if in_sampling else "burnin"
                if C == 1:
                    info = SweepInfo(sweep, phase, state, metrics)
                else:
                    m0 = {k: v[0] for k, v in metrics.items()}
                    info = SweepInfo(sweep, phase, state, m0, metrics)
                for cb in self.callbacks:
                    cb(info)
        for sv in savers:
            sv.wait()

        diag = None
        if diag_traces:
            diag = compute_diagnostics(
                {k: np.stack(v, axis=1) for k, v in diag_traces.items()})
            if savers:
                save_diagnostics(self.save_dir, diag)

        runtime = clock.perf_counter() - t0
        names = model.entity_names
        block_results: List[BlockResult] = []
        head: Optional[BlockResult] = None
        for bi, blk in enumerate(model.blocks):
            acc = accs.get(bi)
            if acc is not None and acc.n == 0:
                acc = None   # resumed past the end: nothing accumulated
            is_probit = isinstance(blk.noise, ProbitNoise)
            br = BlockResult(
                block=bi,
                entities=(names[blk.row_entity], names[blk.col_entity]),
                rmse_train_trace=train_traces[bi],
                rmse_test_trace=test_traces.get(bi, []),
                rmse_test=(acc.rmse() if acc else None),
                auc_test=(acc.auc() if (acc and is_probit) else None),
                predictions=(np.asarray(acc.mean) if acc else None),
                pred_var=(np.asarray(acc.var) if acc else None))
            block_results.append(br)
            if head is None and acc is not None:
                head = br
        if head is None:
            head = block_results[0]
        chain_blocks = None
        if C > 1:
            chain_blocks = [
                [BlockResult(
                    block=bi,
                    entities=(names[blk.row_entity],
                              names[blk.col_entity]),
                    rmse_train_trace=chain_train_traces[c][bi],
                    rmse_test_trace=[], rmse_test=None, auc_test=None,
                    predictions=None, pred_var=None)
                 for bi, blk in enumerate(model.blocks)]
                for c in range(C)]
        means = None
        if sums is not None:
            if n_acc == 0 and self.nsamples > 0:
                raise ValueError(
                    f"run(resume=True) restored the chain at {start} "
                    "completed sweeps — at or past the end of the "
                    f"burnin={self.burnin} + nsamples={self.nsamples} "
                    f"= {total} schedule — so ZERO posterior draws "
                    "were accumulated and factor_means would be "
                    "silently all-zero. The schedule counts TOTAL "
                    "sweeps, not additional ones: raise nsamples to "
                    "extend the chain, or rerun without resume=True.")
            means = [np.asarray(s / max(n_acc, 1)) for s in sums]
        rec.gauge("session.chains", C)
        self._export_obs(rec)
        return SessionResult(
            rmse_test=head.rmse_test,
            auc_test=head.auc_test,
            predictions=head.predictions,
            pred_var=head.pred_var,
            rmse_train_trace=train_traces[0],
            rmse_test_trace=head.rmse_test_trace,
            nsamples=self.nsamples,
            runtime_s=runtime,
            compile_s=compile_s,
            state=state,
            samples=samples if keep_samples else None,
            blocks=block_results,
            factor_means=means,
            save_dir=self.save_dir,
            n_chains=C,
            chain_blocks=chain_blocks,
            diagnostics=diag,
            resumed_from=resumed_from,
        )


# ---------------------------------------------------------------------------
# the classic shapes, as thin wrappers over the builder
# ---------------------------------------------------------------------------

class TrainSession:
    """Single-R-matrix session (BMF / Macau / probit variants).

    A thin wrapper over :class:`ModelBuilder`: two entities ("rows",
    "cols"), one block — it composes the identical ``ModelDef`` graph
    the pre-builder session did, so the sampled chain is unchanged
    (tests/test_golden_chain.py replays it against the engine chain
    bitwise).  Pass ``mesh`` to run the chain through the explicit
    distributed sweep and ``pipeline`` to select the fixed-factor
    exchange ("eager" all-gather vs "ring" ppermute hops; None defers
    to ``REPRO_PIPELINE``).  ``save_freq``/``save_dir`` stream
    posterior samples for :class:`~repro.core.predict.PredictSession`.
    """

    def __init__(self, num_latent: int = 16, burnin: int = 100,
                 nsamples: int = 100, seed: int = 0,
                 priors: Sequence[str] = ("normal", "normal"),
                 use_pallas: bool = False, verbose: int = 0,
                 save_freq: int = 0, save_dir: Optional[str] = None,
                 mesh: Any = None, pipeline: Optional[str] = None,
                 chains: Optional[int] = None,
                 chain_axis: Optional[str] = None,
                 callbacks: Sequence[Callable[[SweepInfo], None]] = (),
                 recorder: Any = None):
        self.num_latent = num_latent
        self.burnin = burnin
        self.nsamples = nsamples
        self.seed = seed
        self.recorder = recorder
        self.prior_names = tuple(p.replace("-", "").replace("_", "")
                                 for p in priors)
        self.use_pallas = use_pallas
        self.verbose = verbose
        self.save_freq = save_freq
        self.save_dir = save_dir
        self.mesh = mesh
        self.pipeline = pipeline
        self.chains = chains
        self.chain_axis = chain_axis
        self.callbacks = callbacks
        self._train: Optional[Any] = None
        self._test: Optional[TestSet] = None
        self._noise: Any = FixedGaussian(5.0)
        self._sides: List[Optional[np.ndarray]] = [None, None]
        # per axis — a second add_side_info call must not clobber the
        # first axis's precision knobs
        self._beta_precisions: List[float] = [5.0, 5.0]
        self._sample_beta_precisions: List[bool] = [True, True]

    # -- construction ------------------------------------------------------

    def add_train_and_test(self, train, test=None, noise=None):
        """train: SparseMatrix | dense np.ndarray; test: (i, j, v)."""
        if isinstance(train, np.ndarray):
            train = dense_block(train)
        self._train = train
        if test is not None:
            self._test = make_test_set(*test)
        if noise is not None:
            self._noise = noise
        return self

    def add_side_info(self, axis: int, F: np.ndarray,
                      beta_precision: float = 5.0,
                      sample_beta_precision: bool = True):
        """Attach side information to rows (axis=0) or cols (axis=1).

        ``beta_precision`` / ``sample_beta_precision`` are stored PER
        AXIS — side info on both axes keeps each axis's own knobs.
        """
        if axis not in (0, 1):
            raise ValueError(
                f"unknown axis {axis!r}; valid axes: (0, 1) — 0 rows, "
                "1 cols")
        self._sides[axis] = np.asarray(F, np.float32)
        self._beta_precisions[axis] = beta_precision
        self._sample_beta_precisions[axis] = sample_beta_precision
        return self

    # -- model assembly ----------------------------------------------------

    def _builder(self) -> ModelBuilder:
        assert self._train is not None, "call add_train_and_test first"
        n_rows, n_cols = self._train.shape
        b = ModelBuilder(self.num_latent, self.use_pallas)
        for axis, (name, n) in enumerate((("rows", n_rows),
                                          ("cols", n_cols))):
            side = self._sides[axis]
            if side is not None:
                b.add_entity(
                    name, n, side_info=side,
                    beta_precision=self._beta_precisions[axis],
                    sample_beta_precision=self._sample_beta_precisions[
                        axis])
            else:
                b.add_entity(name, n, prior=self.prior_names[axis])
        b.add_block("rows", "cols", self._train, noise=self._noise,
                    test=self._test)
        return b

    def _build(self) -> Tuple[ModelDef, MFData]:
        """(ModelDef, MFData) — the benchmark/driver entry point."""
        model, data, _ = self._builder().build()
        return model, data

    # -- run ---------------------------------------------------------------

    def run(self, keep_samples: bool = False,
            resume: bool = False) -> SessionResult:
        sess = self._builder().session(
            burnin=self.burnin, nsamples=self.nsamples, seed=self.seed,
            mesh=self.mesh, pipeline=self.pipeline,
            chains=self.chains, chain_axis=self.chain_axis,
            save_freq=self.save_freq, save_dir=self.save_dir,
            verbose=self.verbose, callbacks=self.callbacks,
            recorder=self.recorder)
        return sess.run(keep_samples=keep_samples, resume=resume)


class GFASession:
    """Group Factor Analysis: M views sharing a sample entity.

    views: list of (N, D_m) dense arrays.  The shared entity gets a
    fixed-Normal prior; each view's loading matrix gets the
    spike-and-slab prior (paper Table 1, GFA row: "Normal + SnS").
    A thin wrapper over :class:`ModelBuilder` — the view star it
    composes is the identical ``ModelDef`` graph as before the
    builder, so the sampled chain is unchanged.

    Pass ``mesh`` to run the chain through the explicit distributed
    sweep: the spike-and-slab coordinate updates are counter-based per
    global row, so the sharded chain matches this single-device one at
    reduction-order tolerance — GFA is in the sharded subset, not on a
    pjit fallback.  ``pipeline`` selects the fixed-factor exchange
    ("eager" all-gather vs "ring" ppermute hops; None defers to
    ``REPRO_PIPELINE``).
    """

    def __init__(self, views: Sequence[np.ndarray], num_latent: int = 8,
                 burnin: int = 200, nsamples: int = 200, seed: int = 0,
                 noise: Any = None, use_pallas: bool = False,
                 zero_init_loadings: bool = True, mesh: Any = None,
                 pipeline: Optional[str] = None,
                 chains: Optional[int] = None,
                 chain_axis: Optional[str] = None,
                 save_freq: int = 0, save_dir: Optional[str] = None,
                 callbacks: Sequence[Callable[[SweepInfo], None]] = (),
                 recorder: Any = None):
        self.views = [np.asarray(v, np.float32) for v in views]
        self.recorder = recorder
        self.num_latent = num_latent
        self.burnin = burnin
        self.nsamples = nsamples
        self.seed = seed
        self.noise = noise or AdaptiveGaussian()
        self.use_pallas = use_pallas
        # Grow-from-empty: starting the loading matrices at zero lets
        # spike-and-slab components switch on one by one, which finds
        # the sparse mode that a random-init Gibbs chain cannot rotate
        # into (the GFA rotation degeneracy; R's CCAGFA needs an
        # explicit rotation-optimization step for the same reason).
        self.zero_init_loadings = zero_init_loadings
        self.mesh = mesh
        self.pipeline = pipeline
        self.chains = chains
        self.chain_axis = chain_axis
        self.save_freq = save_freq
        self.save_dir = save_dir
        self.callbacks = callbacks

    def _builder(self) -> ModelBuilder:
        N = self.views[0].shape[0]
        b = ModelBuilder(self.num_latent, self.use_pallas)
        # GFA pins Z ~ N(0, I) (fixed); SnS on the loadings does the
        # component selection (see FixedNormalPrior docstring).
        b.add_entity("samples", N, prior=FixedNormalPrior(self.num_latent))
        for m, X in enumerate(self.views):
            b.add_entity(f"view{m}", X.shape[1],
                         prior=SpikeAndSlabPrior(self.num_latent))
            b.add_block("samples", f"view{m}", X, noise=self.noise)
        return b

    def _build(self) -> Tuple[ModelDef, MFData]:
        model, data, _ = self._builder().build()
        return model, data

    def _zero_loadings(self, state: MFState) -> MFState:
        fs = list(state.factors)
        for e in range(1, len(fs)):
            fs[e] = jnp.zeros_like(fs[e])
        return state._replace(factors=tuple(fs))

    def run(self, resume: bool = False) -> Dict[str, Any]:
        sess = self._builder().session(
            burnin=self.burnin, nsamples=self.nsamples, seed=self.seed,
            mesh=self.mesh, pipeline=self.pipeline,
            chains=self.chains, chain_axis=self.chain_axis,
            save_freq=self.save_freq, save_dir=self.save_dir,
            callbacks=self.callbacks, recorder=self.recorder,
            init_transform=(self._zero_loadings
                            if self.zero_init_loadings else None),
            accumulate_factor_means=True)
        r = sess.run(resume=resume)
        # Multi-chain: "Z"/"W" follow CHAIN 0 — GFA's rotation/sign
        # indeterminacy makes pooling raw loadings across chains
        # meaningless (chains converge to differently-rotated modes).
        # The stacked per-chain means stay available as */_chains and
        # r.diagnostics carries the cross-chain R-hat/ESS evidence.
        if r.n_chains > 1:
            out = {
                "Z": r.factor_means[0][0],
                "W": [m[0] for m in r.factor_means[1:]],
                "Z_last": np.asarray(r.state.factors[0][0]),
                "W_last": [np.asarray(f[0])
                           for f in r.state.factors[1:]],
                "Z_chains": r.factor_means[0],
                "W_chains": r.factor_means[1:],
            }
        else:
            out = {
                "Z": r.factor_means[0],
                "W": r.factor_means[1:],
                "Z_last": np.asarray(r.state.factors[0]),
                "W_last": [np.asarray(f) for f in r.state.factors[1:]],
            }
        out.update({
            "rmse_train": [b.rmse_train_trace for b in r.blocks],
            "runtime_s": r.runtime_s,
            "compile_s": r.compile_s,
            "state": r.state,
            "diagnostics": r.diagnostics,
            "result": r,
        })
        return out


def smurff(train, test=None, side_info=(None, None), num_latent=16,
           burnin=100, nsamples=100, noise=None, seed=0,
           use_pallas=False, verbose=0, mesh=None, pipeline=None,
           chains=None, chain_axis=None,
           save_freq=0, save_dir=None) -> SessionResult:
    """One-call convenience API (mirrors ``smurff.smurff(...)``).

    Forwards the full knob set — including ``mesh``/``pipeline``
    (distributed sweep + exchange pipeline), ``chains``/``chain_axis``
    (vectorized multi-chain sampling + convergence diagnostics), and
    ``save_freq``/``save_dir`` (posterior-sample streaming for
    ``PredictSession``).
    """
    sess = TrainSession(num_latent=num_latent, burnin=burnin,
                        nsamples=nsamples, seed=seed,
                        use_pallas=use_pallas, verbose=verbose,
                        mesh=mesh, pipeline=pipeline,
                        chains=chains, chain_axis=chain_axis,
                        save_freq=save_freq, save_dir=save_dir)
    sess.add_train_and_test(train, test=test, noise=noise)
    for axis, F in enumerate(side_info):
        if F is not None:
            sess.add_side_info(axis, F)
    return sess.run()

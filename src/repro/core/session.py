"""High-level session API, mirroring SMURFF's Python ``TrainSession``.

    import repro.core as smurff

    session = smurff.TrainSession(num_latent=16, burnin=200,
                                  nsamples=400, seed=0)
    session.add_train_and_test(R_train, test=(i, j, v),
                               noise=smurff.AdaptiveGaussian())
    session.add_side_info(axis=0, F=features)     # -> Macau
    result = session.run()
    result.rmse_test, result.predictions

Composable exactly like the paper's Table 1: priors x noise x input
matrix types x side information.  ``GFASession`` builds the multi-block
group-factor-analysis layout on top of the same engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import (BlockDef, DenseBlock, EntityDef, ModelDef,
                     dense_block)
from .gibbs import MFData, MFState, gibbs_step, init_state
from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .predict import PredictAccumulator, TestSet, make_test_set
from .priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                     SpikeAndSlabPrior)
from .sparse import SparseMatrix


@dataclasses.dataclass
class SessionResult:
    rmse_test: Optional[float]
    auc_test: Optional[float]
    predictions: Optional[np.ndarray]
    pred_var: Optional[np.ndarray]
    rmse_train_trace: List[float]
    rmse_test_trace: List[float]
    nsamples: int
    runtime_s: float
    state: MFState
    samples: Optional[List[Tuple[np.ndarray, ...]]] = None


_PRIORS = {"normal": NormalPrior, "spikeandslab": SpikeAndSlabPrior,
           "fixednormal": FixedNormalPrior}


def _prior_by_name(name: str, num_latent: int):
    if name not in _PRIORS:
        raise ValueError(
            f"unknown prior {name!r}; valid priors: "
            f"{', '.join(sorted(_PRIORS))} (side information selects "
            "the macau prior automatically)")
    return _PRIORS[name](num_latent)


def _place_step(model: ModelDef, data: MFData, state: MFState,
                mesh: Any, pipeline: Optional[str]):
    """(data, state, step) — distributed through ``mesh`` when given.

    Shared by ``TrainSession`` and ``GFASession``: builds the explicit
    shard_map sweep with the requested exchange ``pipeline``
    ("eager"/"ring"/None-for-REPRO_PIPELINE) and places data/state on
    the mesh; without a mesh the single-device ``gibbs_step`` runs.
    Warns when the model falls outside the sharded subset (entity dims
    must divide the shard count) — the pjit fallback still samples the
    same chain, just with partitioner-placed collectives.  The
    ``pipeline`` knob is validated even without a mesh (a typo must
    raise, not silently run the single-device sweep), and asking for a
    pipeline WITH no mesh to run it on warns — there is no exchange to
    pipeline.
    """
    from .distributed import (distributed_supported,
                              make_distributed_step, resolve_pipeline)
    resolve_pipeline(pipeline)
    if mesh is None:
        if pipeline is not None:
            import warnings
            warnings.warn(
                f"pipeline={pipeline!r} has no effect without mesh=: "
                "the session runs the single-device sweep",
                stacklevel=3)
        return data, state, (lambda d, s: gibbs_step(model, d, s))
    if not distributed_supported(model, mesh, data):
        import warnings
        warnings.warn(
            "model is outside the sharded subset on this mesh (entity "
            "dims must divide the shard count); falling back to "
            "auto-partitioned pjit", stacklevel=3)
    step, ds, ss = make_distributed_step(model, mesh, data, state,
                                         pipeline=pipeline)
    return jax.device_put(data, ds), jax.device_put(state, ss), step


class TrainSession:
    """Single-R-matrix session (BMF / Macau / probit variants).

    Pass ``mesh`` to run the chain through the explicit distributed
    sweep (``make_distributed_step``); ``pipeline`` then selects the
    fixed-factor exchange — ``"eager"`` (one all-gather per half-sweep)
    or ``"ring"`` (``n_shards - 1`` double-buffered ppermute hops
    overlapping the local solves).  ``None`` defers to the
    ``REPRO_PIPELINE`` environment variable; either way the sampled
    chain matches the single-device one at reduction-order tolerance
    (counter-based per-row RNG — see ``core/distributed.py``).
    """

    def __init__(self, num_latent: int = 16, burnin: int = 100,
                 nsamples: int = 100, seed: int = 0,
                 priors: Sequence[str] = ("normal", "normal"),
                 use_pallas: bool = False, verbose: int = 0,
                 save_freq: int = 0, mesh: Any = None,
                 pipeline: Optional[str] = None):
        self.num_latent = num_latent
        self.burnin = burnin
        self.nsamples = nsamples
        self.seed = seed
        self.prior_names = tuple(p.replace("-", "").replace("_", "")
                                 for p in priors)
        self.use_pallas = use_pallas
        self.verbose = verbose
        self.save_freq = save_freq
        self.mesh = mesh
        self.pipeline = pipeline
        self._train: Optional[Any] = None
        self._test: Optional[TestSet] = None
        self._noise: Any = FixedGaussian(5.0)
        self._sides: List[Optional[np.ndarray]] = [None, None]
        self._beta_precision = 5.0
        self._sample_beta_precision = True

    # -- construction ------------------------------------------------------

    def add_train_and_test(self, train, test=None, noise=None):
        """train: SparseMatrix | dense np.ndarray; test: (i, j, v)."""
        if isinstance(train, np.ndarray):
            train = dense_block(train)
        self._train = train
        if test is not None:
            self._test = make_test_set(*test)
        if noise is not None:
            self._noise = noise
        return self

    def add_side_info(self, axis: int, F: np.ndarray,
                      beta_precision: float = 5.0,
                      sample_beta_precision: bool = True):
        """Attach side information to rows (axis=0) or cols (axis=1)."""
        self._sides[axis] = np.asarray(F, np.float32)
        self._beta_precision = beta_precision
        self._sample_beta_precision = sample_beta_precision
        return self

    # -- model assembly ----------------------------------------------------

    def _build(self) -> Tuple[ModelDef, MFData]:
        assert self._train is not None, "call add_train_and_test first"
        n_rows, n_cols = self._train.shape
        ents = []
        for axis, (name, n) in enumerate((("rows", n_rows),
                                          ("cols", n_cols))):
            side = self._sides[axis]
            if side is not None:
                prior = MacauPrior(
                    self.num_latent, side.shape[1],
                    beta_precision=self._beta_precision,
                    sample_beta_precision=self._sample_beta_precision)
            else:
                prior = _prior_by_name(self.prior_names[axis],
                                       self.num_latent)
            ents.append(EntityDef(name, n, prior))
        sparse = isinstance(self._train, SparseMatrix)
        model = ModelDef(tuple(ents),
                         (BlockDef(0, 1, self._noise, sparse),),
                         self.num_latent, self.use_pallas)
        sides = tuple(None if s is None else jnp.asarray(s)
                      for s in self._sides)
        data = MFData((self._train,), sides)
        return model, data

    # -- run ---------------------------------------------------------------

    def run(self, keep_samples: bool = False) -> SessionResult:
        model, data = self._build()
        state = init_state(model, data, self.seed)
        data, state, step = _place_step(model, data, state, self.mesh,
                                        self.pipeline)
        acc = PredictAccumulator(self._test) if self._test else None
        t0 = time.perf_counter()
        train_trace, test_trace = [], []
        samples: List[Tuple[np.ndarray, ...]] = []

        total = self.burnin + self.nsamples
        for sweep in range(total):
            state, metrics = step(data, state)
            train_trace.append(float(metrics["rmse_train_0"]))
            if sweep >= self.burnin:
                if acc is not None:
                    acc.update(state.factors[0], state.factors[1])
                    test_trace.append(
                        float(jnp.sqrt(jnp.mean(
                            (acc.mean - acc.test.v) ** 2))))
                if keep_samples:
                    samples.append(tuple(np.asarray(f)
                                         for f in state.factors))
            if self.verbose and (sweep % max(1, total // 20) == 0):
                ph = "burnin" if sweep < self.burnin else "sample"
                print(f"[{ph} {sweep:4d}] rmse_train="
                      f"{train_trace[-1]:.4f}")

        runtime = time.perf_counter() - t0
        is_probit = isinstance(self._noise, ProbitNoise)
        return SessionResult(
            rmse_test=(acc.rmse() if acc else None),
            auc_test=(acc.auc() if (acc and is_probit) else None),
            predictions=(np.asarray(acc.mean) if acc else None),
            pred_var=(np.asarray(acc.var) if acc else None),
            rmse_train_trace=train_trace,
            rmse_test_trace=test_trace,
            nsamples=self.nsamples,
            runtime_s=runtime,
            state=state,
            samples=samples if keep_samples else None,
        )


class GFASession:
    """Group Factor Analysis: M views sharing a sample entity.

    views: list of (N, D_m) dense arrays.  The shared entity gets a
    Normal prior; each view's loading matrix gets the spike-and-slab
    prior (paper Table 1, GFA row: "Normal + SnS").

    Pass ``mesh`` to run the chain through the explicit distributed
    sweep (``make_distributed_step``): the spike-and-slab coordinate
    updates are counter-based per global row, so the sharded chain
    matches this single-device one at reduction-order tolerance — GFA
    is in the sharded subset, not on a pjit fallback.  ``pipeline``
    selects the fixed-factor exchange ("eager" all-gather vs "ring"
    ppermute hops; None defers to ``REPRO_PIPELINE``).
    """

    def __init__(self, views: Sequence[np.ndarray], num_latent: int = 8,
                 burnin: int = 200, nsamples: int = 200, seed: int = 0,
                 noise: Any = None, use_pallas: bool = False,
                 zero_init_loadings: bool = True, mesh: Any = None,
                 pipeline: Optional[str] = None):
        self.views = [np.asarray(v, np.float32) for v in views]
        self.num_latent = num_latent
        self.burnin = burnin
        self.nsamples = nsamples
        self.seed = seed
        self.noise = noise or AdaptiveGaussian()
        self.use_pallas = use_pallas
        # Grow-from-empty: starting the loading matrices at zero lets
        # spike-and-slab components switch on one by one, which finds
        # the sparse mode that a random-init Gibbs chain cannot rotate
        # into (the GFA rotation degeneracy; R's CCAGFA needs an
        # explicit rotation-optimization step for the same reason).
        self.zero_init_loadings = zero_init_loadings
        self.mesh = mesh
        self.pipeline = pipeline

    def _build(self) -> Tuple[ModelDef, MFData]:
        N = self.views[0].shape[0]
        # GFA pins Z ~ N(0, I) (fixed); SnS on the loadings does the
        # component selection (see FixedNormalPrior docstring).
        ents = [EntityDef("samples", N, FixedNormalPrior(self.num_latent))]
        blocks = []
        payloads = []
        for m, X in enumerate(self.views):
            assert X.shape[0] == N, "views must share the sample axis"
            ents.append(EntityDef(f"view{m}", X.shape[1],
                                  SpikeAndSlabPrior(self.num_latent)))
            blocks.append(BlockDef(0, m + 1, self.noise, sparse=False))
            payloads.append(dense_block(X))
        model = ModelDef(tuple(ents), tuple(blocks), self.num_latent,
                         self.use_pallas)
        data = MFData(tuple(payloads), tuple([None] * len(ents)))
        return model, data

    def run(self) -> Dict[str, Any]:
        model, data = self._build()
        state = init_state(model, data, self.seed)
        if self.zero_init_loadings:
            fs = list(state.factors)
            for e in range(1, len(fs)):
                fs[e] = jnp.zeros_like(fs[e])
            state = state._replace(factors=tuple(fs))
        data, state, step = _place_step(model, data, state, self.mesh,
                                        self.pipeline)
        t0 = time.perf_counter()
        train_traces: List[List[float]] = [[] for _ in self.views]
        # posterior means of Z and the W_m
        sums = [jnp.zeros((e.n_rows, self.num_latent))
                for e in model.entities]
        n_acc = 0
        for sweep in range(self.burnin + self.nsamples):
            state, metrics = step(data, state)
            for m in range(len(self.views)):
                train_traces[m].append(float(metrics[f"rmse_train_{m}"]))
            if sweep >= self.burnin:
                sums = [s + f for s, f in zip(sums, state.factors)]
                n_acc += 1
        means = [np.asarray(s / max(n_acc, 1)) for s in sums]
        return {
            "Z": means[0],
            "W": means[1:],
            "Z_last": np.asarray(state.factors[0]),
            "W_last": [np.asarray(f) for f in state.factors[1:]],
            "rmse_train": train_traces,
            "runtime_s": time.perf_counter() - t0,
            "state": state,
        }


def smurff(train, test=None, side_info=(None, None), num_latent=16,
           burnin=100, nsamples=100, noise=None, seed=0,
           use_pallas=False, verbose=0) -> SessionResult:
    """One-call convenience API (mirrors ``smurff.smurff(...)``)."""
    sess = TrainSession(num_latent=num_latent, burnin=burnin,
                        nsamples=nsamples, seed=seed,
                        use_pallas=use_pallas, verbose=verbose)
    sess.add_train_and_test(train, test=test, noise=noise)
    for axis, F in enumerate(side_info):
        if F is not None:
            sess.add_side_info(axis, F)
    return sess.run()

"""TPU-native padded sparse matrices.

SMURFF (the CPU original) stores R in CSR and runs an irregular
parallel-for over rows.  On TPU irregularity is poison: we instead pad
every row's nonzeros to a common ``max_nnz`` ("padded-bucket CSR") so the
entire Gibbs half-sweep becomes one batched dense einsum over a
``(rows, max_nnz, K)`` gather — MXU-friendly, mask-correct, and
shardable along the row axis with no load imbalance by construction.

Both orientations are precomputed (rows for the U update, columns for
the V update) because the Gibbs sweep alternates between them.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedRows:
    """One orientation of a sparse matrix: per-row padded nonzeros.

    idx[i, t]  = column index of the t-th nonzero of row i (0 when padded)
    val[i, t]  = value of that nonzero (0 when padded)
    mask[i, t] = 1.0 for real entries, 0.0 for padding
    """

    idx: jnp.ndarray   # (n_rows, max_nnz) int32
    val: jnp.ndarray   # (n_rows, max_nnz) float32
    mask: jnp.ndarray  # (n_rows, max_nnz) float32
    n_other: int       # number of columns in this orientation

    def tree_flatten(self):
        return (self.idx, self.val, self.mask), (self.n_other,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_other=aux[0])

    @property
    def n_rows(self) -> int:
        return self.idx.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.idx.shape[1]

    @property
    def nnz(self) -> jnp.ndarray:
        return self.mask.sum()

    def with_values(self, new_val: jnp.ndarray) -> "PaddedRows":
        """Same pattern, different values (probit latent augmentation)."""
        return PaddedRows(self.idx, new_val, self.mask, self.n_other)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """A sparse matrix held in both orientations plus flat COO.

    ``rows``/``cols`` drive the two Gibbs half-sweeps; the flat COO view
    (``coo_i/coo_j/coo_v/coo_mask``) drives SDDMM-style residual and
    adaptive-noise computations.
    """

    rows: PaddedRows
    cols: PaddedRows
    coo_i: jnp.ndarray     # (nnz_pad,) int32
    coo_j: jnp.ndarray     # (nnz_pad,) int32
    coo_v: jnp.ndarray     # (nnz_pad,) float32
    coo_mask: jnp.ndarray  # (nnz_pad,) float32
    coo_rpos: jnp.ndarray  # (nnz_pad,) int32 flat pos into rows.val
    coo_cpos: jnp.ndarray  # (nnz_pad,) int32 flat pos into cols.val
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.rows, self.cols, self.coo_i, self.coo_j,
                self.coo_v, self.coo_mask, self.coo_rpos,
                self.coo_cpos), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> jnp.ndarray:
        return self.coo_mask.sum()

    def transpose(self) -> "SparseMatrix":
        return SparseMatrix(self.cols, self.rows, self.coo_j, self.coo_i,
                            self.coo_v, self.coo_mask, self.coo_cpos,
                            self.coo_rpos, (self.shape[1], self.shape[0]))

    def with_coo_values(self, new_v: jnp.ndarray) -> "SparseMatrix":
        """Rebuild both padded orientations from new COO values.

        NOT the probit path: ``ProbitNoise.augment`` draws its
        truncated-normal latents directly on the padded view it is
        handed, per-row counter-based (``gibbs.row_uniforms``), so the
        stored values stay the immutable binary observations and shard
        draws slice the single-device chain.  This rebuild exists for
        data-replacement workflows (bootstrap resampling, synthetic
        relabeling).  Padding entries carry scatter position
        ``rows.size`` (one-past-end dump slot), so they never corrupt
        real slots.
        """
        new_v = new_v * self.coo_mask

        def rebuild(padded: PaddedRows, pos: jnp.ndarray) -> PaddedRows:
            size = padded.idx.size
            buf = jnp.zeros((size + 1,), jnp.float32).at[pos].set(new_v)
            return padded.with_values(buf[:size].reshape(padded.idx.shape))

        return SparseMatrix(
            rows=rebuild(self.rows, self.coo_rpos),
            cols=rebuild(self.cols, self.coo_cpos),
            coo_i=self.coo_i, coo_j=self.coo_j, coo_v=new_v,
            coo_mask=self.coo_mask, coo_rpos=self.coo_rpos,
            coo_cpos=self.coo_cpos, shape=self.shape)


def _pad_axis(n_items: int, ids: np.ndarray, other: np.ndarray,
              vals: np.ndarray, max_nnz: Optional[int],
              round_to: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group COO entries by ``ids`` and pad to a common width.

    Also returns, per original-COO-order entry, its flat position in the
    padded ``val`` buffer (for value re-scatter).
    """
    order = np.argsort(ids, kind="stable")
    ids_s, other_s, vals_s = ids[order], other[order], vals[order]
    counts = np.bincount(ids_s, minlength=n_items)
    width = int(counts.max()) if counts.size and counts.max() > 0 else 1
    if max_nnz is not None:
        width = max(width, 1)
        if width > max_nnz:
            raise ValueError(f"row with {width} nnz exceeds max_nnz={max_nnz}")
        width = max_nnz
    width = max(1, -(-width // round_to) * round_to)  # round up

    idx = np.zeros((n_items, width), dtype=np.int32)
    val = np.zeros((n_items, width), dtype=np.float32)
    mask = np.zeros((n_items, width), dtype=np.float32)
    # position of each entry within its row
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(ids_s.size) - starts[ids_s]
    idx[ids_s, pos] = other_s
    val[ids_s, pos] = vals_s
    mask[ids_s, pos] = 1.0
    # flat position in COO order (invert the sort permutation)
    flat = np.zeros(ids.size, dtype=np.int64)
    flat[order] = ids_s * width + pos
    return idx, val, mask, flat


def from_coo(i: np.ndarray, j: np.ndarray, v: np.ndarray,
             shape: Tuple[int, int], *,
             max_nnz_row: Optional[int] = None,
             max_nnz_col: Optional[int] = None,
             round_to: int = 8) -> SparseMatrix:
    """Build a :class:`SparseMatrix` from COO triplets (host-side numpy)."""
    i = np.asarray(i, dtype=np.int64)
    j = np.asarray(j, dtype=np.int64)
    v = np.asarray(v, dtype=np.float32)
    n_rows, n_cols = shape

    ridx, rval, rmask, rflat = _pad_axis(n_rows, i, j, v, max_nnz_row,
                                         round_to)
    cidx, cval, cmask, cflat = _pad_axis(n_cols, j, i, v, max_nnz_col,
                                         round_to)

    nnz = v.size
    nnz_pad = max(1, -(-nnz // 128) * 128)
    coo_i = np.zeros((nnz_pad,), dtype=np.int32)
    coo_j = np.zeros((nnz_pad,), dtype=np.int32)
    coo_v = np.zeros((nnz_pad,), dtype=np.float32)
    coo_m = np.zeros((nnz_pad,), dtype=np.float32)
    # padding entries scatter to the one-past-end dump slot
    coo_rp = np.full((nnz_pad,), ridx.size, dtype=np.int64)
    coo_cp = np.full((nnz_pad,), cidx.size, dtype=np.int64)
    coo_i[:nnz], coo_j[:nnz], coo_v[:nnz], coo_m[:nnz] = i, j, v, 1.0
    coo_rp[:nnz], coo_cp[:nnz] = rflat, cflat

    return SparseMatrix(
        rows=PaddedRows(jnp.asarray(ridx), jnp.asarray(rval),
                        jnp.asarray(rmask), n_cols),
        cols=PaddedRows(jnp.asarray(cidx), jnp.asarray(cval),
                        jnp.asarray(cmask), n_rows),
        coo_i=jnp.asarray(coo_i), coo_j=jnp.asarray(coo_j),
        coo_v=jnp.asarray(coo_v), coo_mask=jnp.asarray(coo_m),
        coo_rpos=jnp.asarray(coo_rp, dtype=jnp.int32),
        coo_cpos=jnp.asarray(coo_cp, dtype=jnp.int32),
        shape=(n_rows, n_cols),
    )


def from_dense(R: np.ndarray, *, keep_zeros: bool = False,
               round_to: int = 8) -> SparseMatrix:
    """Dense / fully-known matrices.

    ``keep_zeros=True`` treats every cell as observed ("sparse fully
    known" / "dense" in the paper's taxonomy); otherwise zeros are
    unknowns.
    """
    R = np.asarray(R, dtype=np.float32)
    if keep_zeros:
        i, j = np.meshgrid(np.arange(R.shape[0]), np.arange(R.shape[1]),
                           indexing="ij")
        i, j, v = i.ravel(), j.ravel(), R.ravel()
    else:
        i, j = np.nonzero(R)
        v = R[i, j]
    return from_coo(i, j, v, R.shape, round_to=round_to)


def random_sparse(key, shape: Tuple[int, int], density: float,
                  rank: int = 4, noise: float = 0.1,
                  binary: bool = False,
                  round_to: int = 8):
    """Synthetic planted low-rank sparse matrix (ChEMBL-like benchmark).

    Returns (SparseMatrix train, (i,j,v) test triplets, (U*, V*) truth).
    """
    rng = np.random.default_rng(int(key) if np.isscalar(key) else 0)
    n_rows, n_cols = shape
    U = rng.normal(size=(n_rows, rank)).astype(np.float32)
    V = rng.normal(size=(n_cols, rank)).astype(np.float32)
    full = U @ V.T + noise * rng.normal(size=shape).astype(np.float32)
    if binary:
        full = (full > 0).astype(np.float32)

    nnz = int(density * n_rows * n_cols)
    nnz = max(nnz, n_rows + n_cols)  # keep every row/col touched
    flat = rng.choice(n_rows * n_cols, size=nnz, replace=False)
    i, j = np.divmod(flat, n_cols)
    v = full[i, j]
    # 90/10 train/test split
    n_test = max(1, nnz // 10)
    test = (i[:n_test], j[:n_test], v[:n_test])
    tr = slice(n_test, None)
    mat = from_coo(i[tr], j[tr], v[tr], shape, round_to=round_to)
    return mat, test, (U, V)


@partial(jax.jit, static_argnames=())
def gather_predict(U: jnp.ndarray, V: jnp.ndarray,
                   i: jnp.ndarray, j: jnp.ndarray) -> jnp.ndarray:
    """pred[e] = U[i[e]] · V[j[e]]  (SDDMM gather-dot, jnp reference)."""
    return jnp.einsum("ek,ek->e", U[i], V[j])

"""The Gibbs sweep (paper Algorithm 1) as pure, jit-able JAX.

One ``gibbs_step`` performs, per entity in order:

  1. resample the entity's prior hyper-parameters from its current
     factor matrix ("sample hyper-parameters ... based on U/V"),
  2. resample the whole factor matrix from its conditional
     ("for all movies/users: update model") — one *batched* pass:
     masked Gram + rhs (Pallas kernel or jnp oracle), batched Cholesky,
     batched triangular solves, one fused N(0,1) draw,

then resamples every block's noise state from the residuals and reports
train-RMSE metrics.

The CPU original loops rows with OpenMP; here the full half-sweep is a
handful of large dense ops, which is what the TPU (and the distributed
layer in ``distributed.py``) wants.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.lax.linalg import cholesky, triangular_solve

from .. import compat
from ..kernels import ops
from .blocks import DenseBlock, ModelDef
from .noise import ProbitNoise
from .priors import MacauPrior, SpikeAndSlabPrior, chol_solve
from .sparse import SparseMatrix


class MFState(NamedTuple):
    """Full sampler state — everything needed to restart the chain."""

    key: jax.Array                      # PRNG key (counter-based)
    factors: Tuple[jnp.ndarray, ...]    # per entity (N_e, K)
    hypers: Tuple[Any, ...]             # per entity prior hyper pytree
    noises: Tuple[Any, ...]             # per block noise state pytree
    step: jnp.ndarray                   # int32 sweep counter


class MFData(NamedTuple):
    """Observed data — static across the chain."""

    blocks: Tuple[Any, ...]             # SparseMatrix | DenseBlock
    sides: Tuple[Optional[jnp.ndarray], ...]   # per entity side info


def init_state(model: ModelDef, data: MFData, seed: int = 0,
               init_scale: float = 1.0,
               key: Optional[jax.Array] = None) -> MFState:
    """Fresh chain state from the STATIC graph alone — ``data`` is
    accepted for signature symmetry but never read.  That contract is
    load-bearing: ``modelspec.state_template`` rebuilds checkpoint
    templates from a ``model.json`` spec with no data payloads, so any
    future data-dependent initialization must stay out of the state
    *structure*.

    ``key`` overrides the ``PRNGKey(seed)`` derivation — the multi-chain
    layer passes ``chain_keys(seed, C)[c]`` here so chain ``c`` of a
    C-chain run is exactly the single-chain run seeded with that key.
    """
    if key is None:
        key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(model.entities) + 1)
    factors = []
    hypers = []
    for e, ent in enumerate(model.entities):
        factors.append(init_scale * jax.random.normal(
            keys[e], (ent.n_rows, model.num_latent), jnp.float32))
        hypers.append(ent.prior.init(keys[e], ent.n_rows))
    noises = tuple(b.noise.init() for b in model.blocks)
    return MFState(keys[-1], tuple(factors), tuple(hypers), noises,
                   jnp.asarray(0, jnp.int32))


# ---------------------------------------------------------------------------
# multi-chain helpers
# ---------------------------------------------------------------------------

def chain_keys(seed: int, chains: int):
    """Per-chain root PRNG keys.

    Chain 0 is ``PRNGKey(seed)`` — NOT folded — so chain 0 of any
    C-chain run is bitwise the existing single-chain golden chain.
    Chains 1..C-1 fold the chain index into the base key.
    """
    base = jax.random.PRNGKey(seed)
    return [base if c == 0 else jax.random.fold_in(base, c)
            for c in range(chains)]


def init_chain_states(model: ModelDef, data: MFData, seed: int,
                      chains: int, init_scale: float = 1.0):
    """List of C independent fresh states (one per chain key)."""
    return [init_state(model, data, seed, init_scale, key=k)
            for k in chain_keys(seed, chains)]


def stack_states(states) -> MFState:
    """Stack per-chain states along a new leading chain axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(stacked: MFState, c: int) -> MFState:
    """Slice chain ``c`` out of a stacked multi-chain state."""
    return jax.tree_util.tree_map(lambda x: x[c], stacked)


def multi_chain_step(model: ModelDef, data: MFData, stacked: MFState
                     ) -> Tuple[MFState, Dict[str, jnp.ndarray]]:
    """One Gibbs sweep of every chain in a stacked state.

    Maps ``gibbs_step`` over the leading chain axis with ``lax.map``
    rather than ``vmap``: vmap batches the per-chain ops into wider
    kernels whose reductions tile differently, drifting ~1e-6 from the
    single-chain program, while ``lax.map`` keeps each chain's subgraph
    identical to ``gibbs_step`` — measured bitwise-equal to C
    independent seeded runs.  Metrics come back stacked with a leading
    ``(C,)`` axis.
    """
    return jax.lax.map(lambda st: gibbs_step(model, data, st), stacked)


@partial(jax.jit, static_argnums=0)
def multi_chain_step_jit(model: ModelDef, data: MFData, stacked: MFState):
    """Jitted ``multi_chain_step`` (single-device multi-chain path)."""
    return multi_chain_step(model, data, stacked)


# ---------------------------------------------------------------------------
# per-block contributions to an entity's conditional
# ---------------------------------------------------------------------------

def _sparse_contrib(model: ModelDef, mat: SparseMatrix, as_row: bool,
                    fixed: jnp.ndarray, u_cur: jnp.ndarray,
                    noise, nstate, key, row_offset=0):
    """alpha-weighted (gram, rhs) of one sparse block for one entity.

    ``row_offset`` is the global index of the operand's row 0 — nonzero
    on row shards of the distributed sweep, where it keeps the probit
    augmentation draws bitwise slices of the single-device draws.
    """
    padded = mat.rows if as_row else mat.cols
    vg = fixed[padded.idx]                      # (R, T, K)
    if isinstance(noise, ProbitNoise):
        pred = jnp.einsum("rtk,rk->rt", vg, u_cur)
        vals, alpha = noise.augment(key, nstate, pred, padded.val,
                                    padded.mask, row_offset=row_offset)
    else:
        vals, alpha = noise.augment(key, nstate, None, padded.val,
                                    padded.mask, row_offset=row_offset)
    gram, rhs = ops.gram_and_rhs(vg, vals, padded.mask,
                                 use_pallas=model.use_pallas)
    return alpha * gram, alpha * rhs            # (R,K,K), (R,K)


def _dense_contrib(payload: DenseBlock, as_row: bool, fixed: jnp.ndarray,
                   u_cur: jnp.ndarray, noise, nstate, key, row_offset=0):
    """Contributions of a dense block.

    Returns (gram_shared | None, gram_rows | None, rhs).  Reads the
    stored orientation (``X`` or ``XT``) rather than transposing, so
    inside the distributed sweep a shard's slice of either orientation
    is self-contained (see ``DenseBlock``); ``row_offset`` as in
    ``_sparse_contrib``.
    """
    X, m = payload.oriented(as_row)             # (R, C)
    if isinstance(noise, ProbitNoise):
        pred = u_cur @ fixed.T
        vals, alpha = noise.augment(key, nstate, pred, X, m,
                                    row_offset=row_offset)
    else:
        vals, alpha = noise.augment(key, nstate, None, X, m,
                                    row_offset=row_offset)
    if payload.fully:
        gram_shared = alpha * (fixed.T @ fixed)             # (K, K)
        rhs = alpha * (vals @ fixed)                        # (R, K)
        return gram_shared, None, rhs
    gram_rows = alpha * jnp.einsum("rc,ck,cl->rkl", m, fixed, fixed)
    rhs = alpha * ((vals * m) @ fixed)
    return None, gram_rows, rhs


def _dense_chunk_contrib(vals: jnp.ndarray, m: jnp.ndarray, fully: bool,
                         chunk: jnp.ndarray, c0):
    """Chunk-accumulating form of ``_dense_contrib``'s moment math.

    ``chunk`` holds rows ``[c0, c0 + Cc)`` of the fixed factor (one
    ring-exchange hop's worth); ``vals``/``m`` are the full oriented
    (R, C) payload, already noise-augmented.  Returns this chunk's
    additive contribution ``(gram_shared | None, gram_rows | None,
    rhs)``.  Summed over any partition of ``[0, C)`` the contributions
    equal the monolithic moments up to f32 summation order — the
    per-chunk compute the ring pipeline overlaps with the next hop's
    ``ppermute`` (property-tested against the monolithic forms in
    ``tests/test_properties.py``, including the ``fully=True`` shared-
    Gram fast path and uneven chunk widths).  The alpha weight is
    applied by the caller AFTER accumulation, not per chunk.
    """
    Cc = chunk.shape[0]
    vs = jax.lax.dynamic_slice_in_dim(vals, c0, Cc, axis=1)
    if fully:
        return chunk.T @ chunk, None, vs @ chunk
    ms = jax.lax.dynamic_slice_in_dim(m, c0, Cc, axis=1)
    gram_rows = jnp.einsum("rc,ck,cl->rkl", ms, chunk, chunk)
    return None, gram_rows, (vs * ms) @ chunk


# ---------------------------------------------------------------------------
# factor conditionals
# ---------------------------------------------------------------------------

def row_normals(key, n_rows: int, num_latent: int, row_offset=0):
    """(n_rows, K) standard normals drawn row-by-row, counter-based.

    Row i's draw comes from ``fold_in(key, row_offset + i)`` — a pure
    function of the sweep key and the row's GLOBAL index, never of the
    batch shape.  A shard holding rows [off, off + n) therefore draws
    exactly the bits the single-device sweep draws for those rows,
    which is what makes the distributed chain bit-compatible with the
    reference chain (and elastic re-meshes safe).

    Probit's truncated-normal augmentation obeys the same contract
    through :func:`row_uniforms` below — every stochastic per-row
    quantity in the sweep is a counter-based function of the global
    row index, so the whole model zoo (Gaussian AND probit, sparse AND
    dense) re-meshes without perturbing the chain.
    """
    rows = row_offset + jnp.arange(n_rows)
    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(rows)
    return jax.vmap(
        lambda k: jax.random.normal(k, (num_latent,), jnp.float32))(keys)


def row_uniforms(key, n_rows: int, width: int, row_offset=0, *,
                 minval=0.0, maxval=1.0):
    """(n_rows, width) uniforms drawn row-by-row, counter-based.

    The uniform sibling of :func:`row_normals`, with the identical
    contract: row i's ``width`` draws come from
    ``fold_in(key, row_offset + i)`` — a pure function of the sweep
    key and the row's GLOBAL index, never of the batch shape.  This is
    what ``ProbitNoise.augment`` consumes for its truncated-normal
    latents, so probit shard draws are bitwise slices of the
    single-device chain exactly like the factor draws above.
    """
    rows = row_offset + jnp.arange(n_rows)
    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(rows)
    return jax.vmap(
        lambda k: jax.random.uniform(k, (width,), jnp.float32,
                                     minval, maxval))(keys)


def row_bernoulli(key, p, row_offset=0):
    """Bernoulli(p) draws, counter-based row-by-row.

    ``p`` is (n_rows,) or (n_rows, W); row i's draw(s) consume the
    uniforms of ``fold_in(key, row_offset + i)`` via
    :func:`row_uniforms` — the same contract as ``row_normals``: a
    pure function of the sweep key and the row's GLOBAL index, never
    of the batch shape.  This is what the spike-and-slab inclusion
    indicators consume (folded per component), so SnS shard draws are
    bitwise slices of the single-device chain and the GFA composition
    can run the explicit distributed sweep.
    """
    n_rows = p.shape[0]
    width = 1 if p.ndim == 1 else p.shape[1]
    u = row_uniforms(key, n_rows, width, row_offset)
    if p.ndim == 1:
        u = u[:, 0]
    return u < p


def _sample_normal_factor(key, gram_shared, gram_rows, rhs, Lam_p, b_p,
                          row_offset=0):
    """u_i ~ N(Lam_i^{-1} b_i, Lam_i^{-1}) batched over rows.

    gram_shared (K,K) and/or gram_rows (N,K,K); rhs (N,K); Lam_p (K,K);
    b_p (K,) or (N,K).  ``row_offset`` is the global index of row 0 —
    nonzero on row shards of the distributed sweep.
    """
    b = rhs + b_p if b_p.ndim == 2 else rhs + b_p[None, :]
    z = row_normals(key, b.shape[0], b.shape[1], row_offset)
    if gram_rows is None:
        # one shared precision -> one Cholesky, matrix solves
        Lam = gram_shared + Lam_p                            # (K,K)
        L = cholesky(Lam)
        y = triangular_solve(L, b.T, left_side=True, lower=True)
        mean = triangular_solve(L, y, left_side=True, lower=True,
                                transpose_a=True).T          # (N,K)
        dz = triangular_solve(L, z.T, left_side=True, lower=True,
                              transpose_a=True).T
        return mean + dz
    Lam = gram_rows + (gram_shared + Lam_p)[None, :, :] \
        if gram_shared is not None else gram_rows + Lam_p[None, :, :]
    L = cholesky(Lam)                                        # (N,K,K)
    mean = chol_solve(L, b)
    dz = triangular_solve(L, z[..., None], left_side=True, lower=True,
                          transpose_a=True)[..., 0]
    return mean + dz


def _sample_sns_factor(model: ModelDef, data: MFData, key,
                       e: int, u: jnp.ndarray, hyper,
                       fixed_for, noises, row_offset=0) -> jnp.ndarray:
    """Coordinate-wise spike-and-slab update for entity ``e``.

    For each latent component k (sequentially — the conditionals are
    coupled through the residual), vectorized over rows:

        q_ik = tau_k + sum_b alpha_b sum_t m f_k^2
        l_ik = sum_b alpha_b sum_t m (r - pred_{-k}) f_k
        odds = rho/(1-rho) * sqrt(tau_k/q) * exp(l^2 / 2q)
        s ~ Bern(odds/(1+odds));  u_ik = s * N(l/q, 1/q)

    ``fixed_for(o)`` returns the dense (pre-gathered) fixed factor of
    entity ``o``; ``u`` and the block payload rows may be a row shard,
    with ``row_offset`` the global index of row 0.  Both q and l are
    row-local, and every stochastic quantity — the Bernoulli inclusion
    indicator (``row_bernoulli``) and the slab normal (``row_normals``),
    each folded per component — is a counter-based function of the
    GLOBAL row index, so this body runs unchanged inside
    ``distributed._sharded_sweep`` and shard draws are bitwise slices
    of the single-device chain.
    """
    K = model.num_latent
    touching = model.blocks_touching(e)

    # gather per-block views once
    views = []
    for bi, as_row in touching:
        blk = model.blocks[bi]
        payload = data.blocks[bi]
        fixed = fixed_for(blk.other(e))
        alpha = noises[bi]["alpha"]
        if blk.sparse:
            padded = payload.rows if as_row else payload.cols
            vg = fixed[padded.idx]                     # (R,T,K)
            pred = jnp.einsum("rtk,rk->rt", vg, u)
            views.append(("sp", vg, padded.val, padded.mask, pred, alpha))
        else:
            X, m = payload.oriented(as_row)
            pred = u @ fixed.T
            kind = "df" if payload.fully else "dn"
            views.append((kind, fixed, X, m, pred, alpha))

    rho, tau = hyper["rho"], hyper["tau"]
    k_incl, k_slab = jax.random.split(key)

    # The K coordinate updates are a lax.scan, not a Python loop, so
    # large-K GFA compiles one body instead of K copies (flat compile
    # time; carried over from PR 3's TODO).  Kinds and the per-view
    # constants (Fv, val, m, alpha) are loop-invariant closures; the
    # carry is (u, per-view residual predictions).  Every indexed read
    # (Fv[..., k], tau[k], rho[k]) and the per-component ``fold_in``
    # take the traced k, which lowers to gathers/dynamic-slices with
    # the same values as the unrolled loop — the golden GFA chains pin
    # this bitwise.
    kinds = tuple(v[0] for v in views)
    consts = tuple((Fv, val, m, alpha)
                   for _, Fv, val, m, _, alpha in views)
    preds0 = tuple(v[4] for v in views)

    def body(carry, k):
        u, preds = carry
        q = tau[k]
        l = jnp.zeros((u.shape[0],), jnp.float32)
        new_preds = []
        for kind, (Fv, val, m, alpha), pred in zip(kinds, consts, preds):
            if kind == "sp":
                fk = Fv[:, :, k]                        # (R,T)
                pred_mk = pred - u[:, k][:, None] * fk
                q = q + alpha * jnp.sum(fk * fk * m, axis=-1)
                l = l + alpha * jnp.sum((val - pred_mk) * m * fk, axis=-1)
            elif kind == "df":
                fk = Fv[:, k]                           # (C,)
                pred_mk = pred - jnp.outer(u[:, k], fk)
                # fully observed: every row shares the one scalar
                # sum_c fk_c^2 and the mask multiply drops — the GFA
                # production views take this branch, saving an
                # O(rows x cols) matvec per component per view
                q = q + alpha * jnp.sum(fk * fk)
                l = l + alpha * ((val - pred_mk) @ fk)
            else:
                fk = Fv[:, k]                           # (C,)
                pred_mk = pred - jnp.outer(u[:, k], fk)
                # masked: sum_c m_rc fk_c^2  (per row)
                q = q + alpha * (m @ (fk * fk))
                l = l + alpha * (((val - pred_mk) * m) @ fk)
            new_preds.append(pred_mk)

        mu = l / q
        log_odds = (jnp.log(rho[k]) - jnp.log1p(-rho[k])
                    + 0.5 * (jnp.log(tau[k]) - jnp.log(q))
                    + 0.5 * mu * l)
        p_incl = jax.nn.sigmoid(log_odds)
        s = row_bernoulli(jax.random.fold_in(k_incl, k), p_incl,
                          row_offset).astype(jnp.float32)
        eps = row_normals(jax.random.fold_in(k_slab, k), u.shape[0], 1,
                          row_offset)[:, 0]
        u_k = s * (mu + eps / jnp.sqrt(q))
        u = u.at[:, k].set(u_k)

        # restore preds with the new component folded back in
        restored = tuple(
            pred_mk + (u_k[:, None] * Fv[:, :, k] if kind == "sp"
                       else jnp.outer(u_k, Fv[:, k]))
            for kind, (Fv, _, _, _), pred_mk in
            zip(kinds, consts, new_preds))
        return (u, restored), None

    (u, _), _ = jax.lax.scan(body, (u, preds0), jnp.arange(K))
    return u


# ---------------------------------------------------------------------------
# the full sweep
# ---------------------------------------------------------------------------

def _gather_view(model: ModelDef, factors):
    """The factor views used as gather/contraction operands.

    With ``bf16_gather`` every consumer (half-sweep gathers, SDDMM
    metrics) shares ONE bf16 copy, so the sharded all-gather moves
    half the bytes and is CSE'd across uses — casting inside each
    consumer instead makes XLA materialize both precisions (measured:
    2x the collective bytes, not 0.5x).
    """
    if not model.bf16_gather:
        return factors

    mesh = compat.get_abstract_mesh()
    axes = () if mesh is None else tuple(
        a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def cast(f):
        if not axes or f.shape[0] % n != 0:
            return f.astype(jnp.bfloat16)
        # EXPLICIT bf16 all-gather.  Leaving this to the partitioner
        # does not work: XLA's algebraic simplifier sinks the bf16
        # convert past any volume-reducing gather, so the implicit
        # all-gather moves f32 again (measured: 2x wire bytes).  An
        # explicit collective on the bf16 shard cannot be rewritten.
        def body(x):
            return jax.lax.all_gather(x.astype(jnp.bfloat16), axes,
                                      axis=0, tiled=True)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=jax.sharding.PartitionSpec(axes),
            out_specs=jax.sharding.PartitionSpec(),
            check=False)(f)

    return tuple(cast(f) for f in factors)


def _entity_update(model: ModelDef, data: MFData, key, e: int,
                   factors, hypers, noises):
    """Hyper-sample + factor-sample for one entity; returns updates."""
    ent = model.entities[e]
    prior = ent.prior
    side = data.sides[e]
    k_hyp, k_fac, k_blk = jax.random.split(key, 3)
    u = factors[e]

    # 1. hyper-parameters from the current factor (Algorithm 1 line 2/5)
    if isinstance(prior, MacauPrior):
        hyper = prior.sample_hyper(k_hyp, u, hypers[e], side=side)
    else:
        hyper = prior.sample_hyper(k_hyp, u, hypers[e])

    # 2. factor matrix from its conditional
    gview = _gather_view(model, factors)
    if isinstance(prior, SpikeAndSlabPrior):
        u_new = _sample_sns_factor(model, data, k_fac, e, u, hyper,
                                   lambda o: gview[o], noises)
        return u_new, hyper

    Lam_p = prior.precision_term(hyper)
    if isinstance(prior, MacauPrior):
        b_p = prior.mean_term(hyper, ent.n_rows, side=side)
    else:
        b_p = prior.mean_term(hyper, ent.n_rows)

    gram_shared = None
    gram_rows = None
    rhs_acc = jnp.zeros((ent.n_rows, model.num_latent), jnp.float32)
    bkeys = jax.random.split(k_blk, max(1, len(model.blocks)))
    for bi, as_row in model.blocks_touching(e):
        blk = model.blocks[bi]
        fixed = gview[blk.other(e)]
        if blk.sparse:
            g, r = _sparse_contrib(model, data.blocks[bi], as_row, fixed,
                                   u, blk.noise, noises[bi], bkeys[bi])
            gram_rows = g if gram_rows is None else gram_rows + g
            rhs_acc = rhs_acc + r
        else:
            gs, gr, r = _dense_contrib(data.blocks[bi], as_row, fixed,
                                       u, blk.noise, noises[bi], bkeys[bi])
            if gs is not None:
                gram_shared = gs if gram_shared is None else gram_shared + gs
            if gr is not None:
                gram_rows = gr if gram_rows is None else gram_rows + gr
            rhs_acc = rhs_acc + r

    if gram_shared is None and gram_rows is None:
        gram_shared = jnp.zeros((model.num_latent, model.num_latent),
                                jnp.float32)
    u_new = _sample_normal_factor(k_fac, gram_shared, gram_rows,
                                  rhs_acc, Lam_p, b_p)
    return u_new, hyper


def _block_pred_observed(model: ModelDef, data: MFData, bi: int, factors):
    """Predictions + (vals, mask) at a block's observed entries."""
    blk = model.blocks[bi]
    U = factors[blk.row_entity]
    V = factors[blk.col_entity]
    payload = data.blocks[bi]
    if blk.sparse:
        pred = ops.sddmm(U[payload.coo_i], V[payload.coo_j],
                         use_pallas=model.use_pallas)
        return pred, payload.coo_v, payload.coo_mask
    pred = U @ V.T
    return pred, payload.X, payload.mask


@partial(jax.jit, static_argnums=0)
def gibbs_step(model: ModelDef, data: MFData, state: MFState
               ) -> Tuple[MFState, Dict[str, jnp.ndarray]]:
    """One full Gibbs sweep over all entities + noise states."""
    key, *ekeys = jax.random.split(state.key, len(model.entities) + 2)
    nkey = ekeys[-1]
    factors = list(state.factors)
    hypers = list(state.hypers)
    noises = list(state.noises)

    for e in range(len(model.entities)):
        u_new, hyper = _entity_update(model, data, ekeys[e], e,
                                      tuple(factors), tuple(hypers),
                                      tuple(noises))
        factors[e] = u_new
        hypers[e] = hyper

    metrics = {}
    nkeys = jax.random.split(nkey, max(1, len(model.blocks)))
    gview = _gather_view(model, tuple(factors))
    for bi, blk in enumerate(model.blocks):
        pred, vals, mask = _block_pred_observed(model, data, bi, gview)
        noises[bi] = blk.noise.sample_state(nkeys[bi], noises[bi], pred,
                                            vals, mask)
        se = jnp.sum(((vals - pred) * mask) ** 2)
        # all-masked blocks (padded shard views) have nnz == 0: report
        # rmse 0 instead of 0/0 -> NaN poisoning the metric trace
        metrics[f"rmse_train_{bi}"] = jnp.sqrt(
            se / jnp.maximum(jnp.sum(mask), 1.0))
        metrics[f"alpha_{bi}"] = noises[bi]["alpha"]

    new_state = MFState(key, tuple(factors), tuple(hypers), tuple(noises),
                        state.step + 1)
    return new_state, metrics


@partial(jax.jit, static_argnums=(0, 3))
def run_sweeps(model: ModelDef, data: MFData, state: MFState, n: int):
    """``lax.scan`` over n sweeps; returns final state + stacked metrics.

    Used by benchmarks to amortize dispatch overhead; the session layer
    uses single ``gibbs_step`` calls to collect posterior samples.
    """

    def body(st, _):
        st, m = gibbs_step(model, data, st)
        return st, m

    return jax.lax.scan(body, state, None, length=n)

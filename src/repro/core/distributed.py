"""Distributed Gibbs sweep — the paper's §7 future work, realized.

SMURFF was single-node OpenMP; the GASPI multi-node port was a separate
code base.  Here the *same* ``gibbs_step`` distributes through pjit on
the production mesh:

* rows of every factor (and the corresponding padded-CSR block rows)
  are sharded over all mesh axes flattened — the MF analogue of the
  paper's parallel-for over users/movies, but across chips;
* the *fixed* factor of each half-sweep is needed dense on every chip:
  XLA inserts exactly one all-gather per half-sweep for it (verified in
  the dry-run HLO), matching the GASPI implementation's communication
  pattern (Vander Aa et al. 2017);
* the Normal-Wishart hyper-sample needs global factor moments: those
  reduce over the row shards with one small all-reduce (K and K^2
  sized payloads — negligible);
* counter-based per-row RNG means the sampled chain is bit-identical
  regardless of the mesh, which is what makes elastic restart safe.

``FACTOR_AXES`` flattens ("pod", "data", "model") — MF has no tensor
axis worth model-parallelism (K is tiny), so every chip takes a row
slice.  This gives perfect load balance by construction (padded rows).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .blocks import ModelDef
from .gibbs import MFData, MFState, gibbs_step

FACTOR_AXES = ("pod", "data", "model")


def _axes_in(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in FACTOR_AXES if a in mesh.axis_names)


def row_sharding(mesh: Mesh) -> NamedSharding:
    """Shard axis 0 over every mesh axis; replicate the rest."""
    return NamedSharding(mesh, P(_axes_in(mesh)))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _n_shards(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _axes_in(mesh)]))


def _fit_rows(mesh: Mesh, x) -> NamedSharding:
    """Row-shard when the leading dim divides the mesh, else replicate
    (elastic re-meshes may not divide the COO padding width)."""
    if hasattr(x, "ndim") and x.ndim >= 1 \
            and x.shape[0] % _n_shards(mesh) == 0:
        return row_sharding(mesh)
    return replicated(mesh)


def state_shardings(model: ModelDef, mesh: Mesh,
                    state: MFState) -> MFState:
    """Sharding pytree matching an MFState: factors row-sharded,
    hyper/noise state replicated (they are K-sized)."""
    rep = replicated(mesh)

    def shard_like(x):
        return rep

    factors = tuple(_fit_rows(mesh, f) for f in state.factors)
    hypers = jax.tree.map(shard_like, state.hypers)
    noises = jax.tree.map(shard_like, state.noises)
    return MFState(rep, factors, hypers, noises, rep)


def data_shardings(model: ModelDef, mesh: Mesh, data: MFData) -> MFData:
    """Both padded orientations row-sharded; COO and sides likewise.

    Any leaf whose leading dim does not divide the shard count falls
    back to replication — the fit rule that keeps elastic re-meshes
    onto awkward survivor counts legal.  (The COO view only drives
    test-point prediction and adaptive noise.)
    """

    def for_block(blk):
        return jax.tree.map(lambda x: _fit_rows(mesh, x), blk)

    blocks = tuple(for_block(b) for b in data.blocks)
    sides = tuple(None if s is None else _fit_rows(mesh, s)
                  for s in data.sides)
    return MFData(blocks, sides)


def make_distributed_step(model: ModelDef, mesh: Mesh, data: MFData,
                          state: MFState):
    """jit ``gibbs_step`` with explicit in/out shardings on ``mesh``.

    Returns (step_fn, placed_data, placed_state) — on real hardware the
    placement transfers; in the dry-run we only ``.lower().compile()``.
    """
    ss = state_shardings(model, mesh, state)
    ds = data_shardings(model, mesh, data)
    fn = jax.jit(
        partial(gibbs_step, model),
        in_shardings=(ds, ss),
        out_shardings=(ss, replicated(mesh)),
    )
    return fn, ds, ss


def pad_rows_to(n: int, devices: int) -> int:
    """Round a row count up so every shard is equal (elastic re-bucket)."""
    return int(-(-n // devices) * devices)

"""Distributed Gibbs sweep — the paper's §7 future work, realized.

SMURFF was single-node OpenMP; the GASPI multi-node port was a separate
code base.  Here the sweep distributes through an EXPLICIT ``shard_map``
over the production mesh (``compat.shard_map`` — version-portable):

* rows of every factor (and the corresponding padded-CSR block rows)
  are sharded over all mesh axes flattened — the MF analogue of the
  paper's parallel-for over users/movies, but across chips;
* the *fixed* factor of each half-sweep is needed (in full) by every
  chip.  HOW it travels is the ``pipeline`` knob of
  ``make_distributed_step`` (default from the ``REPRO_PIPELINE``
  environment variable, else ``"eager"``):

  - ``"eager"``: exactly ONE explicit ``all_gather`` per half-sweep
    (bf16 when ``ModelDef.bf16_gather`` — cast BEFORE the collective,
    halving the wire bytes), matching the GASPI implementation's
    communication pattern (Vander Aa et al. 2017); the gather of the
    final factor is reused for the residual metrics, so a sweep over
    E entities moves exactly E gathers;
  - ``"ring"``: the same bytes travel as ``n_shards - 1``
    ``lax.ppermute`` hops around the flattened mesh ring
    (``_ring_accumulate``) — ZERO all-gathers in the program, and the
    hop for chunk t+1 is issued before chunk t is consumed, so the
    wire transfer overlaps the local math (the asynchronous /
    limited-communication BMF exchange of arXiv:1705.10633 and
    arXiv:2004.02561).  Dense non-probit blocks of the earlier
    half-sweep consume the circulating chunks directly through
    chunk-accumulated Gram/RHS moments (``gibbs._dense_chunk_contrib``)
    and never materialize the dense fixed view at all; every other
    consumer (padded-CSR gathers, probit's pred-dependent
    augmentation, the SnS coordinate loop, end-of-sweep metrics)
    reassembles the view from the chunks by ``dynamic_update_slice``
    — bitwise the all-gathered array, so those chains are
    draw-for-draw the eager chains.  Ring-vs-eager parity and the
    collective-permute/no-all-gather HLO contract are pinned in
    ``tests/test_distributed.py``; the overlap-aware exchange term is
    modeled in ``launch/mf_dryrun.py`` (eager stays the default until
    that term wins on the target).
* the Normal-Wishart hyper-sample needs global factor moments: those
  reduce over the row shards with K- and K^2-sized ``psum`` payloads
  (D-sized for the Macau link terms) and are then resampled as an
  identical replicated computation on every shard;
* dense blocks shard the same way: both stored orientations
  (``DenseBlock.X``/``XT``) are row-sharded along their leading axis,
  and each shard's Gram/RHS contribution contracts its slice against
  the gathered fixed factor — fully-observed blocks additionally share
  ONE replicated (K, K) Gram across all rows;
* probit noise rides through the same machinery because its
  truncated-normal augmentation is per-row counter-based
  (``gibbs.row_uniforms`` threaded through ``ProbitNoise.augment`` via
  ``row_offset``) — the compound-activity classification workload of
  the paper runs the explicit sweep, not the pjit fallback;
* the Macau side-Gramian ``FtF = side^T side`` is STATIC data: it is
  computed once at ``make_distributed_step`` placement time and passed
  in replicated, so the per-sweep hyper path carries no (D, D) psum;
* spike-and-slab priors (the GFA composition, paper Table 1
  "Normal + SnS") run the same schedule: the coordinate-wise q/l
  moments are row-local given the gathered fixed factor, so the
  per-component loop adds ZERO collectives, and the hyper update
  reduces exactly two K-sized psums (inclusion counts + per-component
  sum of squares, ``SpikeAndSlabPrior.sample_hyper_moments``); the
  inclusion indicators and slab normals are counter-based per row
  (``gibbs.row_bernoulli``/``row_normals``, folded per component);
* counter-based per-row RNG (``gibbs.row_normals`` for the factor
  draws, ``gibbs.row_uniforms`` for the probit latents,
  ``gibbs.row_bernoulli`` for the SnS inclusions) means each
  shard draws exactly the bits the single-device sweep draws for its
  rows (asserted bitwise in tests), so the sampled chain agrees with
  the single-device chain up to reduction-order ULPs — psum grouping
  of the K/K^2 moments and XLA's batch-size-dependent tiling of the
  per-row solves; measured ~1e-5 after 3 sweeps, asserted at 2e-4 —
  which is what makes elastic restart onto a different mesh safe.
  Verified against the single-device chain on 8 simulated CPU devices
  in ``tests/test_distributed.py`` (Gaussian, probit, dense-block, and
  spike-and-slab/GFA models) and through an on-disk checkpoint +
  shrunk-mesh restore in ``tests/test_elastic.py``.

Models outside the sharded subset (self-blocks, row counts that do not
divide the mesh) fall back to auto-sharded pjit over the same
shardings — slower collectives, same results.  Every prior in the
paper's Table 1 now runs the explicit sweep.

``FACTOR_AXES`` flattens ("pod", "data", "model") — MF has no tensor
axis worth model-parallelism (K is tiny), so every chip takes a row
slice.  This gives perfect load balance by construction (padded rows).
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import compat
from .blocks import DenseBlock, ModelDef
from .gibbs import (MFData, MFState, _dense_chunk_contrib, _dense_contrib,
                    _sample_normal_factor, _sample_sns_factor,
                    _sparse_contrib, gibbs_step)
from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                     SpikeAndSlabPrior)

FACTOR_AXES = ("pod", "data", "model")

PIPELINES = ("eager", "ring")

# below this shard count the ring loop is unrolled (tests pin one
# collective-permute per hop on the HLO); above it a lax.scan keeps the
# program size flat (production meshes: one while loop, trip S - 1,
# which launch/hlo_cost.py multiplies back out)
RING_UNROLL_MAX = 32


def resolve_pipeline(pipeline: Optional[str] = None) -> str:
    """Validate the exchange-pipeline knob, defaulting from the
    ``REPRO_PIPELINE`` environment variable (CI runs a ring leg that
    way), else ``"eager"``."""
    if pipeline is None:
        pipeline = os.environ.get("REPRO_PIPELINE", "eager")
    if pipeline not in PIPELINES:
        raise ValueError(
            f"unknown pipeline {pipeline!r}; valid pipelines: "
            f"{', '.join(PIPELINES)} (the REPRO_PIPELINE environment "
            "variable sets the default)")
    return pipeline


def _axes_in(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in FACTOR_AXES if a in mesh.axis_names)


def row_sharding(mesh: Mesh) -> NamedSharding:
    """Shard axis 0 over every mesh axis; replicate the rest."""
    return NamedSharding(mesh, P(_axes_in(mesh)))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _n_shards(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _axes_in(mesh)]))


def _fit_rows_spec(mesh: Mesh, x) -> P:
    """Row-shard when the leading dim divides the mesh, else replicate
    (elastic re-meshes may not divide the COO padding width)."""
    if hasattr(x, "ndim") and x.ndim >= 1 \
            and x.shape[0] % _n_shards(mesh) == 0:
        return P(_axes_in(mesh))
    return P()


def state_specs(model: ModelDef, mesh: Mesh, state: MFState) -> MFState:
    """PartitionSpec pytree matching an MFState: factors row-sharded,
    hyper/noise state replicated (they are K-sized)."""
    factors = tuple(_fit_rows_spec(mesh, f) for f in state.factors)
    hypers = jax.tree.map(lambda x: P(), state.hypers)
    noises = jax.tree.map(lambda x: P(), state.noises)
    return MFState(P(), factors, hypers, noises, P())


def stacked_state_specs(model: ModelDef, mesh: Mesh, stacked: MFState,
                        chain_axis: Optional[str] = None) -> MFState:
    """PartitionSpec pytree for a chain-stacked ``(C, ...)`` MFState.

    The leading chain dim shards over ``chain_axis`` when given (chains
    x shards fills the mesh) and replicates otherwise; factor ROWS (now
    axis 1) shard over the FACTOR_AXES exactly as in ``state_specs``.
    """
    ca = chain_axis

    def fit_rows(x):
        if hasattr(x, "ndim") and x.ndim >= 2 \
                and x.shape[1] % _n_shards(mesh) == 0:
            return P(ca, _axes_in(mesh))
        return P(ca)

    factors = tuple(fit_rows(f) for f in stacked.factors)
    hypers = jax.tree.map(lambda x: P(ca), stacked.hypers)
    noises = jax.tree.map(lambda x: P(ca), stacked.noises)
    return MFState(P(ca), factors, hypers, noises, P(ca))


def data_specs(model: ModelDef, mesh: Mesh, data: MFData) -> MFData:
    """Both padded orientations row-sharded; COO and sides likewise.

    Any leaf whose leading dim does not divide the shard count falls
    back to replication — the fit rule that keeps elastic re-meshes
    onto awkward survivor counts legal.  (The COO view only drives
    test-point prediction and adaptive noise.)
    """

    def for_block(blk):
        return jax.tree.map(lambda x: _fit_rows_spec(mesh, x), blk)

    blocks = tuple(for_block(b) for b in data.blocks)
    sides = tuple(None if s is None else _fit_rows_spec(mesh, s)
                  for s in data.sides)
    return MFData(blocks, sides)


def _with_mesh(mesh: Mesh, tree):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), tree,
                        is_leaf=lambda x: isinstance(x, P))


def state_shardings(model: ModelDef, mesh: Mesh,
                    state: MFState) -> MFState:
    """NamedSharding pytree for device_put, mirroring ``state_specs``."""
    return _with_mesh(mesh, state_specs(model, mesh, state))


def data_shardings(model: ModelDef, mesh: Mesh, data: MFData) -> MFData:
    """NamedSharding pytree for device_put, mirroring ``data_specs``."""
    return _with_mesh(mesh, data_specs(model, mesh, data))


def distributed_unsupported_reason(model: ModelDef, mesh: Mesh,
                                   data: Optional[MFData] = None
                                   ) -> Optional[str]:
    """Why this model falls off the explicit sweep — None when it fits.

    The predicate behind :func:`distributed_supported`, kept separate
    so the session layer's pjit-fallback warning can NAME the reason
    (an arbitrary builder-composed graph has many more ways to miss
    the subset than the old two hardcoded session shapes did).
    """
    S = _n_shards(mesh)
    for e, ent in enumerate(model.entities):
        if ent.n_rows % S != 0:
            return (f"entity {ent.name!r} has {ent.n_rows} rows, not "
                    f"divisible by the {S}-shard mesh")
        if not isinstance(ent.prior,
                          (NormalPrior, MacauPrior, FixedNormalPrior,
                           SpikeAndSlabPrior)):
            return (f"entity {ent.name!r} prior "
                    f"{type(ent.prior).__name__} has no sharded moment "
                    "algebra")
        if isinstance(ent.prior, MacauPrior) and (
                data is None or data.sides[e] is None):
            return (f"entity {ent.name!r} has a Macau prior but no "
                    "side-information matrix in the data")
    for bi, blk in enumerate(model.blocks):
        if blk.row_entity == blk.col_entity:
            return (f"block {bi} relates entity {blk.row_entity} to "
                    "itself (self-blocks are not sharded)")
        if not isinstance(blk.noise,
                          (FixedGaussian, AdaptiveGaussian, ProbitNoise)):
            return (f"block {bi} noise {type(blk.noise).__name__} has "
                    "no sharded residual reduction")
        if not blk.sparse and data is not None:
            payload = data.blocks[bi]
            # both orientations must be stored for per-shard reads
            if not isinstance(payload, DenseBlock) \
                    or getattr(payload, "XT", None) is None:
                return (f"block {bi} dense payload lacks the stored "
                        "transposed orientation (use dense_block())")
    return None


def distributed_supported(model: ModelDef, mesh: Mesh,
                          data: Optional[MFData] = None) -> bool:
    """True when the explicit shard_map sweep covers this model.

    Whitelist, not blacklist: only prior/noise types whose sharded
    moment algebra ``_sharded_sweep`` implements are admitted — a new
    prior whose ``sample_hyper`` reads the factor matrix would
    otherwise silently sample per-shard-divergent hypers (out_specs
    P() with check off never validates replication).  The subset now
    spans sparse AND dense blocks under Gaussian, adaptive-Gaussian,
    and probit noise (probit's truncated-normal draws are per-row
    counter-based, so shard draws slice the single-device chain), and
    every Table-1 prior including spike-and-slab (counter-based
    ``row_bernoulli``/``row_normals`` coordinate updates + two K-sized
    hyper psums) — the GFA composition runs the explicit sweep, and so
    does any multi-relation graph ``ModelBuilder`` composes from the
    admitted pieces.  Outside it (self-blocks, non-dividing row
    counts, dense payloads without the stored transposed orientation)
    ``make_distributed_step`` falls back to pjit;
    :func:`distributed_unsupported_reason` names the offending piece.
    """
    return distributed_unsupported_reason(model, mesh, data) is None


# ---------------------------------------------------------------------------
# the explicit shard_map sweep
# ---------------------------------------------------------------------------

def _shard_index(axes: Tuple[str, ...], sizes: Tuple[int, ...]):
    """Flattened row-shard index of this device (major-to-minor = axes
    order, matching both NamedSharding(P(axes)) layout and tiled
    all_gather concatenation order)."""
    idx = jnp.asarray(0, jnp.int32)
    for a, sz in zip(axes, sizes):
        idx = idx * sz + jax.lax.axis_index(a)
    return idx


def _ring_accumulate(axes: Tuple[str, ...], sizes: Tuple[int, ...],
                     shard, f_shard, init, chunk_fn):
    """Circulate this device's shard of a fixed factor around the ring.

    Device ``s`` starts from its own shard and receives the remaining
    ``S - 1`` chunks via ``lax.ppermute`` over the flattened mesh axes
    (exactly ``S - 1`` hops; no all-gather anywhere).  The hop moving
    chunk ``t + 1`` is issued BEFORE chunk ``t`` is consumed, so on
    targets with async collectives the wire transfer overlaps
    ``chunk_fn``'s compute — the double-buffered exchange of the
    asynchronous-communication BMF (arXiv:1705.10633).

    ``chunk_fn(acc, chunk, c0) -> acc`` must be pure; ``c0`` is the
    global row index of the chunk's first row (traced — device ``s``
    sees chunk ``(s + t) % S`` at step ``t``).  Unrolled below
    ``RING_UNROLL_MAX`` shards, ``lax.scan``-rolled above it.
    """
    S = int(np.prod(sizes))
    rows_per = f_shard.shape[0]
    perm = [((j + 1) % S, j) for j in range(S)]

    def c0_at(t):
        return ((shard + t) % S) * rows_per

    if S <= RING_UNROLL_MAX:
        acc, chunk = init, f_shard
        for t in range(S):
            nxt = jax.lax.ppermute(chunk, axes, perm) if t < S - 1 \
                else None
            acc = chunk_fn(acc, chunk, c0_at(t))
            chunk = nxt
        return acc

    def body(carry, t):
        chunk, acc = carry
        nxt = jax.lax.ppermute(chunk, axes, perm)
        return (nxt, chunk_fn(acc, chunk, c0_at(t))), None

    (chunk, acc), _ = jax.lax.scan(body, (f_shard, init),
                                   jnp.arange(S - 1))
    return chunk_fn(acc, chunk, c0_at(S - 1))


def _streamable(model: ModelDef, bi: int, e: int) -> bool:
    """True when block ``bi``'s contribution to entity ``e``'s update
    can consume the ring exchange chunk-by-chunk, never materializing
    the dense fixed view: dense payload, pred-free augmentation (non-
    probit), and ``e`` is the EARLIER-updated side (the later side's
    view is the one the end-of-sweep metrics reuse, so that half-sweep
    reassembles it instead)."""
    blk = model.blocks[bi]
    return (not blk.sparse
            and not isinstance(blk.noise, ProbitNoise)
            and max(blk.row_entity, blk.col_entity) != e)


def _psum_hyper(model: ModelDef, e: int, key, u, hyper, side, axes,
                ftf=None):
    """Hyper-sample from psummed moments — replicated-identical output.

    The collective payloads are K (factor sum), K^2 (factor Gramian)
    and, for Macau link terms, D/DxK — negligible next to the factor
    all-gathers.  The Macau (D, D) side-Gramian ``ftf`` is NOT psummed
    here: it is static data, computed once at placement time in
    ``make_distributed_step`` and passed in replicated.
    """
    prior = model.entities[e].prior
    N = model.entities[e].n_rows
    psum = partial(jax.lax.psum, axis_name=axes)
    if isinstance(prior, MacauPrior):
        Uc = u - side @ hyper["beta"]
        return prior.sample_hyper_moments(
            key, hyper,
            F_sum=psum(Uc.sum(axis=0)), F_cov=psum(Uc.T @ Uc), n_rows=N,
            StF=psum(side.T @ u), s_side=psum(side.sum(axis=0)),
            FtF=ftf)
    if isinstance(prior, NormalPrior):
        return prior.sample_hyper_moments(
            key, hyper, F_sum=psum(u.sum(axis=0)), F_cov=psum(u.T @ u),
            n_rows=N)
    if isinstance(prior, SpikeAndSlabPrior):
        # two K-sized payloads: per-component inclusion counts and
        # sum of squares — the ONLY collectives SnS adds to a sweep
        s = (jnp.abs(u) > 0).astype(jnp.float32)
        return prior.sample_hyper_moments(
            key, hyper, n_incl=psum(s.sum(axis=0)),
            sumsq=psum((u * u).sum(axis=0)), n_rows=N)
    # moment-free priors (FixedNormalPrior): identical on every shard
    return prior.sample_hyper(key, u, hyper)


def _sharded_sweep(model: ModelDef, axes: Tuple[str, ...],
                   sizes: Tuple[int, ...], pipeline: str, ftf,
                   data: MFData, state: MFState):
    """One full Gibbs sweep, executed per-shard inside shard_map.

    Mirrors ``gibbs.gibbs_step`` exactly — same key-splitting sequence,
    same per-row draws (offset by the shard's global row origin), same
    per-block contributions (sparse padded-CSR or dense, Gaussian or
    probit-augmented) — with the three global couplings made explicit:
    one fixed-factor exchange per half-sweep (a blocking ``all_gather``
    in the ``"eager"`` pipeline, ``S - 1`` double-buffered ``ppermute``
    hops in ``"ring"`` — see the module docstring), K/K^2 psums for the
    hyper moments, scalar psums for residual SSE/nnz.  ``ftf`` holds
    the per-entity Macau side-Gramians, precomputed and replicated
    (None for non-Macau entities).
    """
    S = int(np.prod(sizes))
    shard = _shard_index(axes, sizes)
    ring = pipeline == "ring"
    key, *ekeys = jax.random.split(state.key, len(model.entities) + 2)
    nkey = ekeys[-1]
    factors = list(state.factors)          # row shards (N_e / S, K)
    hypers = list(state.hypers)
    noises = list(state.noises)

    gathered = {}   # entity -> full exchange-view factor on this shard

    def _wire_cast(f):
        return f.astype(jnp.bfloat16) if model.bf16_gather else f

    def fixed_view(o: int):
        """The dense fixed factor of entity ``o`` on this shard.

        Eager: ONE tiled all-gather, bf16 when the model flags it
        (cast before the collective — half the bytes).  Ring: the same
        bytes arrive as ``S - 1`` ppermute hops and are reassembled by
        ``dynamic_update_slice`` — bitwise the all-gathered array (pure
        data movement, no arithmetic), with zero all-gathers in the
        program.
        """
        if o not in gathered:
            f = _wire_cast(factors[o])
            if ring:
                full0 = jnp.zeros((model.entities[o].n_rows, f.shape[1]),
                                  f.dtype)
                ag = _ring_accumulate(
                    axes, sizes, shard, f, full0,
                    lambda acc, chunk, c0:
                        jax.lax.dynamic_update_slice(acc, chunk, (c0, 0)))
            else:
                ag = jax.lax.all_gather(f, axes, axis=0, tiled=True)
            if model.bf16_gather:
                # Keep the gathered value bf16 in the optimized graph:
                # without the barrier the algebraic simplifier may hoist
                # the consumers' bf16->f32 upcast through the collective
                # and move f32 on the wire.  (XLA:CPU additionally
                # normalizes bf16 collectives to convert-gather-convert
                # — backend detail; the dry-run test asserts the bf16
                # exchange on the lowered StableHLO, pre-backend.)
                ag = jax.lax.optimization_barrier(ag)
            gathered[o] = ag
        return gathered[o]

    for e in range(len(model.entities)):
        ent = model.entities[e]
        side = data.sides[e]
        k_hyp, k_fac, k_blk = jax.random.split(ekeys[e], 3)
        u = factors[e]
        row_offset = shard * (ent.n_rows // S)

        # 1. hyper-parameters from psummed global moments
        hyper = _psum_hyper(model, e, k_hyp, u, hypers[e], side, axes,
                            ftf=ftf[e])

        # 2. this shard's factor rows from their conditional
        prior = ent.prior
        if isinstance(prior, SpikeAndSlabPrior):
            # coordinate-wise SnS update: q/l moments are row-local
            # given the gathered fixed factor, and the inclusion/slab
            # draws are counter-based on the global row index — the
            # body is the single-device one, offset to this shard.
            # Zero per-component collectives.
            factors[e] = _sample_sns_factor(model, data, k_fac, e, u,
                                            hyper, fixed_view, noises,
                                            row_offset=row_offset)
            hypers[e] = hyper
            gathered.pop(e, None)
            continue
        Lam_p = prior.precision_term(hyper)
        if isinstance(prior, MacauPrior):
            b_p = prior.mean_term(hyper, ent.n_rows, side=side)
        else:
            b_p = prior.mean_term(hyper, ent.n_rows)

        gram_shared = None
        gram_rows = None
        rhs_acc = jnp.zeros((ent.n_rows // S, model.num_latent),
                            jnp.float32)
        bkeys = jax.random.split(k_blk, max(1, len(model.blocks)))
        touching = model.blocks_touching(e)
        streamed = set()
        if ring:
            # Chunk-accumulated circulations: group the touching blocks
            # by their fixed entity; a group streams (per-chunk Gram/RHS
            # folded into the ring, the dense fixed view NEVER
            # materialized) when every consumer qualifies — see
            # ``_streamable``.  Non-streamed groups fall through to the
            # reassembled ``fixed_view`` below.
            by_fixed = {}
            for bi, as_row in touching:
                by_fixed.setdefault(model.blocks[bi].other(e),
                                    []).append((bi, as_row))
            for o, group in by_fixed.items():
                if o in gathered or not all(_streamable(model, bi, e)
                                            for bi, _ in group):
                    continue
                streamed.update(bi for bi, _ in group)
                # augment once per block up front (pred-free for the
                # non-probit noises this path admits); one circulation
                # then folds every block's moment contributions chunk
                # by chunk, overlapping the next hop's wire transfer
                prep = []
                for bi, as_row in group:
                    blk = model.blocks[bi]
                    X, msk = data.blocks[bi].oriented(as_row)
                    vals, alpha = blk.noise.augment(
                        bkeys[bi], noises[bi], None, X, msk,
                        row_offset=row_offset)
                    prep.append((data.blocks[bi].fully, vals, msk, alpha))
                K = model.num_latent
                R = ent.n_rows // S
                init = tuple(
                    (jnp.zeros((K, K), jnp.float32) if fully else None,
                     None if fully else jnp.zeros((R, K, K), jnp.float32),
                     jnp.zeros((R, K), jnp.float32))
                    for fully, _, _, _ in prep)

                def chunk_fn(acc, chunk, c0, prep=prep):
                    if model.bf16_gather:
                        # same guard as fixed_view's reassembled view:
                        # without the barrier the algebraic simplifier
                        # may hoist the moment math's bf16->f32 upcast
                        # through the ppermute chain and move f32 on
                        # the wire
                        chunk = jax.lax.optimization_barrier(chunk)
                    out = []
                    for (fully, vals, msk, _), (gs, gr, rh) in zip(prep,
                                                                   acc):
                        dgs, dgr, drh = _dense_chunk_contrib(
                            vals, msk, fully, chunk, c0)
                        out.append((
                            None if gs is None else gs + dgs,
                            None if gr is None else gr + dgr,
                            rh + drh))
                    return tuple(out)

                accs = _ring_accumulate(axes, sizes, shard,
                                        _wire_cast(factors[o]), init,
                                        chunk_fn)
                for (fully, _, _, alpha), (gs, gr, rh) in zip(prep, accs):
                    if gs is not None:
                        gram_shared = alpha * gs if gram_shared is None \
                            else gram_shared + alpha * gs
                    if gr is not None:
                        gram_rows = alpha * gr if gram_rows is None \
                            else gram_rows + alpha * gr
                    rhs_acc = rhs_acc + alpha * rh
        for bi, as_row in touching:
            if bi in streamed:
                continue
            blk = model.blocks[bi]
            fixed = fixed_view(blk.other(e))
            if blk.sparse:
                g, r = _sparse_contrib(model, data.blocks[bi], as_row,
                                       fixed, u, blk.noise, noises[bi],
                                       bkeys[bi], row_offset=row_offset)
                gram_rows = g if gram_rows is None else gram_rows + g
            else:
                gs, g, r = _dense_contrib(data.blocks[bi], as_row,
                                          fixed, u, blk.noise,
                                          noises[bi], bkeys[bi],
                                          row_offset=row_offset)
                if gs is not None:
                    # fully-observed: ONE (K, K) Gram shared by every
                    # row, built from the gathered (replicated) fixed
                    # factor — identical on all shards by construction
                    gram_shared = gs if gram_shared is None \
                        else gram_shared + gs
                if g is not None:
                    gram_rows = g if gram_rows is None else gram_rows + g
            rhs_acc = rhs_acc + r

        if gram_shared is None and gram_rows is None:
            gram_shared = jnp.zeros(   # entity with no observed blocks
                (model.num_latent, model.num_latent), jnp.float32)
        factors[e] = _sample_normal_factor(k_fac, gram_shared, gram_rows,
                                           rhs_acc, Lam_p, b_p,
                                           row_offset=row_offset)
        hypers[e] = hyper
        gathered.pop(e, None)   # any cached view of e is now stale

    # 3. noise states + metrics from the residuals, re-using the last
    # half-sweep's gather: orient each block along its later-updated
    # entity, whose fixed factor (the earlier-updated one) is already
    # dense on every shard.
    metrics = {}
    nkeys = jax.random.split(nkey, max(1, len(model.blocks)))
    psum = partial(jax.lax.psum, axis_name=axes)
    for bi, blk in enumerate(model.blocks):
        e_last = max(blk.row_entity, blk.col_entity)
        payload = data.blocks[bi]
        fixed = gathered[blk.other(e_last)]
        v = factors[e_last]
        if model.bf16_gather:
            v = v.astype(jnp.bfloat16)
        if blk.sparse:
            padded = payload.rows if blk.row_entity == e_last \
                else payload.cols
            vals, msk = padded.val, padded.mask
            pred = jnp.einsum("rtk,rk->rt", fixed[padded.idx], v)
        else:
            vals, msk = payload.oriented(blk.row_entity == e_last)
            pred = v @ fixed.T
        resid = (vals - pred) * msk
        se = psum(jnp.sum(resid * resid))
        nnz = psum(jnp.sum(msk))
        noises[bi] = blk.noise.sample_state(nkeys[bi], noises[bi], pred,
                                            vals, msk, sse=se, nnz=nnz)
        metrics[f"rmse_train_{bi}"] = jnp.sqrt(se / jnp.maximum(nnz, 1.0))
        metrics[f"alpha_{bi}"] = noises[bi]["alpha"]

    new_state = MFState(key, tuple(factors), tuple(hypers), tuple(noises),
                        state.step + 1)
    return new_state, metrics


def _macau_ftf(model: ModelDef, data: MFData):
    """Per-entity Macau side-Gramians ``side^T side`` — STATIC data.

    Computed ONCE here (placement time) so the per-sweep loop carries
    no (D, D) psum; asserted on the HLO in tests/test_distributed.py.
    Abstract (ShapeDtypeStruct) sides — the dry-run path, which only
    lowers — produce abstract Gramians.
    """
    out = []
    for e, ent in enumerate(model.entities):
        side = data.sides[e]
        if not isinstance(ent.prior, MacauPrior) or side is None:
            out.append(None)
        elif isinstance(side, jax.ShapeDtypeStruct):
            D = side.shape[1]
            out.append(jax.ShapeDtypeStruct((D, D), jnp.float32))
        else:
            side = jnp.asarray(side, jnp.float32)
            out.append(side.T @ side)
    return tuple(out)


def _validate_chain_axis(mesh: Mesh, chains: int,
                         chain_axis: Optional[str]) -> None:
    if chain_axis is None:
        return
    if chain_axis in FACTOR_AXES:
        raise ValueError(
            f"chain_axis {chain_axis!r} collides with the row-sharding "
            f"axes {FACTOR_AXES}; name the chain mesh axis something "
            "else (conventionally 'chain')")
    if chain_axis not in mesh.axis_names:
        raise ValueError(
            f"chain_axis {chain_axis!r} is not a mesh axis; this mesh "
            f"has {tuple(mesh.axis_names)}")
    size = mesh.shape[chain_axis]
    if chains % size != 0:
        raise ValueError(
            f"chains={chains} does not divide over chain_axis "
            f"{chain_axis!r} of size {size}")


def make_multi_chain_step(model: ModelDef, mesh: Mesh, data: MFData,
                          stacked: MFState,
                          pipeline: Optional[str] = None,
                          chains: int = 1,
                          chain_axis: Optional[str] = None):
    """The distributed sweep over a chain-stacked ``(C, ...)`` state.

    Chains map over the leading axis with ``lax.map`` INSIDE the
    shard_map body — each chain runs the identical ``_sharded_sweep``
    subgraph, so chain c of the multi-chain program is bitwise the
    single-chain distributed run keyed with ``chain_keys(seed, C)[c]``
    (vmap would batch the per-chain reductions and drift ~1e-6).

    With ``chain_axis`` the stacked state shards its chain dim over
    that mesh axis and rows over the remaining FACTOR_AXES — chains x
    shards fills the pod, each device sweeps ``C / mesh.shape[chain_
    axis]`` local chains, and the per-sweep collective census equals
    the single-chain census on the smaller per-chain shard group
    (``contract_for(..., chains=C, chain_axis_size=...)`` derives it).
    Without ``chain_axis`` every shard sweeps all C chains serially and
    the census scales by C.

    Returns (step_fn, placed_data_shardings, stacked_state_shardings);
    metrics come back stacked ``(C,)`` per quantity.
    """
    pipeline = resolve_pipeline(pipeline)
    _validate_chain_axis(mesh, chains, chain_axis)
    sss = stacked_state_specs(model, mesh, stacked, chain_axis)
    ss = _with_mesh(mesh, sss)
    ds = data_shardings(model, mesh, data)
    mspec = P(chain_axis)
    if distributed_supported(model, mesh, data):
        axes = _axes_in(mesh)
        sizes = compat.mesh_axis_sizes(mesh, axes)
        ftf = _macau_ftf(model, data)
        ftf_specs = jax.tree.map(lambda x: P(), ftf)

        def sweep_chains(ftf_, data_, stacked_):
            return jax.lax.map(
                lambda st: _sharded_sweep(model, axes, sizes, pipeline,
                                          ftf_, data_, st),
                stacked_)

        body = compat.shard_map(
            sweep_chains,
            mesh=mesh,
            in_specs=(ftf_specs,
                      data_specs(model, mesh, data),
                      sss),
            out_specs=(sss, mspec),
            check=False)
        jfn = jax.jit(body,
                      in_shardings=(_with_mesh(mesh, ftf_specs), ds, ss),
                      out_shardings=(ss, NamedSharding(mesh, mspec)))

        def fn(data, state):
            return jfn(ftf, data, state)

        fn.lower = lambda data, state: jfn.lower(ftf, data, state)
    else:
        fn = jax.jit(
            lambda data_, stacked_: jax.lax.map(
                lambda st: gibbs_step(model, data_, st), stacked_),
            in_shardings=(ds, ss),
            out_shardings=(ss, NamedSharding(mesh, mspec)),
        )
    return fn, ds, ss


def make_distributed_step(model: ModelDef, mesh: Mesh, data: MFData,
                          state: MFState, pipeline: Optional[str] = None):
    """The distributed sweep jitted on ``mesh``.

    Returns (step_fn, placed_data, placed_state) — on real hardware the
    placement transfers; in the dry-run we only ``.lower().compile()``.
    Uses the explicit shard_map sweep when the model is in the sharded
    subset (see ``distributed_supported``); otherwise jits the
    single-device ``gibbs_step`` with the same in/out shardings and
    lets the partitioner place the collectives.

    ``pipeline`` selects the fixed-factor exchange: ``"eager"`` (one
    blocking all-gather per half-sweep) or ``"ring"`` (``S - 1``
    double-buffered ppermute hops overlapping the local solves); None
    defers to the ``REPRO_PIPELINE`` environment variable (see
    ``resolve_pipeline``).  The knob only changes HOW the exchange
    travels — the sampled chain is pinned to the eager one by the
    ring-vs-eager parity and golden-chain tests.

    ``step_fn(data, state)`` closes over the precomputed Macau
    side-Gramians (replicated) and exposes ``.lower(data, state)``
    exactly like a bare ``jax.jit`` result.
    """
    pipeline = resolve_pipeline(pipeline)
    ss = state_shardings(model, mesh, state)
    ds = data_shardings(model, mesh, data)
    if distributed_supported(model, mesh, data):
        axes = _axes_in(mesh)
        sizes = compat.mesh_axis_sizes(mesh, axes)
        ftf = _macau_ftf(model, data)
        ftf_specs = jax.tree.map(lambda x: P(), ftf)
        body = compat.shard_map(
            partial(_sharded_sweep, model, axes, sizes, pipeline),
            mesh=mesh,
            in_specs=(ftf_specs,
                      data_specs(model, mesh, data),
                      state_specs(model, mesh, state)),
            out_specs=(state_specs(model, mesh, state), P()),
            check=False)
        jfn = jax.jit(body,
                      in_shardings=(_with_mesh(mesh, ftf_specs), ds, ss),
                      out_shardings=(ss, replicated(mesh)))

        def fn(data, state):
            return jfn(ftf, data, state)

        fn.lower = lambda data, state: jfn.lower(ftf, data, state)
    else:
        fn = jax.jit(
            partial(gibbs_step, model),
            in_shardings=(ds, ss),
            out_shardings=(ss, replicated(mesh)),
        )
    return fn, ds, ss


def pad_rows_to(n: int, devices: int) -> int:
    """Round a row count up so every shard is equal (elastic re-bucket)."""
    return int(-(-n // devices) * devices)

"""Prior distributions over the factor matrices (paper Table 1, col 2).

Choices, exactly as in SMURFF:

* ``NormalPrior``       — multivariate Normal with a Normal-Wishart
                          hyperprior (BPMF, Salakhutdinov & Mnih 2008).
* ``MacauPrior``        — NormalPrior + side information F through a
                          link matrix beta (Simm et al. 2017).
* ``SpikeAndSlabPrior`` — per-(row, component) spike-and-slab for
                          group-sparse factors (GFA, Virtanen 2012).

Each prior exposes:

* ``init(key, n_rows)``                  -> hyper-state pytree
* ``sample_hyper(key, F, hyper, ...)``   -> new hyper-state given the
                                            current factor matrix
* ``precision_term(hyper)``              -> Lambda_p (K, K)
* ``mean_term(hyper, n_rows)``           -> b_p (n_rows, K) or (K,)
                                            (Lambda_p @ prior_mean rows)

All sampling is counter-based ``jax.random`` — reproducible regardless
of how the row axis is sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.lax.linalg import cholesky, triangular_solve


# ---------------------------------------------------------------------------
# shared linear-algebra helpers
# ---------------------------------------------------------------------------

def chol_solve(L: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve (L L^T) x = b for batched lower-triangular L.

    L (..., K, K), b (..., K)  ->  x (..., K)
    """
    b = b[..., None]
    y = triangular_solve(L, b, left_side=True, lower=True)
    x = triangular_solve(L, y, left_side=True, lower=True, transpose_a=True)
    return x[..., 0]


def sample_mvn_from_precision(key, L_prec: jnp.ndarray,
                              mean: jnp.ndarray) -> jnp.ndarray:
    """x ~ N(mean, Lambda^{-1}) given L_prec = chol(Lambda), batched."""
    z = jax.random.normal(key, mean.shape, dtype=mean.dtype)
    dz = triangular_solve(L_prec, z[..., None], left_side=True, lower=True,
                          transpose_a=True)[..., 0]
    return mean + dz


def sample_wishart(key, L_scale: jnp.ndarray, df: float) -> jnp.ndarray:
    """Draw Lambda ~ Wishart(scale, df) via the Bartlett decomposition.

    L_scale = chol(scale matrix), K x K.  Returns a K x K precision
    sample Lambda = (L A)(L A)^T where A is the Bartlett factor.
    """
    K = L_scale.shape[-1]
    kn, kg = jax.random.split(key)
    # chi2(df - i) = 2 * gamma((df - i) / 2)
    i = jnp.arange(K, dtype=jnp.float32)
    c = jnp.sqrt(2.0 * jax.random.gamma(kg, (df - i) / 2.0,
                                        dtype=jnp.float32))
    n = jax.random.normal(kn, (K, K), dtype=jnp.float32)
    A = jnp.tril(n, -1) + jnp.diag(c)
    LA = L_scale @ A
    return LA @ LA.T


# ---------------------------------------------------------------------------
# Normal prior with Normal-Wishart hyperprior (BPMF)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NormalPrior:
    """mu, Lambda ~ Normal-Wishart(mu0, b0, W0 = I, df = K)."""

    num_latent: int
    b0: float = 2.0
    mu0: float = 0.0

    def init(self, key, n_rows: int):
        K = self.num_latent
        return {"mu": jnp.zeros((K,), jnp.float32),
                "Lambda": jnp.eye(K, dtype=jnp.float32)}

    def sample_hyper(self, key, F: jnp.ndarray, hyper,
                     F_sum: Optional[jnp.ndarray] = None,
                     F_cov: Optional[jnp.ndarray] = None,
                     n_rows: Optional[jnp.ndarray] = None):
        """Conditional NW update given the factor matrix F (N, K).

        ``F_sum``/``F_cov``/``n_rows`` override the locally computed
        moments — the distributed path psums them across shards first.
        """
        s = F.sum(axis=0) if F_sum is None else F_sum
        C = F.T @ F if F_cov is None else F_cov
        N = F.shape[0] if n_rows is None else n_rows
        return self.sample_hyper_moments(key, hyper, F_sum=s, F_cov=C,
                                         n_rows=N)

    def sample_hyper_moments(self, key, hyper, *, F_sum: jnp.ndarray,
                             F_cov: jnp.ndarray, n_rows):
        """NW update from sufficient statistics only.

        ``F_sum`` (K,) and ``F_cov`` = F^T F (K, K) are the moments of
        the factor matrix; the distributed sweep computes them as a
        K/K^2-sized ``psum`` over row shards, so the hyper-sample is an
        identical replicated computation on every device.
        """
        K = self.num_latent
        N = jnp.asarray(n_rows, jnp.float32)
        fbar = F_sum / N
        # scatter matrix sum_i (f_i - fbar)(f_i - fbar)^T
        SS = F_cov - N * jnp.outer(fbar, fbar)

        mu0 = jnp.full((K,), self.mu0, jnp.float32)
        b_star = self.b0 + N
        df_star = K + N
        mu_star = (self.b0 * mu0 + N * fbar) / b_star
        dv = fbar - mu0
        Winv = (jnp.eye(K, dtype=jnp.float32) + SS
                + (self.b0 * N / b_star) * jnp.outer(dv, dv))
        # scale = Winv^{-1}: invert through the Cholesky of Winv
        Lw = cholesky(Winv)
        eye = jnp.eye(K, dtype=jnp.float32)
        y = triangular_solve(Lw, eye, left_side=True, lower=True)
        W = triangular_solve(Lw, y, left_side=True, lower=True,
                             transpose_a=True)
        Ls = cholesky((W + W.T) / 2.0)

        k1, k2 = jax.random.split(key)
        Lam = sample_wishart(k1, Ls, df_star)
        Llam = cholesky(Lam * b_star)
        mu = sample_mvn_from_precision(k2, Llam, mu_star)
        return {"mu": mu, "Lambda": Lam}

    def precision_term(self, hyper) -> jnp.ndarray:
        return hyper["Lambda"]

    def mean_term(self, hyper, n_rows: int) -> jnp.ndarray:
        """Lambda_p @ prior-mean, shared by all rows -> (K,)."""
        return hyper["Lambda"] @ hyper["mu"]


@dataclasses.dataclass(frozen=True)
class FixedNormalPrior:
    """Fixed z_i ~ N(0, I) — no hyper-sampling.

    This is GFA's prior on the shared sample factor Z (Virtanen 2012):
    pinning Z's scale/rotation is what lets the spike-and-slab prior on
    the loading matrices actually kill unused components.  (A
    Normal-Wishart prior on Z would re-absorb any rescaling and keep
    every component alive.)
    """

    num_latent: int

    def init(self, key, n_rows: int):
        return {}

    def sample_hyper(self, key, F, hyper, **_):
        return hyper

    def precision_term(self, hyper) -> jnp.ndarray:
        return jnp.eye(self.num_latent, dtype=jnp.float32)

    def mean_term(self, hyper, n_rows: int) -> jnp.ndarray:
        return jnp.zeros((self.num_latent,), jnp.float32)


# ---------------------------------------------------------------------------
# Macau prior: Normal + side information through a link matrix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MacauPrior:
    """NormalPrior whose per-row mean is shifted by beta^T f_i.

    u_i ~ N(mu + beta^T f_i, Lambda^{-1}),
    beta ~ MatrixNormal(0, (beta_precision)^{-1} I_D, Lambda^{-1}).

    ``side`` F is (N, D) and is considered static data (closed over at
    jit time via the model definition).
    """

    num_latent: int
    num_features: int
    b0: float = 2.0
    mu0: float = 0.0
    beta_precision: float = 5.0
    sample_beta_precision: bool = True

    @property
    def _normal(self) -> NormalPrior:
        return NormalPrior(self.num_latent, self.b0, self.mu0)

    def init(self, key, n_rows: int):
        K, D = self.num_latent, self.num_features
        h = self._normal.init(key, n_rows)
        h["beta"] = jnp.zeros((D, K), jnp.float32)
        h["beta_prec"] = jnp.asarray(self.beta_precision, jnp.float32)
        return h

    def sample_hyper(self, key, F, hyper, side=None, FtF=None, **mom):
        """NW update on (U - F beta), then the beta conditional.

        side (N, D): feature matrix.  FtF (D, D): precomputed side^T side
        (static, may be psummed by the distributed caller).
        """
        assert side is not None
        U_centered = F - side @ hyper["beta"]
        stats = dict(
            F_sum=mom.get("F_sum", U_centered.sum(axis=0)),
            F_cov=mom.get("F_cov", U_centered.T @ U_centered),
            n_rows=mom.get("n_rows", F.shape[0]),
            StF=mom.get("StF", side.T @ F),
            s_side=mom.get("s_side", side.sum(axis=0)),
            FtF=side.T @ side if FtF is None else FtF,
        )
        return self.sample_hyper_moments(key, hyper, **stats)

    def sample_hyper_moments(self, key, hyper, *, F_sum, F_cov, n_rows,
                             StF, s_side, FtF):
        """Macau hyper-sample from sufficient statistics only.

        ``F_sum``/``F_cov`` are the moments of the *centered* factor
        U - side @ beta; ``StF`` = side^T U (D, K), ``s_side`` =
        column sums of side (D,), ``FtF`` = side^T side (D, D).  The
        distributed sweep psums each of these over row shards; the rest
        of the update is replicated K/D-sized linear algebra.
        """
        k_nw, k_b, k_prec = jax.random.split(key, 3)
        h = self._normal.sample_hyper_moments(k_nw, hyper, F_sum=F_sum,
                                              F_cov=F_cov, n_rows=n_rows)

        # beta | U, Lambda  ~ MN(mean, A^{-1}, Lambda^{-1}),
        # A = side^T side + beta_prec * I
        D, K = self.num_features, self.num_latent
        A = FtF + hyper["beta_prec"] * jnp.eye(D, dtype=jnp.float32)
        La = cholesky(A)
        # side^T (U - mu 1^T) decomposed so shards only contribute sums
        FtU = StF - jnp.outer(s_side, h["mu"])  # (D, K)
        y = triangular_solve(La, FtU, left_side=True, lower=True)
        mean_b = triangular_solve(La, y, left_side=True, lower=True,
                                  transpose_a=True)
        # sample: mean + La^{-T} Z Llam^{-1}
        Z = jax.random.normal(k_b, (D, K), dtype=jnp.float32)
        Zr = triangular_solve(La, Z, left_side=True, lower=True,
                              transpose_a=True)
        Llam = cholesky(h["Lambda"])
        beta = mean_b + _mn_col_mix(Zr, Llam)

        # lambda_beta ~ Gamma conditional (Macau eq. for the link precision)
        if self.sample_beta_precision:
            # beta has D*K entries; weighted by Lambda across components:
            bl = beta @ h["Lambda"] @ beta.T
            sse = jnp.trace(bl)
            a_post = 0.5 * (D * K) + 1.0
            b_post = 0.5 * sse + 1.0
            prec = jax.random.gamma(k_prec, a_post) / b_post
            h["beta_prec"] = prec.astype(jnp.float32)
        else:
            h["beta_prec"] = hyper["beta_prec"]
        h["beta"] = beta
        return h

    def precision_term(self, hyper) -> jnp.ndarray:
        return hyper["Lambda"]

    def mean_term(self, hyper, n_rows: int, side=None) -> jnp.ndarray:
        """(N, K): Lambda @ (mu + beta^T f_i) per row."""
        assert side is not None
        m = hyper["mu"][None, :] + side @ hyper["beta"]
        return m @ hyper["Lambda"].T

    def predict_factor(self, hyper, F_new) -> jnp.ndarray:
        """Latent rows for UNSEEN entities through the sampled link.

        The Macau conditional mean of a row with feature vector f is
        ``mu + beta^T f``; ``beta``/``mu`` here are the posterior
        SAMPLES carried in ``hyper`` (``beta`` is resampled every
        sweep by ``sample_hyper_moments`` and saved with the chain
        state).  ``PredictSession`` averages this per retained sample
        for out-of-matrix prediction — whole rows never present in the
        training matrix, the compound-activity cold-start workflow
        (Simm et al. 2017; arXiv:1904.02514).

        F_new (M, D) -> (M, K).
        """
        F_new = jnp.asarray(F_new, jnp.float32)
        return hyper["mu"][None, :] + F_new @ hyper["beta"]


def _mn_col_mix(Zr: jnp.ndarray, Llam: jnp.ndarray) -> jnp.ndarray:
    """Right-multiply row-mixed noise by Llam^{-T}: Zr @ Llam^{-1}...

    For MN(0, A^{-1}, Lambda^{-1}) we need Zr @ L_c^T with
    L_c = chol(Lambda^{-1}) = Llam^{-T}; i.e. Zr @ Llam^{-1}.
    Solve X Llam = Zr  =>  Llam^T X^T = Zr^T.
    """
    Xt = triangular_solve(Llam, Zr.T, left_side=True, lower=True,
                          transpose_a=True)
    return Xt.T


# ---------------------------------------------------------------------------
# Spike-and-slab prior (GFA-style group sparsity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpikeAndSlabPrior:
    """v_ik ~ (1 - rho_k) delta_0 + rho_k N(0, 1 / tau_k).

    Per-component inclusion probability rho_k ~ Beta(a, b) and slab
    precision tau_k ~ Gamma(c, d) are resampled each sweep.  The factor
    update itself is the coordinate-wise conditional (handled in
    ``gibbs.py::_sample_sns_factor`` because it needs the residuals);
    this class owns the hyper-state.
    """

    num_latent: int
    rho_a: float = 1.0
    rho_b: float = 1.0
    tau_c: float = 1.0
    tau_d: float = 1.0

    def init(self, key, n_rows: int):
        K = self.num_latent
        return {"rho": jnp.full((K,), 0.5, jnp.float32),
                "tau": jnp.ones((K,), jnp.float32)}

    def sample_hyper(self, key, F, hyper, n_incl=None, sumsq=None,
                     n_rows=None, **_):
        """F is the factor matrix (N, K); zeros mark excluded entries.

        ``n_incl``/``sumsq``/``n_rows`` override the locally computed
        per-component moments — the distributed sweep psums them over
        row shards first (two K-sized collectives).
        """
        s = (jnp.abs(F) > 0).astype(jnp.float32)     # inclusion indicators
        return self.sample_hyper_moments(
            key, hyper,
            n_incl=s.sum(axis=0) if n_incl is None else n_incl,
            sumsq=(F * F).sum(axis=0) if sumsq is None else sumsq,
            n_rows=F.shape[0] if n_rows is None else n_rows)

    def sample_hyper_moments(self, key, hyper, *, n_incl, sumsq, n_rows):
        """SnS hyper-sample from sufficient statistics only.

        ``n_incl`` (K,) counts the included (nonzero) entries per
        component and ``sumsq`` (K,) their sum of squares; the
        distributed sweep psums both over row shards — the ONLY
        collectives the spike-and-slab composition adds to a sweep —
        so the hyper-sample is an identical replicated computation on
        every device, mirroring ``NormalPrior.sample_hyper_moments``.
        """
        N = jnp.asarray(n_rows, jnp.float32)
        kr, kt1, kt2 = jax.random.split(key, 3)
        # rho_k ~ Beta(a + n_incl, b + N - n_incl)
        g1 = jax.random.gamma(kr, self.rho_a + n_incl)
        g2 = jax.random.gamma(kt1, self.rho_b + N - n_incl)
        rho = g1 / (g1 + g2)
        # tau_k ~ Gamma(c + n_incl/2, d + sum v^2 / 2)
        tau = (jax.random.gamma(kt2, self.tau_c + 0.5 * n_incl)
               / (self.tau_d + 0.5 * sumsq))
        return {"rho": jnp.clip(rho, 1e-4, 1.0 - 1e-4), "tau": tau}

    def precision_term(self, hyper) -> jnp.ndarray:
        return jnp.diag(hyper["tau"])

    def mean_term(self, hyper, n_rows: int) -> jnp.ndarray:
        return jnp.zeros((self.num_latent,), jnp.float32)

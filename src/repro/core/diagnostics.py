"""Convergence diagnostics for multi-chain MCMC — split-R-hat and bulk-ESS.

Pure numpy, host-side, deterministic (no RNG, no wall clock — lint rule
``nondeterminism-in-core`` applies to this module).  The estimators
follow Vehtari, Gelman, Simpson, Carpenter, Bürkner (2021), "Rank-
normalization, folding, and localization: an improved R-hat for
assessing convergence of MCMC":

* ``split_rhat`` — each chain is split in half (2C half-chains of
  length N//2, the middle draw dropped when N is odd), then the classic
  Gelman-Rubin potential scale reduction factor sqrt(var_hat / W) is
  computed over the half-chains.  Splitting makes a single non-
  stationary chain flag itself.
* ``bulk_ess`` — effective sample size of the rank-normalized split
  chains, with per-chain autocovariances combined as in Stan and the
  autocorrelation sum truncated by Geyer's initial monotone positive
  sequence.

Both take draws shaped ``(C, N)`` (chains x draws) and return a float;
with fewer than 4 draws per chain (or a constant trace) they return
``nan`` rather than a misleading number.

The session layer records one trace per monitored quantity — per-block
``rmse_train_<b>`` / ``alpha_<b>`` and per-entity factor RMS norms over
the post-burnin sweeps — and stores the resulting :class:`Diagnostics`
next to the sample store as ``diagnostics.json``, where
``PredictSession(require_converged=True)`` gates on it before serving.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

DIAGNOSTICS_FILE = "diagnostics.json"
_FORMAT = "repro-mf-diagnostics-v1"

# Default convergence threshold for split-R-hat.  Vehtari et al. (2021)
# recommend 1.01 for publication-grade inference; 1.05 is the common
# serving-gate compromise (classic Gelman-Rubin used 1.1).
DEFAULT_RHAT_THRESHOLD = 1.05

MIN_DRAWS = 4


def _as_chain_matrix(x) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[None, :]
    if x.ndim != 2:
        raise ValueError(
            f"expected draws shaped (chains, draws), got shape {x.shape}")
    return x


def split_chains(x) -> np.ndarray:
    """(C, N) draws -> (2C, N//2) half-chains (odd middle draw dropped)."""
    x = _as_chain_matrix(x)
    half = x.shape[1] // 2
    return np.concatenate([x[:, :half], x[:, x.shape[1] - half:]], axis=0)


def split_rhat(x) -> float:
    """Split potential scale reduction factor over ``(C, N)`` draws."""
    x = _as_chain_matrix(x)
    if x.shape[1] < MIN_DRAWS or not np.all(np.isfinite(x)):
        return float("nan")
    z = split_chains(x)
    m, n = z.shape
    means = z.mean(axis=1)
    variances = z.var(axis=1, ddof=1)
    w = variances.mean()
    b = n * means.var(ddof=1)
    if w <= 0.0:
        # all half-chains constant: identical means -> converged by
        # definition; differing constants -> no within-variance to
        # shrink to, report nan (undefined, flagged by the gate)
        return 1.0 if b <= 0.0 else float("nan")
    var_hat = (n - 1) / n * w + b / n
    return float(math.sqrt(var_hat / w))


def _ndtri(p: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |rel err| < 1.15e-9) — numpy has no ndtri and scipy is not a
    dependency of this package."""
    p = np.asarray(p, dtype=np.float64)
    a = [-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    out = np.empty_like(p)

    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)

    q = np.sqrt(-2 * np.log(np.where(lo, p, 0.5)))
    out_lo = ((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
               * q + c[5])
              / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    q = np.sqrt(-2 * np.log(np.where(hi, 1 - p, 0.5)))
    out_hi = -((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                * q + c[5])
               / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    q = np.where(mid, p, 0.5) - 0.5
    r = q * q
    out_mid = ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
                * r + a[5]) * q
               / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
                  * r + 1))
    out[lo] = out_lo[lo]
    out[hi] = out_hi[hi]
    out[mid] = out_mid[mid]
    return out


def rank_normalize(x) -> np.ndarray:
    """Rank-normalize draws jointly across chains.

    Average ranks for ties, then the fractional rank ``(r - 3/8) /
    (S + 1/4)`` is pushed through the inverse normal CDF (Blom's
    offset), as in Vehtari et al. (2021) eq. 14.  Shape-preserving.
    """
    x = _as_chain_matrix(x)
    flat = x.ravel()
    order = np.argsort(flat, kind="stable")
    ranks = np.empty(flat.size, dtype=np.float64)
    ranks[order] = np.arange(1, flat.size + 1, dtype=np.float64)
    # average ranks over ties so identical draws get identical z-scores
    uniq, inv, counts = np.unique(flat, return_inverse=True,
                                  return_counts=True)
    if uniq.size != flat.size:
        sums = np.zeros(uniq.size)
        np.add.at(sums, inv, ranks)
        ranks = (sums / counts)[inv]
    z = _ndtri((ranks - 0.375) / (flat.size + 0.25))
    return z.reshape(x.shape)


def _combined_autocorr(z: np.ndarray) -> np.ndarray:
    """Multi-chain autocorrelation estimate rho_t (Stan's combination):

        rho_t = 1 - (W - mean_c s_t^c) / var_hat

    with ``s_t^c`` the per-chain biased autocovariance at lag t and
    ``var_hat`` the split-R-hat total-variance estimate.
    """
    m, n = z.shape
    means = z.mean(axis=1, keepdims=True)
    centered = z - means
    # per-chain biased autocovariances, s_t^c = (1/n) sum x_i x_{i+t}
    acov = np.empty((m, n))
    for c in range(m):
        full = np.correlate(centered[c], centered[c], mode="full")
        acov[c] = full[n - 1:] / n
    chain_var = acov[:, 0] * n / (n - 1.0)
    w = chain_var.mean()
    b_over_n = z.mean(axis=1).var(ddof=1) if m > 1 else 0.0
    var_hat = (n - 1.0) / n * w + b_over_n
    if var_hat <= 0.0:
        return np.full(n, np.nan)
    return 1.0 - (w - acov.mean(axis=0)) / var_hat


def ess(x) -> float:
    """Effective sample size of ``(C, N)`` draws (no rank-normalization;
    use :func:`bulk_ess` for the gate metric).

    Geyer's initial positive sequence: pair sums ``P_t = rho_{2t} +
    rho_{2t+1}`` are accumulated while positive, then made monotone
    non-increasing; ``tau = 1 + 2 sum rho`` and ``ess = C*N / tau``.
    """
    x = _as_chain_matrix(x)
    m, n = x.shape
    if n < MIN_DRAWS or not np.all(np.isfinite(x)):
        return float("nan")
    if np.allclose(x, x.flat[0]):
        return float("nan")
    rho = _combined_autocorr(x)
    if not np.all(np.isfinite(rho[:2])):
        return float("nan")
    # Geyer pairs (rho_0 + rho_1), (rho_2 + rho_3), ...: keep while
    # positive, clip monotone non-increasing
    pair_sums = []
    prev = np.inf
    t = 0
    while 2 * t + 1 < n:
        p = rho[2 * t] + rho[2 * t + 1]
        if not np.isfinite(p) or p < 0.0:
            break
        p = min(p, prev)
        pair_sums.append(p)
        prev = p
        t += 1
    tau = -rho[0] + 2.0 * float(np.sum(pair_sums)) if pair_sums else 1.0
    tau = max(tau, 1.0 / math.log10(max(m * n, 10)))
    return float(m * n / tau)


def bulk_ess(x) -> float:
    """Bulk-ESS: ESS of the rank-normalized split chains."""
    x = _as_chain_matrix(x)
    if x.shape[1] < MIN_DRAWS or not np.all(np.isfinite(x)):
        return float("nan")
    if np.allclose(x, x.flat[0]):
        return float("nan")
    return ess(rank_normalize(split_chains(x)))


@dataclass
class Diagnostics:
    """Per-quantity convergence summary for one multi-chain run."""

    n_chains: int
    n_draws: int
    rhat: Dict[str, float] = field(default_factory=dict)
    ess: Dict[str, float] = field(default_factory=dict)

    @property
    def max_rhat(self) -> float:
        finite = [v for v in self.rhat.values() if math.isfinite(v)]
        return max(finite) if finite else float("nan")

    def failing(self, threshold: float = DEFAULT_RHAT_THRESHOLD
                ) -> Dict[str, float]:
        """Quantities whose R-hat exceeds ``threshold`` or is nan/absent
        of evidence (non-finite with >= MIN_DRAWS draws is a failure —
        an undefined diagnostic must not pass a convergence gate)."""
        out = {}
        for name, v in self.rhat.items():
            if not math.isfinite(v) or v > threshold:
                out[name] = v
        return out

    def converged(self, threshold: float = DEFAULT_RHAT_THRESHOLD) -> bool:
        return bool(self.rhat) and not self.failing(threshold)

    def to_dict(self) -> dict:
        return {
            "format": _FORMAT,
            "n_chains": int(self.n_chains),
            "n_draws": int(self.n_draws),
            "rhat": {k: float(v) for k, v in self.rhat.items()},
            "ess": {k: float(v) for k, v in self.ess.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Diagnostics":
        return cls(n_chains=int(d["n_chains"]), n_draws=int(d["n_draws"]),
                   rhat={k: float(v) for k, v in d.get("rhat", {}).items()},
                   ess={k: float(v) for k, v in d.get("ess", {}).items()})


def compute_diagnostics(traces: Dict[str, np.ndarray]) -> Diagnostics:
    """Split-R-hat + bulk-ESS for every monitored trace.

    ``traces`` maps quantity name -> draws shaped ``(C, N)`` (a 1-D
    trace is treated as one chain).  All traces must share C and N.
    """
    n_chains = n_draws = 0
    rhat, ess_ = {}, {}
    for name, tr in traces.items():
        tr = _as_chain_matrix(tr)
        n_chains, n_draws = tr.shape
        rhat[name] = split_rhat(tr)
        ess_[name] = bulk_ess(tr)
    return Diagnostics(n_chains=n_chains, n_draws=n_draws,
                       rhat=rhat, ess=ess_)


def save_diagnostics(save_dir: str, diag: Diagnostics) -> str:
    path = os.path.join(save_dir, DIAGNOSTICS_FILE)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(diag.to_dict(), f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def load_diagnostics(save_dir: str) -> Optional[Diagnostics]:
    path = os.path.join(save_dir, DIAGNOSTICS_FILE)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        d = json.load(f)
    return Diagnostics.from_dict(d)

"""Posterior-predictive evaluation: RMSE / AUC over collected samples.

SMURFF's predict step (Algorithm 1 "for all test points") evaluated per
sweep; predictions for the final report average U_s V_s^T over the
collected posterior samples, which is what makes BMF robust against
overfitting (paper section 1).

:class:`PredictSession` is the from-disk counterpart: it reloads the
posterior samples a session streamed out (``save_freq``/``save_dir``)
and serves averaged predictions without the training data — at
arbitrary cells of any block, as whole dense blocks, and for rows
never present in training through the sampled Macau link matrices
(out-of-matrix prediction, the compound-activity cold-start workflow
of arXiv:1904.02514).

Serving many requests is where the original lazy design fell over:
every ``predict``/``predict_all``/``predict_new`` call re-read the
ENTIRE sample store from disk, so R requests cost R x S checkpoint
loads.  The structural fix is the **resident posterior cache**
(:class:`PosteriorCache`): the first request loads the factor stack
once into ``(S, N, K)`` device arrays (plus the stacked Macau hyper
draws for cold-start rows), bounded by a byte budget
(``cache_bytes``, env ``REPRO_PREDICT_CACHE_BYTES``); every later
request performs ZERO checkpoint loads (asserted via the
``load_count`` counter in tests/test_serving.py).  Stores above the
budget keep the lazy streaming path.  ``recommend``/``recommend_rows``
serve batched top-K item recommendations with posterior mean AND
uncertainty through the fused ``kernels.topk_score`` scorer — the
online serving layer ``launch.serve.RecommendServer`` batches
concurrent requests onto.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import (Any, Dict, Iterator, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops


class TestSet(NamedTuple):
    i: jnp.ndarray   # (E,) int32 row ids
    j: jnp.ndarray   # (E,) int32 col ids
    v: jnp.ndarray   # (E,) f32 true values


def make_test_set(i, j, v) -> TestSet:
    return TestSet(jnp.asarray(i, jnp.int32), jnp.asarray(j, jnp.int32),
                   jnp.asarray(v, jnp.float32))


@jax.jit
def predict_one(U: jnp.ndarray, V: jnp.ndarray, test: TestSet
                ) -> jnp.ndarray:
    """Single-sample prediction at the test entries."""
    return ops.sddmm(U[test.i], V[test.j])


def rmse(pred: jnp.ndarray, truth: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean((pred - truth) ** 2))


def auc(pred: np.ndarray, truth: np.ndarray, threshold: float = 0.5
        ) -> float:
    """Rank-based AUC (Mann-Whitney); truth binarized at threshold.

    Tied predictions get MIDRANKS (the average of the ranks they
    span), the standard tie-corrected Mann-Whitney statistic: each
    tied positive/negative pair then contributes 1/2, matching the
    trapezoidal ROC area.  Raw ``argsort`` ranks instead assign tied
    groups an arbitrary input-order permutation, biasing the AUC on
    discrete/probit outputs where ties are the common case.
    """
    pred = np.asarray(pred)
    pos = np.asarray(truth) > threshold
    n_pos = int(pos.sum())
    n_neg = pos.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    _, inv, counts = np.unique(pred, return_inverse=True,
                               return_counts=True)
    # group g spans ranks (end - count, end]; its midrank is their mean
    end = np.cumsum(counts)
    ranks = (end - (counts - 1) / 2.0)[inv]
    s = ranks[pos].sum()
    return float((s - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


class PredictAccumulator:
    """Streaming average of per-sample predictions (posterior mean)."""

    def __init__(self, test: TestSet):
        self.test = test
        self._sum = jnp.zeros_like(test.v)
        self._sum2 = jnp.zeros_like(test.v)
        self.n = 0

    def update(self, U: jnp.ndarray, V: jnp.ndarray):
        p = predict_one(U, V, self.test)
        self._sum = self._sum + p
        self._sum2 = self._sum2 + p * p
        self.n += 1
        return p

    @property
    def mean(self) -> jnp.ndarray:
        return self._sum / max(self.n, 1)

    @property
    def var(self) -> jnp.ndarray:
        """Population variance OVER THE POSTERIOR SAMPLES of the
        per-sample predictions: ``E[p^2] - E[p]^2`` with both moments
        averaged over the ``n`` accumulated samples (pinned against a
        hand-rolled oracle in tests/test_predict.py).  This is the
        posterior-predictive spread of ``u_s . v_s`` — the Bayesian
        uncertainty of the score — NOT an error bar on the mean
        estimator (which would shrink with 1/n)."""
        m = self.mean
        return jnp.maximum(self._sum2 / max(self.n, 1) - m * m, 0.0)

    @property
    def std(self) -> jnp.ndarray:
        """Posterior standard deviation per prediction: sqrt(var).
        The uncertainty field the serving layer reports next to every
        recommended score."""
        return jnp.sqrt(self.var)

    def rmse(self) -> float:
        return float(rmse(self.mean, self.test.v))

    def auc(self, threshold: float = 0.5) -> float:
        return auc(np.asarray(self.mean), np.asarray(self.test.v),
                   threshold)


# ---------------------------------------------------------------------------
# from-disk prediction over saved posterior samples
# ---------------------------------------------------------------------------

# model.json specs keyed by realpath -> (mtime, spec): every
# PredictSession pointed at the same store shares one parsed spec
# instead of re-reading the JSON per instance (a store is written once
# by the training session; mtime invalidates the entry if it IS
# rewritten, e.g. by a resumed chain).  Bounded LRU: a long-lived
# server cycling through many stores (mtime-keyed entries used to
# accumulate FOREVER) now evicts least-recently-used specs past
# _SPEC_CACHE_MAX.
_SPEC_CACHE: "OrderedDict[str, Tuple[float, dict]]" = OrderedDict()
_SPEC_CACHE_MAX = 64
_SPEC_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}

DEFAULT_CACHE_BYTES = 1 << 30    # 1 GiB of stacked posterior samples


def spec_cache_stats() -> dict:
    """Counters + occupancy of the module-level model.json spec cache
    (part of ``PredictSession.cache_stats()``)."""
    out = dict(_SPEC_CACHE_STATS)
    out["size"] = len(_SPEC_CACHE)
    out["max_size"] = _SPEC_CACHE_MAX
    return out


def _load_spec_cached(path: str) -> dict:
    from .modelspec import load_model_spec
    try:
        key = os.path.realpath(path)
        mtime = os.path.getmtime(path)
    except OSError:
        # missing file: fall through for the helpful error message
        return load_model_spec(path)
    hit = _SPEC_CACHE.get(key)
    if hit is not None and hit[0] == mtime:
        _SPEC_CACHE_STATS["hits"] += 1
        _SPEC_CACHE.move_to_end(key)
        return hit[1]
    _SPEC_CACHE_STATS["misses"] += 1
    spec = load_model_spec(path)
    _SPEC_CACHE[key] = (mtime, spec)
    _SPEC_CACHE.move_to_end(key)
    while len(_SPEC_CACHE) > _SPEC_CACHE_MAX:
        _SPEC_CACHE.popitem(last=False)
        _SPEC_CACHE_STATS["evictions"] += 1
    return spec


def _resolve_cache_bytes(cache_bytes: Optional[int]) -> int:
    if cache_bytes is not None:
        return int(cache_bytes)
    env = os.environ.get("REPRO_PREDICT_CACHE_BYTES")
    return int(env) if env else DEFAULT_CACHE_BYTES


class PosteriorCache(NamedTuple):
    """The whole sample store, resident: one device array per leaf.

    ``factors[e]`` stacks entity ``e``'s sampled factor over the
    retained chain — shape ``(S, N_e, K)``, the operand layout the
    fused ``kernels.topk_score`` scorer consumes directly.
    ``hypers[e]`` stacks the prior hyper pytree the same way (leading
    ``S`` axis per leaf), which is what out-of-matrix prediction needs
    (the sampled Macau ``mu_s``/``beta_s`` per retained draw).
    """

    factors: Tuple[jnp.ndarray, ...]
    hypers: Tuple[Any, ...]
    n_samples: int

    def hyper_at(self, entity: int, s: int):
        """Entity ``entity``'s hyper pytree of retained sample ``s``."""
        return jax.tree.map(lambda x: x[s], self.hypers[entity])

    def nbytes(self) -> int:
        """Actual resident bytes of the stacked cache (all leaves)."""
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves((self.factors, self.hypers)))


class RecResult(NamedTuple):
    """Batched top-K recommendations with posterior uncertainty.

    ``ids[b, r]`` is the r-th ranked item for query ``b`` (-1 past the
    number of rankable items), ``mean``/``std`` the posterior mean and
    standard deviation of its score over the retained samples (NaN on
    -1 slots).
    """

    ids: np.ndarray     # (B, k) int32
    mean: np.ndarray    # (B, k) float32
    std: np.ndarray     # (B, k) float32


class PredictSession:
    """Serve averaged predictions from a saved posterior-sample store.

    ``save_dir`` is a directory written by a session with
    ``save_freq > 0``: a ``model.json`` spec (the static entity/block
    graph — see ``core/modelspec.py``) plus ``samples/step_<sweep>``
    checkpoints, each holding one full sampled ``MFState``.  No
    training data is needed: prediction only reads the sampled factors
    and, for out-of-matrix rows, the sampled Macau link matrices in
    the hyper state.

    * ``predict(i, j, block=...)`` — posterior-mean prediction at
      arbitrary cells of a block, the same streaming average the
      in-session accumulator computes (same kernel, same summation
      order — a reload reproduces the in-session ``rmse_test`` to
      float32 tolerance, asserted in tests/test_predict_session.py).
    * ``predict_all(block=...)`` — the whole dense block's posterior
      mean (rows x cols).
    * ``predict_new(entity, F_new, block=...)`` — OUT-of-matrix: rows
      never present in training, mapped into latent space per sample
      through the sampled link (``MacauPrior.predict_factor``:
      ``mu_s + beta_s^T f``) and contracted against that sample's
      other-entity factor.
    * ``recommend(user=..., k=...)`` / ``recommend_rows`` — batched
      top-K item recommendation with posterior mean AND std per score
      through the fused ``kernels.topk_score`` scorer (the serving
      path; ``launch.serve.RecommendServer`` batches onto it).
    * ``restore_latest()`` — (step, MFState) of the newest sample, for
      continuing an interrupted chain (``Session.run(resume=True)``
      uses the same store).

    The first prediction loads the store ONCE into the resident
    :class:`PosteriorCache` (bounded by ``cache_bytes``); every later
    request touches only device memory — ``load_count`` counts
    checkpoint loads and stays flat across repeat requests.  Stores
    bigger than the budget keep the original lazy one-sample-at-a-time
    streaming (the store can be much bigger than memory), trading
    per-request reloads for residency.

    **Multi-chain stores + the convergence gate.**  A session run with
    ``chains=C > 1`` writes one single-chain store per chain under
    ``save_dir/chain_<c>/``; this class detects the layout and POOLS
    the samples of every chain (step-major, chain-minor — the exact
    summation order of the in-session accumulator, so a reload still
    reproduces the in-session ``rmse_test``).  ``num_samples`` counts
    pooled samples; ``load_sample(step, chain=...)`` addresses one.
    The training run also records split-R-hat / bulk-ESS per monitored
    quantity in ``save_dir/diagnostics.json`` (``core.diagnostics``);
    ``require_converged=True`` REFUSES to serve a store whose recorded
    R-hat exceeds ``rhat_threshold`` (or that has no recorded
    diagnostics at all), naming the offending quantities —
    ``require_converged="warn"`` warns instead of raising.  Production
    Bayesian serving should gate: averaging the samples of unmixed
    chains silently serves the wrong posterior.
    """

    def __init__(self, save_dir: str,
                 cache_bytes: Optional[int] = None,
                 require_converged: Union[bool, str] = False,
                 rhat_threshold: Optional[float] = None,
                 recorder: Any = None):
        from ..obs import resolve_recorder
        from ..checkpoint.ckpt import list_steps
        from .diagnostics import load_diagnostics
        from .modelspec import (MODEL_SPEC_FILE, SAMPLES_SUBDIR,
                                chain_count_on_disk, chain_subdir,
                                spec_to_model, state_template)
        self.dir = save_dir
        self.spec = _load_spec_cached(os.path.join(save_dir,
                                                   MODEL_SPEC_FILE))
        self.model = spec_to_model(self.spec)
        self._template = state_template(self.model)
        chains_on_disk = chain_count_on_disk(save_dir)
        self.n_chains = max(1, chains_on_disk)
        if chains_on_disk == 0:
            self._sample_dirs = [os.path.join(save_dir, SAMPLES_SUBDIR)]
        else:
            self._sample_dirs = [
                os.path.join(save_dir, chain_subdir(c), SAMPLES_SUBDIR)
                for c in range(chains_on_disk)]
        self._samples_dir = self._sample_dirs[0]
        per_chain = [list_steps(d) for d in self._sample_dirs]
        # pooled (step, chain) ids, step-major chain-minor — the
        # in-session accumulation order
        self.chain_steps: List[Tuple[int, int]] = sorted(
            (s, c) for c, steps in enumerate(per_chain) for s in steps)
        self.steps: List[int] = sorted({s for s, _ in self.chain_steps})
        if not self.chain_steps:
            raise ValueError(
                f"no complete samples under {self._samples_dir}; run "
                "the session with save_freq > 0 (and let at least one "
                "post-burnin sweep finish)")
        self._step_sets = [frozenset(s) for s in per_chain]
        self._step_set = frozenset(self.steps)   # O(1) membership
        self.cache_bytes = _resolve_cache_bytes(cache_bytes)
        self.load_count = 0          # checkpoint loads, ever
        self._cache: Optional[PosteriorCache] = None
        # obs: request-level hit/miss on the resident cache (a hit =
        # warm_cache found the store already resident; a miss = a load
        # or an over-budget refusal that fell back to streaming)
        self.obs = resolve_recorder(recorder)
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_over_budget = 0
        self.diagnostics = load_diagnostics(save_dir)
        if require_converged:
            self._check_converged(require_converged, rhat_threshold)

    def _check_converged(self, mode: Union[bool, str],
                         rhat_threshold: Optional[float]) -> None:
        from .diagnostics import DEFAULT_RHAT_THRESHOLD
        threshold = (DEFAULT_RHAT_THRESHOLD if rhat_threshold is None
                     else float(rhat_threshold))
        if self.diagnostics is None:
            msg = (
                f"require_converged: store {self.dir!r} records no "
                "diagnostics.json — it predates convergence recording "
                "or the training run died before finishing; rerun the "
                "session (ideally chains>=2) to record split-R-hat/"
                "bulk-ESS, or serve explicitly ungated with "
                "require_converged=False")
        else:
            failing = self.diagnostics.failing(threshold)
            if not failing:
                return
            worst = ", ".join(f"{k}={v:.4g}"
                              for k, v in sorted(failing.items()))
            msg = (
                f"require_converged: store {self.dir!r} has NOT "
                f"converged — split-R-hat over "
                f"{self.diagnostics.n_chains} chain(s) x "
                f"{self.diagnostics.n_draws} draws exceeds "
                f"{threshold:g} for: {worst}. Run more sweeps/chains, "
                "raise rhat_threshold deliberately, or serve "
                "explicitly ungated with require_converged=False")
        if mode == "warn":
            import warnings
            warnings.warn(msg, stacklevel=3)
        else:
            raise ValueError(msg)

    # -- sample access -----------------------------------------------------

    @property
    def num_samples(self) -> int:
        """Pooled sample count — across ALL chains for a multi-chain
        store."""
        return len(self.chain_steps)

    def load_sample(self, step: int, chain: int = 0):
        """The full sampled ``MFState`` saved at global sweep ``step``
        (of ``chain``, for a multi-chain store)."""
        from ..checkpoint.ckpt import load_pytree
        if not 0 <= chain < self.n_chains:
            raise ValueError(
                f"no chain {chain}; this store holds "
                f"{self.n_chains} chain(s)")
        if step not in self._step_sets[chain]:
            saved = ", ".join(map(str, sorted(self._step_sets[chain])))
            raise ValueError(
                f"no sample at step {step}"
                + (f" for chain {chain}" if self.n_chains > 1 else "")
                + f"; saved steps: {saved}")
        self.load_count += 1
        return load_pytree(self._template,
                           os.path.join(self._sample_dirs[chain],
                                        f"step_{step}"))

    def samples(self) -> Iterator:
        """Lazily yield every sampled state — in chain order, and for
        multi-chain stores pooled step-major chain-minor (the
        in-session accumulation order)."""
        for s, c in self.chain_steps:
            yield self.load_sample(s, c)

    def restore_latest(self) -> Tuple[int, object]:
        """(step, MFState) of the newest sample — the resume point.
        For a multi-chain store this is CHAIN 0's newest sample
        (``Session.run(resume=True)`` restores every chain itself)."""
        last = max(self._step_sets[0])
        return last, self.load_sample(last, 0)

    # -- resident posterior cache ------------------------------------------

    def store_nbytes(self) -> int:
        """Resident size of the FULL stacked store, estimated from the
        state template (factor + hyper + noise leaves x num_samples) —
        what the cache would occupy, computed without loading it."""
        per_sample = sum(
            int(np.prod(np.shape(leaf))) * np.dtype(
                getattr(leaf, "dtype", np.float32)).itemsize
            for leaf in jax.tree.leaves(self._template))
        return per_sample * self.num_samples

    @property
    def cache_resident(self) -> bool:
        return self._cache is not None

    def warm_cache(self) -> Optional[PosteriorCache]:
        """Load the store once into the resident cache (idempotent).

        Returns the cache, or None when the store exceeds
        ``cache_bytes`` — callers then stream samples lazily.  This is
        the ONLY place serving paths are allowed to touch the
        checkpoint loader (enforced structurally by the
        ``checkpoint-load-in-serving-request-path`` invariant rule on
        ``launch/serve.py``).
        """
        if self._cache is not None:
            self._cache_hits += 1
            self.obs.add("predict.cache_hit")
            return self._cache
        self._cache_misses += 1
        self.obs.add("predict.cache_miss")
        if self.store_nbytes() > self.cache_bytes:
            # the cache's only "eviction": an all-or-nothing refusal
            # to go resident (there is no partial LRU over samples)
            self._cache_over_budget += 1
            self.obs.add("predict.cache_over_budget")
            return None
        n_ent = len(self.model.entities)
        with self.obs.span("predict/warm_cache", cat="predict",
                           samples=self.num_samples):
            fac: List[List[np.ndarray]] = [[] for _ in range(n_ent)]
            hyp: List[List[Any]] = [[] for _ in range(n_ent)]
            for st in self.samples():
                for e in range(n_ent):
                    fac[e].append(np.asarray(st.factors[e]))
                    hyp[e].append(st.hypers[e])
            factors = tuple(jnp.asarray(np.stack(f)) for f in fac)
            hypers = tuple(
                jax.tree.map(
                    lambda *xs: jnp.asarray(np.stack(
                        [np.asarray(x) for x in xs])), *h)
                for h in hyp)
            self._cache = PosteriorCache(factors, hypers,
                                         self.num_samples)
        self.obs.gauge("predict.cache_resident_bytes",
                       self._cache.nbytes())
        return self._cache

    def cache_stats(self) -> dict:
        """Counters for the resident posterior cache + the module
        spec cache (PR 10 satellite — observability for serving).

        ``hits``/``misses`` count ``warm_cache()`` calls (every
        request path goes through it): a miss is the initial load OR
        an over-budget refusal that fell back to streaming.
        """
        return {
            "hits": self._cache_hits,
            "misses": self._cache_misses,
            "over_budget": self._cache_over_budget,
            "resident": self._cache is not None,
            "resident_bytes": (self._cache.nbytes()
                               if self._cache is not None else 0),
            "budget_bytes": self.cache_bytes,
            "load_count": self.load_count,
            "spec_cache": spec_cache_stats(),
        }

    def _factor_iter(self, entity: int) -> Iterator[jnp.ndarray]:
        """Entity factors per retained sample — from the cache when
        resident (zero loads), streamed from disk otherwise."""
        cache = self.warm_cache()
        if cache is not None:
            for s in range(cache.n_samples):
                yield cache.factors[entity][s]
        else:
            for st in self.samples():
                yield jnp.asarray(st.factors[entity])

    def _factor_pair_iter(self, ent_a: int, ent_b: int):
        cache = self.warm_cache()
        if cache is not None:
            for s in range(cache.n_samples):
                yield (cache.factors[ent_a][s],
                       cache.factors[ent_b][s])
        else:
            for st in self.samples():
                yield (jnp.asarray(st.factors[ent_a]),
                       jnp.asarray(st.factors[ent_b]))

    def _hyper_factor_iter(self, entity: int, other: int):
        """(hyper_s of ``entity``, factor_s of ``other``) per sample."""
        cache = self.warm_cache()
        if cache is not None:
            for s in range(cache.n_samples):
                yield (cache.hyper_at(entity, s),
                       cache.factors[other][s])
        else:
            for st in self.samples():
                yield st.hypers[entity], jnp.asarray(st.factors[other])

    # -- block/entity resolution -------------------------------------------

    def _resolve_block(self, block: Union[int, Tuple[str, str]]
                       ) -> Tuple[int, bool]:
        """(block_index, flipped): ``flipped`` means the caller named
        the pair in the OPPOSITE order to the block's stored
        orientation — their (i, j) address (col, row) cells and their
        result axes are transposed.  An integer block always addresses
        the stored orientation."""
        model = self.model
        if isinstance(block, tuple):
            a = model.entity_index(block[0])
            b = model.entity_index(block[1])
            for bi, blk in enumerate(model.blocks):
                if (blk.row_entity, blk.col_entity) == (a, b):
                    return bi, False
                if (blk.row_entity, blk.col_entity) == (b, a):
                    return bi, True
            names = model.entity_names
            pairs = ", ".join(
                f"({names[blk.row_entity]}, {names[blk.col_entity]})"
                for blk in model.blocks)
            raise ValueError(
                f"no block relates {block!r}; blocks in this model: "
                f"{pairs}")
        bi = int(block)
        if not 0 <= bi < len(model.blocks):
            raise ValueError(
                f"block index {bi} out of range; this model has "
                f"{len(model.blocks)} blocks")
        return bi, False

    # -- prediction --------------------------------------------------------

    def predict(self, i, j, block: Union[int, Tuple[str, str]] = 0,
                return_var: bool = False):
        """Posterior-mean prediction at cells (i[e], j[e]) of a block.

        The identical streaming average the in-session accumulator
        runs — one ``predict_one`` per sample, summed in chain order —
        so a reload reproduces the in-session posterior mean at the
        same cells to float32 tolerance.  A tuple ``block`` addresses
        (i, j) in the order the tuple names the entities, whichever
        orientation the block was declared in.

        Routed through the resident cache: repeat calls perform zero
        checkpoint loads (the accumulator runs over the cached device
        arrays — the same float program, so cached and lazy answers
        are bitwise equal).
        """
        bi, flipped = self._resolve_block(block)
        blk = self.model.blocks[bi]
        if flipped:
            i, j = j, i
        i = np.asarray(i)
        test = make_test_set(i, j, np.zeros(i.shape[0], np.float32))
        acc = PredictAccumulator(test)
        for u, v in self._factor_pair_iter(blk.row_entity,
                                           blk.col_entity):
            acc.update(u, v)
        if return_var:
            return np.asarray(acc.mean), np.asarray(acc.var)
        return np.asarray(acc.mean)

    def predict_all(self, block: Union[int, Tuple[str, str]] = 0
                    ) -> np.ndarray:
        """The whole block's posterior-mean prediction.

        Axes follow the order the caller named the entities in a tuple
        ``block`` (an integer block uses the stored orientation).
        """
        bi, flipped = self._resolve_block(block)
        blk = self.model.blocks[bi]
        s = None
        for u, v in self._factor_pair_iter(blk.row_entity,
                                           blk.col_entity):
            p = u @ v.T
            s = p if s is None else s + p
        out = np.asarray(s / self.num_samples)
        return out.T if flipped else out

    def predict_new(self, entity: Union[int, str], F_new,
                    block: Optional[Union[int, Tuple[str, str]]] = None
                    ) -> np.ndarray:
        """Out-of-matrix prediction for UNSEEN rows of ``entity``.

        ``F_new`` (M, D) holds the new rows' side-information features;
        each retained sample maps them into latent space through ITS
        link matrix draw (``mu_s + beta_s^T f``, exposed as
        ``MacauPrior.predict_factor``) and contracts against ITS
        other-entity factor — averaging after the nonlinearity, the
        correct posterior-predictive mean.  Returns (M, n_other)
        predictions against ``block``'s other entity (``block`` may be
        omitted when only one block touches the entity).
        """
        from .priors import MacauPrior
        model = self.model
        e = model.entity_index(entity)
        ent = model.entities[e]
        if not isinstance(ent.prior, MacauPrior):
            raise ValueError(
                f"entity {ent.name!r} has {type(ent.prior).__name__}; "
                "out-of-matrix prediction needs the Macau "
                "side-information prior (its sampled beta link maps "
                "new feature rows to latents) — add_entity(..., "
                "side_info=F)")
        touching = model.blocks_touching(e)
        if block is None:
            if len(touching) != 1:
                names = model.entity_names
                opts = ", ".join(
                    f"({names[model.blocks[bi].row_entity]}, "
                    f"{names[model.blocks[bi].col_entity]})"
                    for bi, _ in touching)
                raise ValueError(
                    f"entity {ent.name!r} touches {len(touching)} "
                    f"blocks ({opts}); pass block= to pick one")
            bi = touching[0][0]
        else:
            bi, _ = self._resolve_block(block)
            if bi not in [b for b, _ in touching]:
                names = model.entity_names
                opts = ", ".join(
                    f"({names[model.blocks[b].row_entity]}, "
                    f"{names[model.blocks[b].col_entity]})"
                    for b, _ in touching)
                raise ValueError(
                    f"block {block!r} does not touch entity "
                    f"{ent.name!r}; touching blocks: {opts}")
        other = model.blocks[bi].other(e)
        F_new = np.atleast_2d(np.asarray(F_new, np.float32))
        if F_new.shape[1] != ent.prior.num_features:
            raise ValueError(
                f"F_new has {F_new.shape[1]} features; entity "
                f"{ent.name!r} was trained with "
                f"{ent.prior.num_features}")
        s = None
        for hyper, v in self._hyper_factor_iter(e, other):
            u = ent.prior.predict_factor(hyper, F_new)
            p = u @ v.T
            s = p if s is None else s + p
        return np.asarray(s / self.num_samples)

    # -- batched top-K recommendation (the serving path) -------------------

    def _block_entities(self, block: Union[int, Tuple[str, str]]
                        ) -> Tuple[int, int]:
        """(user_entity, item_entity) of ``block`` — a tuple block
        names (users, items) in that order; an integer block ranks the
        column entity's rows as items."""
        bi, flipped = self._resolve_block(block)
        blk = self.model.blocks[bi]
        if flipped:
            return blk.col_entity, blk.row_entity
        return blk.row_entity, blk.col_entity

    def user_rows(self, users: Sequence[int],
                  block: Union[int, Tuple[str, str]] = 0
                  ) -> jnp.ndarray:
        """Sampled latent rows of warm users: (B, S, K).

        Gathered from the resident cache when it fits the budget
        (zero loads); streamed from disk once otherwise.
        """
        ue, _ = self._block_entities(block)
        users = np.asarray(users, np.int32)
        n_rows = self.model.entities[ue].n_rows
        bad = users[(users < 0) | (users >= n_rows)]
        if bad.size:
            raise ValueError(
                f"user row(s) {bad.tolist()} out of range for entity "
                f"{self.model.entities[ue].name!r} with {n_rows} rows;"
                " unseen rows are served via features= (cold start)")
        cache = self.warm_cache()
        if cache is not None:
            # (S, B, K) -> (B, S, K)
            return jnp.swapaxes(cache.factors[ue][:, users, :], 0, 1)
        rows = [np.asarray(f)[users] for f in self._factor_iter(ue)]
        return jnp.swapaxes(jnp.asarray(np.stack(rows)), 0, 1)

    def cold_rows(self, F_new,
                  block: Union[int, Tuple[str, str]] = 0
                  ) -> jnp.ndarray:
        """Sampled latent rows for UNSEEN users via the Macau link:
        (M, S, K), one ``mu_s + beta_s^T f`` draw per retained sample
        (same per-sample mapping as ``predict_new``, kept per sample
        so top-K scoring sees the full posterior spread)."""
        from .priors import MacauPrior
        ue, _ = self._block_entities(block)
        ent = self.model.entities[ue]
        if not isinstance(ent.prior, MacauPrior):
            raise ValueError(
                f"entity {ent.name!r} has {type(ent.prior).__name__};"
                " cold-start recommendation needs the Macau "
                "side-information prior — add_entity(..., "
                "side_info=F)")
        F_new = np.atleast_2d(np.asarray(F_new, np.float32))
        if F_new.shape[1] != ent.prior.num_features:
            raise ValueError(
                f"F_new has {F_new.shape[1]} features; entity "
                f"{ent.name!r} was trained with "
                f"{ent.prior.num_features}")
        rows = []
        cache = self.warm_cache()
        if cache is not None:
            for s in range(cache.n_samples):
                rows.append(ent.prior.predict_factor(
                    cache.hyper_at(ue, s), F_new))
        else:
            for st in self.samples():
                rows.append(ent.prior.predict_factor(st.hypers[ue],
                                                     F_new))
        return jnp.swapaxes(jnp.stack(rows), 0, 1)   # (M, S, K)

    def _exclude_mask(self, exclude, B: int, n_items: int):
        """Per-query excluded item ids -> (B, n_items) f32 mask."""
        if exclude is None:
            return None
        mask = np.zeros((B, n_items), np.float32)
        if len(exclude) != B:
            raise ValueError(
                f"exclude has {len(exclude)} entries for {B} queries;"
                " pass one id-sequence (possibly empty) per query")
        for b, ids in enumerate(exclude):
            ids = np.asarray(ids, np.int64)
            if ids.size:
                if ids.min() < 0 or ids.max() >= n_items:
                    raise ValueError(
                        f"exclude ids for query {b} outside "
                        f"[0, {n_items})")
                mask[b, ids] = 1.0
        return mask

    def recommend_rows(self, rows: jnp.ndarray, k: int = 10,
                       block: Union[int, Tuple[str, str]] = 0,
                       exclude=None) -> RecResult:
        """Top-K items for pre-resolved query rows (B, S, K).

        The batched serving primitive: scores every query against the
        item factor stack across all retained samples through the
        fused ``kernels.topk_score`` (posterior mean ranking, std
        reported per score), honoring ``model.use_pallas``.  Queries
        are scored with one identical float program each regardless of
        batch size, so a batched call is BITWISE equal to one call per
        query — the contract that lets ``RecommendServer`` batch
        concurrent requests (asserted in tests/test_serving.py).

        ``exclude``: one sequence of item ids per query (e.g. the
        user's already-observed items) left out of the ranking.
        """
        rows = jnp.asarray(rows)
        if rows.ndim != 3:
            raise ValueError(
                f"rows must be (B, S, K), got {rows.shape}; build "
                "them with user_rows()/cold_rows()")
        _, ie = self._block_entities(block)
        n_items = self.model.entities[ie].n_rows
        mask = self._exclude_mask(exclude, rows.shape[0], n_items)
        cache = self.warm_cache()
        if cache is not None:
            ids, mean, std = ops.topk_score(
                rows, cache.factors[ie], k, exclude=mask,
                use_pallas=self.model.use_pallas)
            return RecResult(np.asarray(ids), np.asarray(mean),
                             np.asarray(std))
        return self._recommend_rows_lazy(rows, k, ie, mask)

    def _recommend_rows_lazy(self, rows, k, item_entity, mask
                             ) -> RecResult:
        """Over-budget fallback: stream the store once, accumulating
        per-item score moments, then select like the reference.
        Statistically identical to the cached path; summation order
        differs, so near-ties MAY rank differently (documented —
        serving at scale wants the cache)."""
        B, S, _ = rows.shape
        mean_sum = None
        ex2_sum = None
        for s, v in enumerate(self._factor_iter(item_entity)):
            p = jnp.einsum("bk,nk->bn", rows[:, s, :], v)
            mean_sum = p if mean_sum is None else mean_sum + p
            p2 = p * p
            ex2_sum = p2 if ex2_sum is None else ex2_sum + p2
        inv_s = jnp.float32(1.0) / jnp.float32(S)
        mean = mean_sum * inv_s
        ex2 = ex2_sum * inv_s
        std = jnp.sqrt(jnp.maximum(ex2 - mean * mean, 0.0))
        excl = (jnp.zeros_like(mean) if mask is None
                else jnp.asarray(mask))
        rank = jnp.where(excl > 0, -jnp.inf, mean)
        k_eff = min(int(k), rank.shape[1])
        order = jnp.argsort(-rank, axis=1)[:, :k_eff]    # stable
        sel_mean = jnp.take_along_axis(mean, order, axis=1)
        sel_std = jnp.take_along_axis(std, order, axis=1)
        n_valid = jnp.sum(excl <= 0, axis=1).astype(jnp.int32)
        bad = jnp.arange(k_eff, dtype=jnp.int32)[None, :] \
            >= n_valid[:, None]
        return RecResult(
            np.asarray(jnp.where(bad, -1, order.astype(jnp.int32))),
            np.asarray(jnp.where(bad, jnp.nan, sel_mean)),
            np.asarray(jnp.where(bad, jnp.nan, sel_std)))

    def recommend(self, user: Optional[Union[int, Sequence[int]]]
                  = None, *, features=None, k: int = 10,
                  block: Union[int, Tuple[str, str]] = 0,
                  exclude=None) -> RecResult:
        """Top-K recommendation for warm and/or cold users.

        ``user``: row id(s) seen in training; ``features``: (M, D)
        side-information rows for UNSEEN users, mapped through the
        sampled Macau link (cold start).  Warm queries come first in
        the result when both are given.  ``exclude`` follows
        ``recommend_rows`` (for a single query, a flat id list is
        accepted).
        """
        parts = []
        n_q = 0
        if user is not None:
            users = np.atleast_1d(np.asarray(user, np.int32))
            parts.append(self.user_rows(users, block))
            n_q += users.shape[0]
        if features is not None:
            cold = self.cold_rows(features, block)
            parts.append(cold)
            n_q += cold.shape[0]
        if not parts:
            raise ValueError(
                "pass user= (warm row ids) and/or features= "
                "(cold-start side info)")
        if exclude is not None and n_q == 1:
            # single-query convenience: accept a flat id list — and an
            # EMPTY one ("nothing to exclude"), which must normalize to
            # one empty per-query sequence, not zero sequences
            ex = list(exclude)
            if not ex or np.ndim(ex[0]) == 0:
                exclude = [ex]
        rows = parts[0] if len(parts) == 1 else \
            jnp.concatenate(parts, axis=0)
        return self.recommend_rows(rows, k, block, exclude)

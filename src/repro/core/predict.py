"""Posterior-predictive evaluation: RMSE / AUC over collected samples.

SMURFF's predict step (Algorithm 1 "for all test points") evaluated per
sweep; predictions for the final report average U_s V_s^T over the
collected posterior samples, which is what makes BMF robust against
overfitting (paper section 1).

:class:`PredictSession` is the from-disk counterpart: it reloads the
posterior samples a session streamed out (``save_freq``/``save_dir``)
and serves averaged predictions without the training data — at
arbitrary cells of any block, as whole dense blocks, and for rows
never present in training through the sampled Macau link matrices
(out-of-matrix prediction, the compound-activity cold-start workflow
of arXiv:1904.02514).
"""
from __future__ import annotations

import os
from typing import Iterator, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops


class TestSet(NamedTuple):
    i: jnp.ndarray   # (E,) int32 row ids
    j: jnp.ndarray   # (E,) int32 col ids
    v: jnp.ndarray   # (E,) f32 true values


def make_test_set(i, j, v) -> TestSet:
    return TestSet(jnp.asarray(i, jnp.int32), jnp.asarray(j, jnp.int32),
                   jnp.asarray(v, jnp.float32))


@jax.jit
def predict_one(U: jnp.ndarray, V: jnp.ndarray, test: TestSet
                ) -> jnp.ndarray:
    """Single-sample prediction at the test entries."""
    return ops.sddmm(U[test.i], V[test.j])


def rmse(pred: jnp.ndarray, truth: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean((pred - truth) ** 2))


def auc(pred: np.ndarray, truth: np.ndarray, threshold: float = 0.5
        ) -> float:
    """Rank-based AUC (Mann-Whitney); truth binarized at threshold.

    Tied predictions get MIDRANKS (the average of the ranks they
    span), the standard tie-corrected Mann-Whitney statistic: each
    tied positive/negative pair then contributes 1/2, matching the
    trapezoidal ROC area.  Raw ``argsort`` ranks instead assign tied
    groups an arbitrary input-order permutation, biasing the AUC on
    discrete/probit outputs where ties are the common case.
    """
    pred = np.asarray(pred)
    pos = np.asarray(truth) > threshold
    n_pos = int(pos.sum())
    n_neg = pos.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    _, inv, counts = np.unique(pred, return_inverse=True,
                               return_counts=True)
    # group g spans ranks (end - count, end]; its midrank is their mean
    end = np.cumsum(counts)
    ranks = (end - (counts - 1) / 2.0)[inv]
    s = ranks[pos].sum()
    return float((s - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


class PredictAccumulator:
    """Streaming average of per-sample predictions (posterior mean)."""

    def __init__(self, test: TestSet):
        self.test = test
        self._sum = jnp.zeros_like(test.v)
        self._sum2 = jnp.zeros_like(test.v)
        self.n = 0

    def update(self, U: jnp.ndarray, V: jnp.ndarray):
        p = predict_one(U, V, self.test)
        self._sum = self._sum + p
        self._sum2 = self._sum2 + p * p
        self.n += 1
        return p

    @property
    def mean(self) -> jnp.ndarray:
        return self._sum / max(self.n, 1)

    @property
    def var(self) -> jnp.ndarray:
        m = self.mean
        return jnp.maximum(self._sum2 / max(self.n, 1) - m * m, 0.0)

    def rmse(self) -> float:
        return float(rmse(self.mean, self.test.v))

    def auc(self, threshold: float = 0.5) -> float:
        return auc(np.asarray(self.mean), np.asarray(self.test.v),
                   threshold)


# ---------------------------------------------------------------------------
# from-disk prediction over saved posterior samples
# ---------------------------------------------------------------------------

class PredictSession:
    """Serve averaged predictions from a saved posterior-sample store.

    ``save_dir`` is a directory written by a session with
    ``save_freq > 0``: a ``model.json`` spec (the static entity/block
    graph — see ``core/modelspec.py``) plus ``samples/step_<sweep>``
    checkpoints, each holding one full sampled ``MFState``.  No
    training data is needed: prediction only reads the sampled factors
    and, for out-of-matrix rows, the sampled Macau link matrices in
    the hyper state.

    * ``predict(i, j, block=...)`` — posterior-mean prediction at
      arbitrary cells of a block, the same streaming average the
      in-session accumulator computes (same kernel, same summation
      order — a reload reproduces the in-session ``rmse_test`` to
      float32 tolerance, asserted in tests/test_predict_session.py).
    * ``predict_all(block=...)`` — the whole dense block's posterior
      mean (rows x cols).
    * ``predict_new(entity, F_new, block=...)`` — OUT-of-matrix: rows
      never present in training, mapped into latent space per sample
      through the sampled link (``MacauPrior.predict_factor``:
      ``mu_s + beta_s^T f``) and contracted against that sample's
      other-entity factor.
    * ``restore_latest()`` — (step, MFState) of the newest sample, for
      continuing an interrupted chain (``Session.run(resume=True)``
      uses the same store).

    Samples are loaded lazily, one at a time — the store can be much
    bigger than memory.
    """

    def __init__(self, save_dir: str):
        from ..checkpoint.ckpt import list_steps
        from .modelspec import (MODEL_SPEC_FILE, SAMPLES_SUBDIR,
                                load_model_spec, spec_to_model,
                                state_template)
        self.dir = save_dir
        self.spec = load_model_spec(os.path.join(save_dir,
                                                 MODEL_SPEC_FILE))
        self.model = spec_to_model(self.spec)
        self._template = state_template(self.model)
        self._samples_dir = os.path.join(save_dir, SAMPLES_SUBDIR)
        self.steps: List[int] = list_steps(self._samples_dir)
        if not self.steps:
            raise ValueError(
                f"no complete samples under {self._samples_dir}; run "
                "the session with save_freq > 0 (and let at least one "
                "post-burnin sweep finish)")

    # -- sample access -----------------------------------------------------

    @property
    def num_samples(self) -> int:
        return len(self.steps)

    def load_sample(self, step: int):
        """The full sampled ``MFState`` saved at global sweep ``step``."""
        from ..checkpoint.ckpt import load_pytree
        if step not in self.steps:
            raise ValueError(
                f"no sample at step {step}; saved steps: {self.steps}")
        return load_pytree(self._template,
                           os.path.join(self._samples_dir,
                                        f"step_{step}"))

    def samples(self) -> Iterator:
        """Lazily yield every sampled state, in chain order."""
        for s in self.steps:
            yield self.load_sample(s)

    def restore_latest(self) -> Tuple[int, object]:
        """(step, MFState) of the newest sample — the resume point."""
        last = self.steps[-1]
        return last, self.load_sample(last)

    # -- block/entity resolution -------------------------------------------

    def _resolve_block(self, block: Union[int, Tuple[str, str]]
                       ) -> Tuple[int, bool]:
        """(block_index, flipped): ``flipped`` means the caller named
        the pair in the OPPOSITE order to the block's stored
        orientation — their (i, j) address (col, row) cells and their
        result axes are transposed.  An integer block always addresses
        the stored orientation."""
        model = self.model
        if isinstance(block, tuple):
            a = model.entity_index(block[0])
            b = model.entity_index(block[1])
            for bi, blk in enumerate(model.blocks):
                if (blk.row_entity, blk.col_entity) == (a, b):
                    return bi, False
                if (blk.row_entity, blk.col_entity) == (b, a):
                    return bi, True
            names = model.entity_names
            pairs = ", ".join(
                f"({names[blk.row_entity]}, {names[blk.col_entity]})"
                for blk in model.blocks)
            raise ValueError(
                f"no block relates {block!r}; blocks in this model: "
                f"{pairs}")
        bi = int(block)
        if not 0 <= bi < len(model.blocks):
            raise ValueError(
                f"block index {bi} out of range; this model has "
                f"{len(model.blocks)} blocks")
        return bi, False

    # -- prediction --------------------------------------------------------

    def predict(self, i, j, block: Union[int, Tuple[str, str]] = 0,
                return_var: bool = False):
        """Posterior-mean prediction at cells (i[e], j[e]) of a block.

        The identical streaming average the in-session accumulator
        runs — one ``predict_one`` per sample, summed in chain order —
        so a reload reproduces the in-session posterior mean at the
        same cells to float32 tolerance.  A tuple ``block`` addresses
        (i, j) in the order the tuple names the entities, whichever
        orientation the block was declared in.
        """
        bi, flipped = self._resolve_block(block)
        blk = self.model.blocks[bi]
        if flipped:
            i, j = j, i
        i = np.asarray(i)
        test = make_test_set(i, j, np.zeros(i.shape[0], np.float32))
        acc = PredictAccumulator(test)
        for st in self.samples():
            acc.update(jnp.asarray(st.factors[blk.row_entity]),
                       jnp.asarray(st.factors[blk.col_entity]))
        if return_var:
            return np.asarray(acc.mean), np.asarray(acc.var)
        return np.asarray(acc.mean)

    def predict_all(self, block: Union[int, Tuple[str, str]] = 0
                    ) -> np.ndarray:
        """The whole block's posterior-mean prediction.

        Axes follow the order the caller named the entities in a tuple
        ``block`` (an integer block uses the stored orientation).
        """
        bi, flipped = self._resolve_block(block)
        blk = self.model.blocks[bi]
        s = None
        for st in self.samples():
            p = jnp.asarray(st.factors[blk.row_entity]) \
                @ jnp.asarray(st.factors[blk.col_entity]).T
            s = p if s is None else s + p
        out = np.asarray(s / self.num_samples)
        return out.T if flipped else out

    def predict_new(self, entity: Union[int, str], F_new,
                    block: Optional[Union[int, Tuple[str, str]]] = None
                    ) -> np.ndarray:
        """Out-of-matrix prediction for UNSEEN rows of ``entity``.

        ``F_new`` (M, D) holds the new rows' side-information features;
        each retained sample maps them into latent space through ITS
        link matrix draw (``mu_s + beta_s^T f``, exposed as
        ``MacauPrior.predict_factor``) and contracts against ITS
        other-entity factor — averaging after the nonlinearity, the
        correct posterior-predictive mean.  Returns (M, n_other)
        predictions against ``block``'s other entity (``block`` may be
        omitted when only one block touches the entity).
        """
        from .priors import MacauPrior
        model = self.model
        e = model.entity_index(entity)
        ent = model.entities[e]
        if not isinstance(ent.prior, MacauPrior):
            raise ValueError(
                f"entity {ent.name!r} has {type(ent.prior).__name__}; "
                "out-of-matrix prediction needs the Macau "
                "side-information prior (its sampled beta link maps "
                "new feature rows to latents) — add_entity(..., "
                "side_info=F)")
        touching = model.blocks_touching(e)
        if block is None:
            if len(touching) != 1:
                names = model.entity_names
                opts = ", ".join(
                    f"({names[model.blocks[bi].row_entity]}, "
                    f"{names[model.blocks[bi].col_entity]})"
                    for bi, _ in touching)
                raise ValueError(
                    f"entity {ent.name!r} touches {len(touching)} "
                    f"blocks ({opts}); pass block= to pick one")
            bi = touching[0][0]
        else:
            bi, _ = self._resolve_block(block)
            if bi not in [b for b, _ in touching]:
                names = model.entity_names
                opts = ", ".join(
                    f"({names[model.blocks[b].row_entity]}, "
                    f"{names[model.blocks[b].col_entity]})"
                    for b, _ in touching)
                raise ValueError(
                    f"block {block!r} does not touch entity "
                    f"{ent.name!r}; touching blocks: {opts}")
        other = model.blocks[bi].other(e)
        F_new = np.atleast_2d(np.asarray(F_new, np.float32))
        if F_new.shape[1] != ent.prior.num_features:
            raise ValueError(
                f"F_new has {F_new.shape[1]} features; entity "
                f"{ent.name!r} was trained with "
                f"{ent.prior.num_features}")
        s = None
        for st in self.samples():
            u = ent.prior.predict_factor(st.hypers[e], F_new)
            p = u @ jnp.asarray(st.factors[other]).T
            s = p if s is None else s + p
        return np.asarray(s / self.num_samples)

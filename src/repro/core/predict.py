"""Posterior-predictive evaluation: RMSE / AUC over collected samples.

SMURFF's predict step (Algorithm 1 "for all test points") evaluated per
sweep; predictions for the final report average U_s V_s^T over the
collected posterior samples, which is what makes BMF robust against
overfitting (paper section 1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops


class TestSet(NamedTuple):
    i: jnp.ndarray   # (E,) int32 row ids
    j: jnp.ndarray   # (E,) int32 col ids
    v: jnp.ndarray   # (E,) f32 true values


def make_test_set(i, j, v) -> TestSet:
    return TestSet(jnp.asarray(i, jnp.int32), jnp.asarray(j, jnp.int32),
                   jnp.asarray(v, jnp.float32))


@jax.jit
def predict_one(U: jnp.ndarray, V: jnp.ndarray, test: TestSet
                ) -> jnp.ndarray:
    """Single-sample prediction at the test entries."""
    return ops.sddmm(U[test.i], V[test.j])


def rmse(pred: jnp.ndarray, truth: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean((pred - truth) ** 2))


def auc(pred: np.ndarray, truth: np.ndarray, threshold: float = 0.5
        ) -> float:
    """Rank-based AUC (Mann-Whitney); truth binarized at threshold.

    Tied predictions get MIDRANKS (the average of the ranks they
    span), the standard tie-corrected Mann-Whitney statistic: each
    tied positive/negative pair then contributes 1/2, matching the
    trapezoidal ROC area.  Raw ``argsort`` ranks instead assign tied
    groups an arbitrary input-order permutation, biasing the AUC on
    discrete/probit outputs where ties are the common case.
    """
    pred = np.asarray(pred)
    pos = np.asarray(truth) > threshold
    n_pos = int(pos.sum())
    n_neg = pos.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    _, inv, counts = np.unique(pred, return_inverse=True,
                               return_counts=True)
    # group g spans ranks (end - count, end]; its midrank is their mean
    end = np.cumsum(counts)
    ranks = (end - (counts - 1) / 2.0)[inv]
    s = ranks[pos].sum()
    return float((s - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


class PredictAccumulator:
    """Streaming average of per-sample predictions (posterior mean)."""

    def __init__(self, test: TestSet):
        self.test = test
        self._sum = jnp.zeros_like(test.v)
        self._sum2 = jnp.zeros_like(test.v)
        self.n = 0

    def update(self, U: jnp.ndarray, V: jnp.ndarray):
        p = predict_one(U, V, self.test)
        self._sum = self._sum + p
        self._sum2 = self._sum2 + p * p
        self.n += 1
        return p

    @property
    def mean(self) -> jnp.ndarray:
        return self._sum / max(self.n, 1)

    @property
    def var(self) -> jnp.ndarray:
        m = self.mean
        return jnp.maximum(self._sum2 / max(self.n, 1) - m * m, 0.0)

    def rmse(self) -> float:
        return float(rmse(self.mean, self.test.v))

    def auc(self, threshold: float = 0.5) -> float:
        return auc(np.asarray(self.mean), np.asarray(self.test.v),
                   threshold)

"""Model-graph (de)serialization for on-disk posterior samples.

A session streaming posterior samples to disk (``Session`` with
``save_freq > 0``) writes TWO things: the sampled ``MFState`` pytrees
(via ``checkpoint.CheckpointManager``, one ``step_<sweep>`` per
retained sample) and ONE ``model.json`` spec produced here.  The spec
captures the static model graph — entities (name, rows, prior with all
its hyper-parameters), blocks (which entities, noise, sparse/dense),
``num_latent`` — which is exactly what ``PredictSession`` needs to

* rebuild an ``MFState`` *template* whose pytree structure matches the
  saved npz leaves (``state_template``), and
* know which entities carry a Macau link matrix for out-of-matrix
  prediction,

WITHOUT the observed data payloads (those are not needed to predict
from samples, and can be huge).

Priors and noises are frozen dataclasses, so round-tripping is just
``dataclasses.asdict`` + a ``type`` tag resolved through an explicit
registry — an unknown tag raises a ValueError naming the valid
choices, mirroring the session layer's ``_PRIORS`` errors.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict

from .blocks import BlockDef, EntityDef, ModelDef
from .gibbs import MFState
from .noise import AdaptiveGaussian, FixedGaussian, ProbitNoise
from .priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                     SpikeAndSlabPrior)

MODEL_SPEC_FILE = "model.json"
SAMPLES_SUBDIR = "samples"
# multi-chain stores nest one full single-chain store per chain:
# save_dir/chain_<c>/{model.json, samples/}; the top-level model.json's
# run.chains announces the layout (see Session._make_savers)
CHAIN_SUBDIR_PREFIX = "chain_"


def chain_subdir(c: int) -> str:
    return f"{CHAIN_SUBDIR_PREFIX}{int(c)}"


def chain_count_on_disk(save_dir: str) -> int:
    """Number of ``chain_<c>`` stores under ``save_dir`` (0 = legacy
    single-chain layout).  Requires a contiguous 0..C-1 run."""
    c = 0
    while os.path.isdir(os.path.join(save_dir, chain_subdir(c))):
        c += 1
    return c

PRIOR_TYPES = {cls.__name__: cls for cls in
               (NormalPrior, FixedNormalPrior, MacauPrior,
                SpikeAndSlabPrior)}
NOISE_TYPES = {cls.__name__: cls for cls in
               (FixedGaussian, AdaptiveGaussian, ProbitNoise)}


def _to_spec(obj: Any, registry: Dict[str, type], what: str) -> dict:
    name = type(obj).__name__
    if name not in registry:
        raise ValueError(
            f"cannot serialize {what} {name!r}; serializable {what}s: "
            f"{', '.join(sorted(registry))}")
    return {"type": name, **dataclasses.asdict(obj)}


def _from_spec(d: dict, registry: Dict[str, type], what: str):
    d = dict(d)
    name = d.pop("type", None)
    if name not in registry:
        raise ValueError(
            f"unknown {what} type {name!r} in model spec; valid "
            f"{what}s: {', '.join(sorted(registry))}")
    return registry[name](**d)


def model_to_spec(model: ModelDef) -> dict:
    """JSON-safe dict capturing the full static model graph."""
    return {
        "format": "repro-mf-model-v1",
        "num_latent": model.num_latent,
        "use_pallas": model.use_pallas,
        "bf16_gather": model.bf16_gather,
        "entities": [
            {"name": e.name, "n_rows": e.n_rows,
             "prior": _to_spec(e.prior, PRIOR_TYPES, "prior")}
            for e in model.entities],
        "blocks": [
            {"row_entity": b.row_entity, "col_entity": b.col_entity,
             "sparse": b.sparse,
             "noise": _to_spec(b.noise, NOISE_TYPES, "noise")}
            for b in model.blocks],
    }


def spec_to_model(spec: dict) -> ModelDef:
    """Rebuild the ``ModelDef`` (static graph only, no data payloads)."""
    ents = tuple(
        EntityDef(e["name"], int(e["n_rows"]),
                  _from_spec(e["prior"], PRIOR_TYPES, "prior"))
        for e in spec["entities"])
    blocks = tuple(
        BlockDef(int(b["row_entity"]), int(b["col_entity"]),
                 _from_spec(b["noise"], NOISE_TYPES, "noise"),
                 bool(b["sparse"]))
        for b in spec["blocks"])
    return ModelDef(ents, blocks, int(spec["num_latent"]),
                    bool(spec.get("use_pallas", False)),
                    bool(spec.get("bf16_gather", False)))


def state_template(model: ModelDef) -> MFState:
    """An ``MFState`` skeleton structurally identical to a live chain's.

    ``checkpoint.load_pytree`` needs a template with the same pytree
    structure and leaf shapes as the saved state — so this IS
    ``gibbs.init_state``, which builds the state from the static graph
    alone (its ``data`` argument is never read), guaranteeing the
    template can never drift leaf-for-leaf from what sessions save.
    The leaf values are irrelevant; ``load_pytree`` overwrites them.
    """
    from .gibbs import init_state
    return init_state(model, None, seed=0)


def save_model_spec(path: str, spec: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(spec, f, indent=1)
    os.replace(tmp, path)


def load_model_spec(path: str) -> dict:
    if not os.path.exists(path):
        raise ValueError(
            f"no model spec at {path}; posterior-sample directories are "
            "written by a Session with save_freq > 0 (TrainSession/"
            "ModelBuilder.session save_dir=...)")
    with open(path) as f:
        return json.load(f)

"""Noise models (paper Table 1, col 3).

* ``FixedGaussian``    — fixed precision alpha.
* ``AdaptiveGaussian`` — alpha ~ Gamma conditional on the residual SSE
                         (SMURFF's "adaptive" noise).
* ``ProbitNoise``      — binary data via truncated-normal latent
                         augmentation (unit precision on the latents).

Each noise model owns a tiny state pytree and two hooks used by the
Gibbs sweep:

* ``sample_state(key, state, pred, vals, mask)`` — resample the noise
  state from residuals at the observed entries.
* ``augment(key, state, pred, vals, mask)`` — return the effective
  (values, precision) the factor update should regress on.  For
  Gaussian noise this is identity; for probit it draws the truncated-
  normal latents.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_SQRT2 = 1.4142135623730951


@dataclasses.dataclass(frozen=True)
class FixedGaussian:
    precision: float = 5.0

    def init(self):
        return {"alpha": jnp.asarray(self.precision, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        return state

    def augment(self, key, state, pred, vals, mask):
        return vals, state["alpha"]


@dataclasses.dataclass(frozen=True)
class AdaptiveGaussian:
    """alpha ~ Gamma(a0 + nnz/2, b0 + SSE/2), resampled every sweep.

    ``sn_init`` seeds alpha; ``sn_max`` caps it (SMURFF exposes the same
    knobs as signal-to-noise ratios; we keep them as direct precisions).
    """

    sn_init: float = 1.0
    sn_max: float = 1e4
    a0: float = 0.5
    b0: float = 0.5

    def init(self):
        return {"alpha": jnp.asarray(self.sn_init, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        """``sse``/``nnz`` override the local residual sums — the
        distributed sweep psums them over shards first, so every shard
        draws the same alpha from the same (replicated) key."""
        if sse is None:
            resid = (vals - pred) * mask
            sse = jnp.sum(resid * resid)
        if nnz is None:
            nnz = jnp.sum(mask)
        a_post = self.a0 + 0.5 * nnz
        b_post = self.b0 + 0.5 * sse
        alpha = jax.random.gamma(key, a_post) / b_post
        return {"alpha": jnp.clip(alpha, 1e-6, self.sn_max)
                .astype(jnp.float32)}

    def augment(self, key, state, pred, vals, mask):
        return vals, state["alpha"]


def _truncnorm(key, mean, lower_tail: jnp.ndarray):
    """z ~ N(mean, 1) truncated to z>0 where lower_tail else z<0.

    Inverse-CDF sampling in float32 via erfinv; numerically safe for
    |mean| up to ~8 (clip keeps the CDF arguments in open (0, 1)).
    """
    u = jax.random.uniform(key, mean.shape, dtype=jnp.float32,
                           minval=1e-7, maxval=1.0 - 1e-7)
    # P(z < 0) = Phi(-mean)
    p0 = 0.5 * (1.0 + jax.lax.erf(-mean / _SQRT2))
    p0 = jnp.clip(p0, 1e-7, 1.0 - 1e-7)
    # positive side: U ~ (p0, 1); negative side: U ~ (0, p0)
    uu = jnp.where(lower_tail > 0, p0 + u * (1.0 - p0), u * p0)
    z = mean + _SQRT2 * jax.lax.erf_inv(2.0 * uu - 1.0)
    return jnp.clip(z, mean - 8.0, mean + 8.0)


@dataclasses.dataclass(frozen=True)
class ProbitNoise:
    """Binary matrices: P(r=1) = Phi(u.v); Albert-Chib augmentation.

    ``augment`` replaces each observed binary value with a latent
    z ~ TruncNormal(pred, 1) whose sign matches the observation, and
    fixes the regression precision at 1.
    """

    threshold: float = 0.5  # vals > threshold count as positive

    def init(self):
        return {"alpha": jnp.asarray(1.0, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        return state

    def augment(self, key, state, pred, vals, mask):
        pos = (vals > self.threshold).astype(jnp.float32)
        z = _truncnorm(key, pred, pos)
        return z * mask, state["alpha"]

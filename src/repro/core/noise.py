"""Noise models (paper Table 1, col 3).

* ``FixedGaussian``    — fixed precision alpha.
* ``AdaptiveGaussian`` — alpha ~ Gamma conditional on the residual SSE
                         (SMURFF's "adaptive" noise).
* ``ProbitNoise``      — binary data via truncated-normal latent
                         augmentation (unit precision on the latents).

Each noise model owns a tiny state pytree and two hooks used by the
Gibbs sweep:

* ``sample_state(key, state, pred, vals, mask)`` — resample the noise
  state from residuals at the observed entries.
* ``augment(key, state, pred, vals, mask)`` — return the effective
  (values, precision) the factor update should regress on.  For
  Gaussian noise this is identity; for probit it draws the truncated-
  normal latents.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_SQRT2 = 1.4142135623730951


@dataclasses.dataclass(frozen=True)
class FixedGaussian:
    precision: float = 5.0

    def init(self):
        return {"alpha": jnp.asarray(self.precision, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        return state

    def augment(self, key, state, pred, vals, mask, row_offset=0):
        return vals, state["alpha"]


@dataclasses.dataclass(frozen=True)
class AdaptiveGaussian:
    """alpha ~ Gamma(a0 + nnz/2, b0 + SSE/2), resampled every sweep.

    ``sn_init`` seeds alpha; ``sn_max`` caps it (SMURFF exposes the same
    knobs as signal-to-noise ratios; we keep them as direct precisions).
    """

    sn_init: float = 1.0
    sn_max: float = 1e4
    a0: float = 0.5
    b0: float = 0.5

    def init(self):
        return {"alpha": jnp.asarray(self.sn_init, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        """``sse``/``nnz`` override the local residual sums — the
        distributed sweep psums them over shards first, so every shard
        draws the same alpha from the same (replicated) key."""
        if sse is None:
            resid = (vals - pred) * mask
            sse = jnp.sum(resid * resid)
        if nnz is None:
            nnz = jnp.sum(mask)
        a_post = self.a0 + 0.5 * nnz
        b_post = self.b0 + 0.5 * sse
        alpha = jax.random.gamma(key, a_post) / b_post
        alpha = jnp.clip(alpha, 1e-6, self.sn_max).astype(jnp.float32)
        # an all-masked block (or fully padded shard view) has no
        # residuals to learn from: keep the previous alpha instead of
        # drawing from the data-free (degenerate) Gamma conditional
        return {"alpha": jnp.where(nnz > 0, alpha, state["alpha"])}

    def augment(self, key, state, pred, vals, mask, row_offset=0):
        return vals, state["alpha"]


_EPS = 1e-7


def _truncnorm_from_u(u, mean, lower_tail: jnp.ndarray):
    """Inverse-CDF truncated-normal transform of uniforms ``u``.

    z ~ N(mean, 1) truncated to z>0 where lower_tail else z<0, with
    u in the open interval (0, 1).  Elementwise, so a row slice of
    (u, mean, lower_tail) yields exactly the matching slice of z —
    which is what lets the distributed sweep draw per-shard.
    """
    # P(z < 0) = Phi(-mean)
    p0 = 0.5 * (1.0 + jax.lax.erf(-mean / _SQRT2))
    p0 = jnp.clip(p0, _EPS, 1.0 - _EPS)
    # positive side: U ~ (p0, 1); negative side: U ~ (0, p0)
    uu = jnp.where(lower_tail > 0, p0 + u * (1.0 - p0), u * p0)
    z = mean + _SQRT2 * jax.lax.erf_inv(2.0 * uu - 1.0)
    return jnp.clip(z, mean - 8.0, mean + 8.0)


def _truncnorm(key, mean, lower_tail: jnp.ndarray):
    """z ~ N(mean, 1) truncated to z>0 where lower_tail else z<0.

    Inverse-CDF sampling in float32 via erfinv; numerically safe for
    |mean| up to ~8 (clip keeps the CDF arguments in open (0, 1)).
    One batch-shaped draw — the Gibbs sweep instead goes through
    ``ProbitNoise.augment`` whose uniforms are per-row counter-based.
    """
    u = jax.random.uniform(key, mean.shape, dtype=jnp.float32,
                           minval=_EPS, maxval=1.0 - _EPS)
    return _truncnorm_from_u(u, mean, lower_tail)


@dataclasses.dataclass(frozen=True)
class ProbitNoise:
    """Binary matrices: P(r=1) = Phi(u.v); Albert-Chib augmentation.

    ``augment`` replaces each observed binary value with a latent
    z ~ TruncNormal(pred, 1) whose sign matches the observation, and
    fixes the regression precision at 1.

    The uniforms behind the truncated-normal draws are per-row
    counter-based (``gibbs.row_uniforms``): row i of a (R, T) operand
    draws from ``fold_in(key, row_offset + i)``, a pure function of
    the sweep key and the row's GLOBAL index.  A shard holding rows
    [off, off + n) of the padded view therefore consumes exactly the
    uniforms the single-device sweep consumes for those rows — the
    same contract as ``gibbs.row_normals`` — which is what admits
    probit models into the explicit distributed sweep.
    """

    threshold: float = 0.5  # vals > threshold count as positive

    def init(self):
        return {"alpha": jnp.asarray(1.0, jnp.float32)}

    def sample_state(self, key, state, pred, vals, mask,
                     sse=None, nnz=None):
        return state

    def augment(self, key, state, pred, vals, mask, row_offset=0):
        # deferred import: gibbs imports this module at load time
        from .gibbs import row_uniforms
        pos = (vals > self.threshold).astype(jnp.float32)
        u = row_uniforms(key, vals.shape[0], vals.shape[1], row_offset,
                         minval=_EPS, maxval=1.0 - _EPS)
        z = _truncnorm_from_u(u, pred, pos)
        return z * mask, state["alpha"]

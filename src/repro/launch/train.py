"""Training step + loop: grad-accumulated, sharded, restartable.

``make_train_step`` builds the jit-able (params, opt, batch) -> step
with microbatch gradient accumulation (lax.scan) — the per-microbatch
activation footprint is what fits in HBM; the accumulated grad lives in
fp32 and shards like the params (ZeRO-3 posture).

``train`` is the runnable driver used by examples/train_lm.py: data
pipeline, checkpoint/auto-resume, straggler monitor, failure-restart.
"""
from __future__ import annotations

import functools
from ..obs import clock
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager
from ..data import TokenStream, make_lm_batch
from ..models import init_model, loss_fn
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..runtime import StragglerMonitor
from . import specs as S


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    mesh: Optional[Mesh] = None, n_micro: int = 1,
                    remat: bool = True):
    """Returns ``step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``n_micro`` splits the global batch into scan-accumulated
    microbatches (batch axis must divide).
    """

    def micro_loss(params, mb):
        return loss_fn(params, cfg, mb, mesh=mesh, remat=remat)

    def step(params, opt_state, batch):
        if n_micro == 1:
            (loss, met), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(n_micro, x.shape[0] // n_micro,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, met), g = jax.value_and_grad(
                    micro_loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), met

            (grads, loss), met = jax.lax.scan(
                acc, (g0, jnp.asarray(0.0)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            met = jax.tree.map(lambda x: x[-1], met)

        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step


def make_sharded_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                            mesh: Mesh, shape, *, n_micro: int = 1,
                            donate: bool = True,
                            variant: str = "baseline"):
    """jit-with-shardings version for the production mesh / dry-run.

    ``variant`` selects the perf flavor recorded in §Perf:
      baseline — remat on, scan-accumulated microbatches
      noremat  — activation checkpointing off (memory-vs-compute trade)
      dponly   — batch over the whole mesh (model axis included),
                 params replicated + ZeRO-1 moments: the small-model
                 regime where TP would replicate attention compute
    """
    variant = S.effective_variant(variant, shape, mesh)
    flags = variant.split(",")
    if "dponly" in flags:
        n_micro = 1          # 1-seq-per-device batches need no accum
    for f in flags:          # explicit microbatch override: "micro<k>"
        if f.startswith("micro") and f[5:].isdigit():
            n_micro = int(f[5:])
    raw_step = make_train_step(cfg, opt_cfg, mesh=mesh, n_micro=n_micro,
                               remat=(variant != "noremat"))

    def step(params, opt_state, batch):
        # the policy context is live while jit traces this body, so
        # every shd.constrain in the model sees the variant
        from ..models import sharding as shd
        with shd.policy(variant):
            return raw_step(params, opt_state, batch)

    ps, os_ = S.train_state_shardings(cfg, mesh, variant=variant)
    bsh = S.batch_shardings(cfg, shape, mesh, variant=variant)
    return jax.jit(
        step,
        in_shardings=(ps, os_, bsh),
        out_shardings=(ps, os_, NamedSharding(mesh, P())),
        donate_argnums=(0, 1) if donate else (),
    ), (ps, os_, bsh)


def train(cfg: ModelConfig, *, steps: int = 100, batch: int = 8,
          seq: int = 128, opt_cfg: Optional[AdamWConfig] = None,
          ckpt_dir: Optional[str] = None, save_every: int = 50,
          seed: int = 0, n_micro: int = 1, log_every: int = 10,
          failure_sim=None) -> Dict[str, Any]:
    """Single-host runnable training loop (examples / smoke tests)."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_micro=n_micro))
    stream = TokenStream(cfg.vocab_size, seed=seed)
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    mon = StragglerMonitor()

    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw_init(params)
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest((params, opt_state))
        if restored is not None:
            start, (params, opt_state) = restored

    losses = []
    t0 = clock.perf_counter()
    i = start
    while i < steps:
        try:
            if failure_sim is not None:
                failure_sim.check(i)
            b = make_lm_batch(
                stream, i, batch, seq,
                frontend_tokens=cfg.n_frontend_tokens,
                d_model=cfg.d_model,
                enc_frames=cfg.encoder_frames
                if cfg.is_encoder_decoder else 0)
            ts = clock.perf_counter()
            params, opt_state, m = step_fn(params, opt_state, b)
            mon.record(clock.perf_counter() - ts)
            losses.append(float(m["loss"]))
            if log_every and i % log_every == 0:
                print(f"step {i:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  "
                      f"lr {float(m['lr']):.2e}")
            i += 1
            if mgr is not None and (i % save_every == 0 or i == steps):
                mgr.save(i, (params, opt_state))
        except Exception as e:  # noqa: BLE001 — restart path
            if failure_sim is not None and \
                    type(e).__name__ == "DeviceLost":
                restored = mgr.restore_latest((params, opt_state)) \
                    if mgr else None
                if restored is None:
                    i = 0
                    params = init_model(jax.random.PRNGKey(seed), cfg)
                    opt_state = adamw_init(params)
                else:
                    i, (params, opt_state) = restored
                continue
            raise
    if mgr is not None:
        mgr.wait()
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "runtime_s": clock.perf_counter() - t0,
            "final_step": i}

"""Production mesh construction.

Never touches jax device state at import time — everything is a
function, and the dry-run entry point is the only place that sets
``xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))

"""Production mesh construction.

Never touches jax device state at import time — everything is a
function, and the dry-run entry point is the only place that sets
``xla_force_host_platform_device_count``.  All mesh construction goes
through ``repro.compat`` so the same code runs on JAX 0.4.x (no
``AxisType``, no ``axis_types=`` kwarg) and on current releases.
"""
from __future__ import annotations

from .. import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return compat.make_mesh(tuple(shape), tuple(axes))

"""Roofline terms from the compiled dry-run artifact.

This container is CPU-only, so nothing is *measured*: all three terms
are derived from XLA's compiled-artifact cost table (via
``hlo_cost.xla_cost_analysis`` / ``compat.cost_analysis`` — the raw
``compiled.cost_analysis()`` return type is version-dependent) plus an
HLO-text parse that sums the operand bytes of every collective.
XLA reports the cost of the *per-device* SPMD module (verified in
``tests/test_roofline.py``: a jit over N devices reports ~1/N of the
global matmul FLOPs), so each term divides by per-chip peaks directly:

    compute_s    = flops_per_device / PEAK_FLOPS
    memory_s     = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

Collective bytes come from the trip-count-aware HLO analyzer in
``hlo_cost.py`` (operand bytes summed per collective kind).

Hardware constants (TPU v5e-like, given by the brief): 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful FLOPs per device: 6·N_active·D train, 2·N_active·D fwd."""
    from ..models.config import param_count
    total, active = param_count(cfg)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * active * tokens / n_chips


def roofline_terms(rec: Dict, cfg, shape) -> Dict:
    """The three terms (s), the bottleneck, and the useful-FLOP ratio.

    The memory term uses ``bytes_hbm`` (TPU-fusion materialization
    model + entry args/outputs — see hlo_cost._MATERIALIZE) when the
    record carries it; ``bytes_accessed`` (every top-level op at
    CPU-fusion granularity) is kept in the record as the upper bound.
    """
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec.get("bytes_hbm", rec["bytes_accessed"]) / HBM_BW
    coll = rec["collective_bytes"]["total"] / ICI_BW
    dom = max(("compute", comp), ("memory", mem),
              ("collective", coll), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape, rec["n_chips"])
    bound = max(comp, mem, coll)
    return {
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_flop_ratio": (mf / rec["flops"]) if rec["flops"] else 0.0,
        # fraction of roofline-bound time the chip would spend at peak
        # on *useful* math — the headline perf score
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
    }

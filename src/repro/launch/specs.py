"""ShapeDtypeStruct stand-ins for every model input + state shardings.

``input_specs(cfg, shape)`` returns exactly what the corresponding step
function takes, as abstract values — weak-type-correct, shardable, no
device allocation — so the dry-run can ``.lower()`` full-size cells on
placeholder devices.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import ShapeSpec
from ..models import init_model, init_serve_cache
from ..models.config import ModelConfig
from ..models import sharding as shd
from ..optim import adamw_init

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract training/prefill batch for one (arch, shape) cell."""
    B = shape.global_batch
    S = shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        # seq drives the audio axis; decoder fixed at 448 tokens
        from ..configs.whisper_medium import DECODER_LEN
        out["enc_frames"] = _sds((B, S, cfg.d_model), F32)
        out["tokens"] = _sds((B, DECODER_LEN), I32)
        out["labels"] = _sds((B, DECODER_LEN), I32)
        return out
    s_text = S - cfg.n_frontend_tokens
    out["tokens"] = _sds((B, s_text), I32)
    out["labels"] = _sds((B, s_text), I32)
    if cfg.n_frontend_tokens:
        out["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                               F32)
    return out


def effective_variant(variant: str, shape: ShapeSpec, mesh: Mesh) -> str:
    """Drop flags whose preconditions the cell violates.

    ``dponly`` requires the global batch to divide the WHOLE mesh —
    otherwise disabling the TP constraints just replicates compute on
    every model rank (measured: smollm prefill_32k, B=32 on 256
    chips: 16x the FLOPs and 85 GiB/dev).
    """
    flags = [f for f in variant.split(",") if f]
    if "dponly" in flags:
        n = 1
        for a in mesh.axis_names:
            n *= mesh.shape[a]
        if shape.global_batch % n:
            flags.remove("dponly")
    return ",".join(flags) or "baseline"


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    variant: str = "baseline"):
    if "dponly" in variant.split(","):
        # treat the model axis as extra data parallelism: batch shards
        # over every mesh axis that divides it (small-model regime
        # where TP would replicate attention compute)
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        bs = P(axes) if shape.global_batch % shd._axis_size(
            mesh, axes) == 0 else shd.batch_spec(mesh, shape.global_batch)
    else:
        bs = shd.batch_spec(mesh, shape.global_batch)

    def leaf(x):
        spec = [bs[0] if len(bs) else None] + [None] * (len(x.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_specs(cfg, shape))


def abstract_train_state(cfg: ModelConfig):
    """(params, opt_state) as ShapeDtypeStructs via eval_shape."""

    def init():
        p = init_model(jax.random.PRNGKey(0), cfg)
        return p, adamw_init(p)

    return jax.eval_shape(init)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh,
                          variant: str = "baseline"):
    params_abs, opt_abs = abstract_train_state(cfg)
    if "dponly" in variant.split(","):
        # pure data parallelism: params replicated, optimizer moments
        # ZeRO-1-sharded over the whole mesh on the largest divisible
        # dim.  XLA then reduce-scatters grads into the moment shards
        # and all-gathers the updated params — no TP collectives.
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        n = shd._axis_size(mesh, axes)

        def pspec(x):
            return NamedSharding(mesh, P())

        def mspec(x):
            for d in range(len(x.shape)):
                if x.shape[d] % n == 0 and x.shape[d] >= n:
                    return NamedSharding(
                        mesh, P(*([None] * d + [axes])))
            return NamedSharding(mesh, P())

        ps = jax.tree.map(pspec, params_abs)
        ms = jax.tree.map(mspec, params_abs)
        return ps, type(opt_abs)(m=ms, v=ms,
                                 step=NamedSharding(mesh, P()))
    with shd.policy(variant):   # rules consult perf flags (e.g. "ep")
        ps = shd.param_shardings(params_abs, mesh)
    # m and v shard identically to the params; step replicated
    return ps, type(opt_abs)(m=ps, v=ps,
                             step=NamedSharding(mesh, P()))


# ---------------------------------------------------------------------------
# serve-side specs
# ---------------------------------------------------------------------------

def abstract_serve_cache(cfg: ModelConfig, shape: ShapeSpec):
    """Decode caches pre-filled to seq_len-1 (the cell's KV length)."""
    B = shape.global_batch
    max_len = shape.seq_len

    def init():
        params = init_model(jax.random.PRNGKey(0), cfg)
        enc = None
        if cfg.is_encoder_decoder:
            enc = jnp.zeros((B, max_len, cfg.d_model), F32)
        # whisper decoder self-cache is its 448 positions; the long
        # axis lives in the cross K/V
        self_len = 448 if cfg.is_encoder_decoder else max_len
        return init_serve_cache(params, cfg, B, self_len, enc_out=enc,
                                prefilled=self_len - 1)

    return jax.eval_shape(init)


def serve_cache_shardings(cfg: ModelConfig, shape: ShapeSpec,
                          mesh: Mesh):
    """Cache sharding: batch over dp, long (cache-seq) axis over model.

    Works for every cache flavor in the pool:
      attn k/v   (B, S, KVH, hd)  -> (dp, model, None, None)
      mla c_kv   (B, S, r)        -> (dp, model, None)
      mamba conv (B, W-1, CH)     -> (dp, None, TP)
      mamba state(B, H, N, P)     -> (dp, TP, None, None)
      cross k/v  (L, B, S, H, hd) -> (None, dp, model, None, None)
    Non-dividing axes fall back to replication (fit rule).
    """
    caches = abstract_serve_cache(cfg, shape)
    dp = shd.dp_axes(mesh)

    def leaf(path, x):
        ps = shd._path_str(path)
        stacked = ("stack" in ps)
        dims = list(x.shape)
        spec: list = []
        if stacked:
            spec.append(None)
            dims = dims[1:]
        if not dims:
            return NamedSharding(mesh, P())
        if "conv" in ps:
            cand = [dp, None, shd.TP][: len(dims)]
        elif "state" in ps:
            cand = [dp, shd.TP, None, None][: len(dims)]
        else:  # k/v/c_kv/k_rope: (B, S, ...) -> batch dp, seq model
            cand = ([dp, shd.TP] + [None] * (len(dims) - 2))[: len(dims)]
        fitted = [c if (c and d % shd._axis_size(mesh, c) == 0) else None
                  for d, c in zip(dims, cand)]
        return NamedSharding(mesh, P(*(([None] if stacked else [])
                                       + fitted)))

    return jax.tree_util.tree_map_with_path(leaf, caches)


def serve_token_spec(cfg: ModelConfig, shape: ShapeSpec):
    return _sds((shape.global_batch, 1), I32)

"""Trip-count-aware cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 30 layer-repeats reports 1/30 of the real dot FLOPs
(verified in ``tests/test_roofline.py``).  Our models deliberately scan
over layer repeats and microbatches (small HLO, fast compiles), so the
dry-run needs its own analyzer:

* parse the module into computations + instructions,
* recursively evaluate cost over the call graph (fusion ``calls=``,
  ``while`` body/condition, conditional branches),
* multiply ``while`` bodies by the trip count recovered from the loop
  condition (scan lowers to ``compare(induction, constant), LT`` with
  a 0-start, 1-step counter),
* FLOPs from ``dot`` result/contraction shapes; HBM bytes from
  top-level operand+result sizes (fusions are the HBM-traffic units;
  instructions *inside* a fusion body touch registers/VMEM, not HBM);
  collective bytes from the operand shapes of every collective,
  bucketed by kind.

Validated against XLA's own numbers on unrolled graphs (where XLA is
correct) in ``tests/test_roofline.py``.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Dict, List, Optional, Tuple

from .. import compat


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """XLA's own per-device cost table for a compiled artifact.

    Normalized through ``compat.cost_analysis`` (JAX 0.4.x returns a
    one-element list, newer JAX a dict) so callers never branch on the
    JAX version.  Kept here, next to the trip-count-aware analyzer it
    cross-checks.
    """
    return compat.cost_analysis(compiled)

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
               "ragged-all-to-all", "all-to-all", "collective-permute",
               "collective-broadcast")


def op_kind(op: str) -> str:
    """Normalize an HLO opcode to its collective kind.

    Async collectives lower to ``<kind>-start`` / ``<kind>-done``
    pairs; both map onto the base kind so callers can classify an op
    exactly once instead of re-deriving the suffix logic (the source
    of the double-count this module used to have).  Non-collective
    ops are returned unchanged.
    """
    for kind in COLLECTIVES:
        if op == kind or (op.startswith(kind)
                          and op[len(kind):] in ("-start", "-done")):
            return kind
    return op

# one scalar/array shape like  bf16[8,128]{1,0:T(8,128)}  or  f32[]
_SHAPE_RE = re.compile(
    r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        bs = _DTYPE_BYTES.get(self.dtype)
        if bs is None:
            if self.dtype not in _WARNED_DTYPES:
                _WARNED_DTYPES.add(self.dtype)
                warnings.warn(
                    f"hlo_cost: unknown HLO dtype {self.dtype!r}; "
                    "treating as 0 bytes — add it to _DTYPE_BYTES "
                    "so roofline terms stay exact", stacklevel=2)
            return 0
        return self.elems * bs


# dtypes already warned about (once per process, not once per shape)
_WARNED_DTYPES: set = set()


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Shape]          # >1 for tuple results
    op: str
    operands: List[str]
    attrs: str                   # raw trailing text
    operand_txt: str = ""        # raw text inside the op's parens

    @property
    def bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def const_val(self) -> Optional[int]:
        if self.op != "constant":
            return None
        m = re.match(r"\s*(-?\d+)\s*$", self.operand_txt)
        return int(m.group(1)) if m else None


def _parse_shapes(text: str) -> List[Shape]:
    return [Shape(dt, tuple(int(x) for x in dims.split(",") if x))
            for dt, dims in _SHAPE_RE.findall(text)]


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMMENT_RE = re.compile(r"/\*[^*]*\*/")


def _split_instr(rest: str):
    """rest after '<name> = ' -> (shape_txt, op, operand_txt, attrs)."""
    rest = rest.lstrip()
    if rest.startswith("("):          # tuple-shaped result
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape_txt, rest = rest[:i + 1], rest[i + 1:]
    else:                              # single shape token
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_txt, rest = rest[:sp], rest[sp:]
    m = re.match(r"\s*([\w\-]+)\((.*)$", rest)
    if not m:
        return None
    op, rest = m.groups()
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    return shape_txt, op, rest[:i], rest[i + 1:]


def parse_module(text: str) -> Dict[str, List[Instr]]:
    """{computation name: [Instr]}; entry computation under 'ENTRY'."""
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        s = _COMMENT_RE.sub("", line.rstrip())
        if not s:
            continue
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", s)
        if m and not s.lstrip().startswith("ROOT") and "= " not in s:
            cur = "ENTRY" if m.group(1) else m.group(2)
            comps[cur] = []
            continue
        if cur is None:
            continue
        mn = _NAME_RE.match(s)
        if not mn:
            continue
        name, rest = mn.groups()
        parts = _split_instr(rest)
        if parts is None:
            continue
        shape_txt, op, operand_txt, attrs = parts
        operands = re.findall(r"%([\w.\-]+)", operand_txt)
        comps[cur].append(Instr(name, _parse_shapes(shape_txt), op,
                                operands, attrs, operand_txt))
    return comps


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(cond: List[Instr]) -> Optional[int]:
    """Recover the scan trip count from the loop condition.

    ``lax.scan`` lowers to a 0-start, +1-step counter compared (LT)
    against a scalar integer constant that lives in the condition
    computation (possibly behind a kLoop compare fusion).  We take the
    largest plausible scalar int constant in the condition as the trip
    count — exact for scan/fori loops, and recorded as 1 when no such
    constant exists (dynamic-bound loops).
    """
    best = None
    for i in cond:
        v = i.const_val
        if v is not None and i.shapes and not i.shapes[0].dims \
                and i.shapes[0].dtype in ("s32", "u32", "s64", "u64") \
                and 0 < v < 10_000_000:
            best = v if best is None else max(best, v)
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_tpu: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_tpu += mult * other.bytes_tpu
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]


_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "constant",
               "bitcast", "copy-start", "copy-done", "after-all",
               "partition-id", "replica-id", "iota"}

# TPU-fusion byte model: ops that MATERIALIZE HBM traffic on a TPU.
# XLA:TPU fuses elementwise/broadcast/reduce chains into their
# producers, so on real hardware only these round-trip HBM: matmul
# operands/results, data-movement ops (gather/scatter/slice-updates,
# copies), RNG, and decompositions.  Elementwise chains (residual
# adds, norms, optimizer update) ride along with entry
# parameters/outputs, which the dry-run adds separately
# (``memory_analysis().argument/output``).  This is the same
# convention as analytic transformer rooflines; the raw per-op count
# (``bytes``) is kept as the CPU-fusion-granularity upper bound.
# Not included: ``copy`` (dot-operand transposes — TPU dot_general
# contracts arbitrary dims, layout assignment absorbs the rest) and
# ``reduce-window`` (XLA:CPU's blocked lowering of softmax reductions;
# an input-fused reduce on TPU).
_MATERIALIZE = {"dot", "convolution", "gather", "scatter",
                "dynamic-slice", "dynamic-update-slice", "sort",
                "custom-call", "rng-bit-generator", "cholesky",
                "triangular-solve", "fft",
                "select-and-scatter", "pad", "concatenate"}


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        # constants values for trip counts
        self._cache: Dict[Tuple[str, bool], Cost] = {}

    # -- per-instruction helpers ------------------------------------

    def _dot_flops(self, ins: Instr, table: Dict[str, Instr]) -> float:
        out = ins.shapes[0]
        lhs = table.get(ins.operands[0]) if ins.operands else None
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        contract = 1
        if lhs is not None and m and lhs.shapes:
            for d in m.group(1).split(","):
                if d:
                    contract *= lhs.shapes[0].dims[int(d)]
        return 2.0 * out.elems * contract

    def _conv_flops(self, ins: Instr, table: Dict[str, Instr]) -> float:
        out = ins.shapes[0]
        rhs = table.get(ins.operands[1]) if len(ins.operands) > 1 else None
        if rhs is None or not rhs.shapes:
            return 0.0
        # 2 * output elems * (kernel elems / output-feature dim):
        # correct for dense convs, conservative for grouped/depthwise
        k = rhs.shapes[0]
        out_feat = max(k.dims) if k.dims else 1
        return 2.0 * out.elems * max(1, k.elems // out_feat)

    # -- recursive evaluation ----------------------------------------

    def cost_of(self, comp: str, in_fusion: bool = False) -> Cost:
        key = (comp, in_fusion)
        if key in self._cache:
            return self._cache[key]
        total = Cost()
        self._cache[key] = total  # guards recursion
        instrs = self.comps.get(comp, [])
        table = {i.name: i for i in instrs}
        for ins in instrs:
            kind = op_kind(ins.op)
            is_coll = kind in COLLECTIVES
            is_done = is_coll and ins.op.endswith("-done")
            if ins.op == "dot":
                total.flops += self._dot_flops(ins, table)
            elif ins.op == "convolution":
                total.flops += self._conv_flops(ins, table)
            elif is_coll and not is_done:
                # An async pair (-start/-done) is ONE transfer: all
                # accounting happens on the -start op (its tuple result
                # aliases operand+result, so subtract operand bytes to
                # recover the result payload); -done is pure bookkeeping
                # and contributes nothing.
                result_b = ins.bytes
                if ins.op.endswith("-start") and len(ins.shapes) > 1:
                    result_b = max(0, ins.bytes - sum(
                        table[o].bytes for o in ins.operands
                        if o in table))
                # per-chip ICI wire bytes (ring algorithms, (N-1)/N ~ 1):
                #   all-gather        ~ result bytes (receives the world)
                #   all-reduce        ~ 2x payload (reduce + broadcast)
                #   reduce-scatter    ~ operand bytes
                #   all-to-all / cp / broadcast ~ operand bytes
                opb = sum(table[o].bytes for o in ins.operands
                          if o in table)
                if opb == 0:
                    opb = result_b
                if kind == "all-gather":
                    b = max(result_b, opb)
                elif kind == "all-reduce":
                    b = 2 * opb
                else:
                    b = opb
                total.coll[kind] += b
                if not in_fusion:
                    total.bytes += result_b

            if ins.op == "while":
                body = _called(ins.attrs, "body")
                cond = _called(ins.attrs, "condition")
                # XLA prints the derived trip count in backend_config
                mt = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"',
                               ins.attrs)
                trip = int(mt.group(1)) if mt else None
                if trip is None and cond and cond in self.comps:
                    trip = _trip_count(self.comps[cond])
                trip = trip if trip else 1
                if body:
                    total.add(self.cost_of(body, in_fusion), trip)
                if cond:
                    total.add(self.cost_of(cond, in_fusion), trip)
                continue
            if ins.op in ("fusion",):
                callee = _called(ins.attrs, "calls")
                if callee:
                    total.add(self.cost_of(callee, True))
            elif ins.op in ("call", "async-start"):
                callee = _called(ins.attrs, "calls") or \
                    _called(ins.attrs, "to_apply")
                if callee:
                    total.add(self.cost_of(callee, in_fusion))
            elif ins.op == "conditional":
                for key2 in ("true_computation", "false_computation"):
                    callee = _called(ins.attrs, key2)
                    if callee:
                        total.add(self.cost_of(callee, in_fusion))

            # HBM traffic: top-level (non-fusion-body) instructions
            # (collectives — sync, -start AND -done — are fully
            # accounted in the collective branch above)
            if not in_fusion and ins.op not in _SKIP_BYTES \
                    and not is_coll:
                b = ins.bytes
                for o in ins.operands:
                    if o in table and table[o].op not in (
                            "tuple", "constant"):
                        b += table[o].bytes
                total.bytes += b
            # TPU-fusion model: materialization points only, counted
            # whether or not CPU-XLA happened to fuse them
            if ins.op in _MATERIALIZE and not is_coll:
                if ins.op in ("dynamic-slice", "gather"):
                    # reads only the sliced/gathered elements
                    b = 2 * ins.bytes
                elif ins.op == "dynamic-update-slice":
                    # in-place: read the update operand, write the slice
                    upd = (table.get(ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    b = 2 * (upd.bytes if upd is not None else ins.bytes)
                elif ins.op == "scatter":
                    upd = (table.get(ins.operands[2])
                           if len(ins.operands) > 2 else None)
                    b = 2 * (upd.bytes if upd is not None else ins.bytes)
                else:
                    b = ins.bytes
                    for o in ins.operands:
                        if o in table and table[o].op not in (
                                "tuple", "constant"):
                            b += table[o].bytes
                total.bytes_tpu += b
            elif is_coll and not is_done:
                b = ins.bytes
                if ins.op.endswith("-start") and len(ins.shapes) > 1:
                    b = max(0, ins.bytes - sum(
                        table[o].bytes for o in ins.operands
                        if o in table))
                total.bytes_tpu += b
        return total

    def entry_cost(self) -> Cost:
        entry = "ENTRY" if "ENTRY" in self.comps else \
            next(iter(self.comps))
        return self.cost_of(entry)


def analyze(text: str) -> Dict[str, float]:
    """Trip-count-aware {flops, bytes, collective bytes by kind}.

    ``bytes_accessed``     — every top-level op (CPU-fusion upper bound)
    ``bytes_materialized`` — TPU-fusion model (see _MATERIALIZE); add
                             entry argument/output bytes for the total.
    """
    c = HloCost(text).entry_cost()
    coll = dict(c.coll)
    coll["total"] = sum(coll.values())
    return {"flops": c.flops, "bytes_accessed": c.bytes,
            "bytes_materialized": c.bytes_tpu,
            "collective_bytes": coll}

import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import (jax locks device count on first init).

"""Multi-pod dry-run of the PAPER'S OWN WORKLOAD: the distributed
Bayesian-MF Gibbs sweep at compound-activity production scale.

The LM-architecture dry-run (dryrun.py) covers the assigned pool; this
module proves the SMURFF core itself distributes: ChEMBL-scale cells
(paper §4 Macau: >1M compounds x thousands of proteins, ECFP side
info) lowered + compiled on the 16x16 single-pod and 2x16x16 multi-pod
meshes, with the same roofline extraction.

Cells:
    bmf_chembl     1,048,576 x 8,192, K=128, ~67M observed entries
    macau_chembl   + 2048-bit ECFP side info on the compound axis
                   (the side-Gramian FtF is hoisted to placement time —
                   no per-sweep (D, D) psum)
    probit_chembl  binary activity classification (paper §4): same
                   shape, ProbitNoise with counter-based truncated-
                   normal augmentation — runs the explicit sharded
                   sweep, not the pjit fallback
    dense_views    131,072 x 4,096 fully-observed dense block
                   ("dense-dense" row of Table 1) through the sharded
                   dense path (row-sharded orientations, one shared
                   (K, K) Gram per half-sweep)
    gfa_views      GFA multi-view workload (Table 1 "Normal + SnS"):
                   131,072 samples x 3 views (8192/4096/2048 features),
                   FixedNormal on the shared Z, spike-and-slab on every
                   loading matrix — the counter-based coordinate update
                   runs the explicit sharded sweep (one all-gather per
                   half-sweep, two K-sized hyper psums per view, zero
                   per-component collectives), not a pjit fallback

Variants:
    baseline      row-sharded factors, f32 fixed-factor all-gather
                  (the GASPI communication pattern, Vander Aa 2017)
    bf16gather    fixed factor cast to bf16 *before* the all-gather
                  (halves the dominant collective payload on targets
                  with native bf16 collectives, i.e. TPU; XLA:CPU —
                  this container — normalizes the collective back to
                  convert-gather-convert, so the recorded
                  collective_bytes do NOT drop here.  The bf16
                  exchange is pinned on the lowered StableHLO in
                  tests/test_distributed.py instead.)
    ring          the fixed-factor exchange travels as n_shards - 1
                  double-buffered ``ppermute`` hops instead of one
                  blocking all-gather (``pipeline="ring"`` on
                  ``make_distributed_step``) — same wire bytes, zero
                  all-gathers, and each hop is issued before the
                  previous chunk is consumed so the exchange hides
                  behind local work.
    chains4       four independent Gibbs chains through
                  ``make_multi_chain_step`` on a ("chain", 4) x
                  ("data", S/4) mesh — chains x shards fills the pod,
                  each 64-shard group sweeps ONE local chain, so the
                  per-group collective census equals the single-chain
                  census at 64 shards (``contract_for(..., chains=4,
                  chain_axis_size=4)``) while useful FLOPs scale by 4.
                  This is the convergence-diagnostics posture: R-hat /
                  ESS need >= 2 chains (``core.diagnostics``).

Exchange model (per-sweep per-device seconds, in every record):
    exchange_s_serial   collective_bytes / ICI_BW — the wire time,
                        which the eager pipeline fully EXPOSES (the
                        blocking all-gather precedes every row solve
                        of its half-sweep)
    exchange_s_modeled  the exposed exchange time after overlap:
                        equal to exchange_s_serial for eager;
                        max(serial - max(compute_s, memory_s), 0) for
                        ring, whose hops overlap the chunk-accumulated
                        Gram/RHS math and local solves.  Eager stays
                        the session default until this term wins on
                        the deploy target.

Usage:
    PYTHONPATH=src python -m repro.launch.mf_dryrun [--cell bmf_chembl]
        [--mesh single|multi|both]
        [--variant baseline|bf16gather|ring]
"""
import argparse
import dataclasses
import json
from ..obs import clock
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class MFCell:
    name: str
    n_rows: int
    n_cols: int
    K: int
    row_nnz: int          # padded nonzeros per row
    col_nnz: int          # padded nonzeros per column
    nnz_pad: int          # flat COO padding
    side_feats: int = 0   # Macau fingerprints on the row axis
    probit: bool = False  # binary data, ProbitNoise augmentation
    dense: bool = False   # fully-observed DenseBlock payload
    gfa_dims: tuple = ()  # GFA view widths (SnS loadings per view)


CELLS = {
    "bmf_chembl": MFCell("bmf_chembl", 1 << 20, 8192, 128, 64, 8192,
                         1 << 26),
    "macau_chembl": MFCell("macau_chembl", 1 << 20, 8192, 128, 64, 8192,
                           1 << 26, side_feats=2048),
    "probit_chembl": MFCell("probit_chembl", 1 << 20, 8192, 128, 64,
                            8192, 1 << 26, probit=True),
    "dense_views": MFCell("dense_views", 1 << 17, 4096, 128, 0, 0, 0,
                          dense=True),
    # GFA latent dim is small in practice (Table 1 runs K ~ 10-30);
    # K=32 also bounds the unrolled per-component coordinate loop
    "gfa_views": MFCell("gfa_views", 1 << 17, 8192, 32, 0, 0, 0,
                        dense=True, gfa_dims=(8192, 4096, 2048)),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_data(cell: MFCell):
    """MFData of ShapeDtypeStructs at full production size."""
    from ..core.blocks import DenseBlock
    from ..core.sparse import PaddedRows, SparseMatrix
    from ..core.gibbs import MFData

    if cell.gfa_dims:
        N = cell.n_rows
        blks = tuple(
            DenseBlock(_sds((N, D), F32), _sds((N, D), F32),
                       _sds((D, N), F32), _sds((D, N), F32), fully=True)
            for D in cell.gfa_dims)
        return MFData(blks, (None,) * (1 + len(cell.gfa_dims)))

    if cell.dense:
        R, C = cell.n_rows, cell.n_cols
        blk = DenseBlock(_sds((R, C), F32), _sds((R, C), F32),
                         _sds((C, R), F32), _sds((C, R), F32),
                         fully=True)
        return MFData((blk,), (None, None))

    rows = PaddedRows(_sds((cell.n_rows, cell.row_nnz), I32),
                      _sds((cell.n_rows, cell.row_nnz), F32),
                      _sds((cell.n_rows, cell.row_nnz), F32),
                      n_other=cell.n_cols)
    cols = PaddedRows(_sds((cell.n_cols, cell.col_nnz), I32),
                      _sds((cell.n_cols, cell.col_nnz), F32),
                      _sds((cell.n_cols, cell.col_nnz), F32),
                      n_other=cell.n_rows)
    E = cell.nnz_pad
    mat = SparseMatrix(rows, cols, _sds((E,), I32), _sds((E,), I32),
                       _sds((E,), F32), _sds((E,), F32),
                       _sds((E,), I32), _sds((E,), I32),
                       shape=(cell.n_rows, cell.n_cols))
    side = _sds((cell.n_rows, cell.side_feats), F32) \
        if cell.side_feats else None
    return MFData((mat,), (side, None))


def build_model(cell: MFCell, variant: str):
    from ..core.blocks import BlockDef, EntityDef, ModelDef
    from ..core.noise import AdaptiveGaussian, ProbitNoise
    from ..core.priors import (FixedNormalPrior, MacauPrior, NormalPrior,
                               SpikeAndSlabPrior)
    if cell.gfa_dims:
        ents = [EntityDef("samples", cell.n_rows,
                          FixedNormalPrior(cell.K))]
        blocks = []
        for m, D in enumerate(cell.gfa_dims):
            ents.append(EntityDef(f"view{m}", D,
                                  SpikeAndSlabPrior(cell.K)))
            blocks.append(BlockDef(0, m + 1, AdaptiveGaussian(),
                                   sparse=False))
        return ModelDef(tuple(ents), tuple(blocks), cell.K,
                        use_pallas=False,
                        bf16_gather=("bf16gather" in variant))
    rp = MacauPrior(cell.K, cell.side_feats) if cell.side_feats \
        else NormalPrior(cell.K)
    noise = ProbitNoise() if cell.probit else AdaptiveGaussian()
    return ModelDef(
        (EntityDef("compounds", cell.n_rows, rp),
         EntityDef("proteins", cell.n_cols, NormalPrior(cell.K))),
        (BlockDef(0, 1, noise, sparse=not cell.dense),),
        cell.K, use_pallas=False,
        bf16_gather=("bf16gather" in variant))


def mf_model_flops(cell: MFCell, n_chips: int) -> float:
    """Useful FLOPs per device per sweep (both half-sweeps).

    Gram 2*K^2 + rhs 2*K per nonzero per orientation, Cholesky K^3/3
    + two triangular solves 2*K^2 per row, one SDDMM 2*K per entry.
    Fully-observed dense blocks instead share one (K, K) Gram per
    half-sweep and regress every cell: rhs 2*K per cell per
    orientation + residual 2*K per cell.
    """
    K = cell.K
    if cell.gfa_dims:
        # Z update: per-view shared Gram + RHS, one Cholesky per row;
        # SnS loadings: the coordinate loop touches every cell ~8x
        # per component (pred downdate, l, pred restore; the q term is
        # one shared scalar on fully-observed views), all row-local;
        # metrics one residual pass
        N = cell.n_rows
        tot = N * (K ** 3 / 3 + 2 * K * K)
        for D in cell.gfa_dims:
            cells_ = N * D
            tot += 2 * D * K * K + 2 * cells_ * K
            tot += 8 * K * cells_
            tot += 2 * cells_ * K
        return tot / n_chips
    if cell.dense:
        cells_ = cell.n_rows * cell.n_cols
        gram = (2 * (cell.n_rows + cell.n_cols) * K * K
                + 4 * cells_ * K)
        chol = (cell.n_rows + cell.n_cols) * (K ** 3 / 3 + 2 * K * K)
        return (gram + 2 * cells_ * K + chol) / n_chips
    nnz = cell.nnz_pad                      # padded upper bound
    gram = 2 * nnz * (2 * K * K + 2 * K)
    chol = (cell.n_rows + cell.n_cols) * (K ** 3 / 3 + 2 * K * K)
    sddmm = 2 * nnz * K
    beta = 0.0
    if cell.side_feats:
        D = cell.side_feats
        beta = 2 * cell.n_rows * D * K + D ** 3 / 3
    return (gram + chol + sddmm + beta) / n_chips


def lower_cell(cell: MFCell, mesh, variant: str):
    from ..analysis.contract import check_compiled, contract_for
    from ..core.distributed import (distributed_supported,
                                    make_distributed_step,
                                    make_multi_chain_step)
    from ..core.gibbs import init_chain_states, init_state, stack_states
    from .hlo_cost import analyze as hlo_analyze
    from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    model = build_model(cell, variant)
    data = abstract_data(cell)
    pipeline = "ring" if "ring" in variant else "eager"
    chains = 4 if "chains4" in variant else 1
    chain_axis = "chain" if chains > 1 else None

    t0 = clock.perf_counter()
    # explicit shard_map sweep (one fixed-factor exchange per
    # half-sweep + K/K^2 moment psums); production cells are always in
    # the sharded subset — assert rather than silently fall back to the
    # auto-partitioned path whose collectives we are here to measure.
    assert distributed_supported(model, mesh, data), cell.name
    if chains > 1:
        state = jax.eval_shape(lambda: stack_states(
            init_chain_states(model, data, 0, chains)))
        step, ds, ss = make_multi_chain_step(
            model, mesh, data, state, pipeline=pipeline,
            chains=chains, chain_axis=chain_axis)
    else:
        state = jax.eval_shape(lambda: init_state(model, data, 0))
        step, ds, ss = make_distributed_step(model, mesh, data, state,
                                             pipeline=pipeline)
    lowered = step.lower(data, state)
    t_lower = clock.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = clock.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    ctxt = compiled.as_text()
    hc = hlo_analyze(ctxt)
    # the derived communication contract, verified against the very
    # HLO whose roofline we are recording (trip-count-aware, so the
    # scan-rolled ring at 256 shards counts its E*(S-1) hops)
    cas = int(mesh.shape[chain_axis]) if chain_axis else None
    contract = contract_for(model, tuple(mesh.devices.shape), pipeline,
                            chains=chains, chain_axis_size=cas)
    violations = check_compiled(contract, ctxt)
    n_chips = mesh.devices.size
    bytes_hbm = (hc["bytes_materialized"]
                 + int(mem.argument_size_in_bytes)
                 + int(mem.output_size_in_bytes))
    comp = hc["flops"] / PEAK_FLOPS
    memt = bytes_hbm / HBM_BW
    coll = hc["collective_bytes"]["total"] / ICI_BW
    # overlap-aware exchange term: the eager all-gather blocks the
    # half-sweep it feeds (fully exposed wire time); the ring's
    # ppermute hops are double-buffered against the chunk-accumulated
    # moment math and local solves, exposing only what the local work
    # cannot cover (see module docstring)
    exchange = coll if pipeline == "eager" \
        else max(coll - max(comp, memt), 0.0)
    # C chains sweep C posteriors — per-device useful FLOPs scale by C
    # (each of the S/axis_size-shard groups sweeps its local chains)
    mf = mf_model_flops(cell, n_chips) * chains
    bound = max(comp, memt, coll)
    rec = {
        "arch": f"mf_{cell.name}", "shape": "gibbs_sweep",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "kind": "mf", "variant": variant, "pipeline": pipeline,
        "n_chips": int(n_chips),
        "flops": hc["flops"],
        "bytes_accessed": hc["bytes_accessed"],
        "bytes_hbm": bytes_hbm,
        "collective_bytes": hc["collective_bytes"],
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     + mem.output_size_in_bytes),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "exchange_s_serial": coll, "exchange_s_modeled": exchange,
        "dominant": max(("compute", comp), ("memory", memt),
                        ("collective", coll), key=lambda kv: kv[1])[0],
        "model_flops": mf,
        "useful_flop_ratio": mf / hc["flops"] if hc["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "contract": contract.asdict(),
        "contract_ok": not violations,
    }
    if chains > 1:
        rec["chains"] = chains
        rec["chain_axis_size"] = cas
    if violations:
        rec["contract_violations"] = violations
    # audited per-kernel VMEM estimates (PR 8): the same report the
    # `python -m repro.analysis` dry-run audit re-derives and compares
    from ..analysis.kernelcheck import vmem_report
    kv = vmem_report()
    rec["kernel_vmem"] = kv
    rec["kernel_vmem_ok"] = all(v["ok"] for v in kv.values())
    return rec


def run_cell(cell_name: str, mesh_kind: str, variant: str,
             save: bool = True):
    from .mesh import make_mesh, make_production_mesh
    if "chains4" in variant:
        # chains x shards fills the same chip count: a ("chain", 4)
        # axis carved out of the pod, rows sharded over the rest
        n = 512 if mesh_kind == "multi" else 256
        mesh = make_mesh((4, n // 4), ("chain", "data"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cell = CELLS[cell_name]
    try:
        rec = lower_cell(cell, mesh, variant)
    except Exception as e:  # noqa: BLE001
        rec = {"arch": f"mf_{cell_name}", "shape": "gibbs_sweep",
               "mesh": mesh_kind, "variant": variant,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        tag = "" if variant == "baseline" else f".{variant}"
        out = RESULTS / f"mf_{cell_name}.gibbs_sweep.{mesh_kind}{tag}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    # composable tags: build_model keys on the "bf16gather" substring,
    # lower_cell on "ring" — fail fast on anything else (a typo must
    # not lower 256 chips and write a baseline JSON under a bogus tag)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "bf16gather", "ring",
                             "bf16gather_ring", "chains4",
                             "chains4_ring"])
    args = ap.parse_args()
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    fail = 0
    for c in cells:
        for mk in meshes:
            rec = run_cell(c, mk, args.variant)
            if "error" in rec:
                fail += 1
                print(f"{c:16s} {mk:6s} FAIL {rec['error'][:100]}")
            else:
                if not rec["contract_ok"]:
                    fail += 1
                ct = "ok" if rec["contract_ok"] else "CONTRACT-VIOLATED"
                print(f"{c:16s} {mk:6s} {ct} comp {rec['compute_s']:.2e} "
                      f"mem {rec['memory_s']:.2e} "
                      f"coll {rec['collective_s']:.2e} "
                      f"xchg {rec['exchange_s_modeled']:.2e} "
                      f"dom={rec['dominant']} rf={rec['roofline_fraction']:.4f}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import (jax locks device count on first init).

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, on the single-pod 16x16
mesh and the two-pod 2x16x16 mesh:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...)
                  .lower(**input_specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / HLO collective-bytes

No arrays are ever allocated at the full sizes — inputs are
``ShapeDtypeStruct``s and the 512 "devices" are XLA host-platform
placeholders.  Results land in ``results/dryrun/<cell>.json``; the
roofline table (EXPERIMENTS.md section Roofline) is generated from
those files by ``launch/roofline.py``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_135m
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
    PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape train_4k \
        --mesh multi  [--variant optimized]
"""
import argparse
import json
from ..obs import clock
import traceback
from pathlib import Path

import jax

from .. import compat
from ..configs import ARCHS, SHAPES, applicable, get_config, shape_by_name
from ..optim import AdamWConfig
from .hlo_cost import analyze as hlo_analyze, xla_cost_analysis
from .mesh import make_production_mesh
from .roofline import roofline_terms
from . import specs as S

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _get_cfg(arch: str, shape, variant: str):
    """Arch config for one cell (long-context flavor where supported)."""
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{arch.replace('-', '_')}")
    kwargs = {}
    if shape.name == "long_500k" and "long_context" in \
            mod.config.__code__.co_varnames:
        kwargs["long_context"] = True
    return mod.config(**kwargs).validate()


def lower_cell(arch: str, shape_name: str, mesh, *, n_micro: int = 1,
               variant: str = "baseline"):
    """Lower+compile one (arch, shape) cell on ``mesh``. Returns record."""
    from .serve import make_sharded_prefill_step, make_sharded_serve_step
    from .train import make_sharded_train_step
    shape = shape_by_name(shape_name)
    cfg = _get_cfg(arch, shape, variant)
    skip = applicable(cfg, shape)
    if skip is not None:
        return {"arch": arch, "shape": shape_name, "skipped": skip}

    t0 = clock.perf_counter()
    with compat.use_mesh(mesh):
        if shape.kind == "train":
            nm = max(n_micro, _default_micro(arch))
            step, (ps, os_, bsh) = make_sharded_train_step(
                cfg, AdamWConfig(), mesh, shape, n_micro=nm,
                variant=variant)
            params_abs, opt_abs = S.abstract_train_state(cfg)
            lowered = step.lower(params_abs, opt_abs,
                                 S.batch_specs(cfg, shape))
        elif shape.kind == "prefill":
            step, (ps, bsh) = make_sharded_prefill_step(
                cfg, mesh, shape, variant=variant)
            params_abs, _ = S.abstract_train_state(cfg)
            lowered = step.lower(params_abs, S.batch_specs(cfg, shape))
        else:  # decode
            step, (ps, cs, tok) = make_sharded_serve_step(
                cfg, mesh, shape, variant=variant)
            params_abs, _ = S.abstract_train_state(cfg)
            caches_abs = S.abstract_serve_cache(cfg, shape)
            lowered = step.lower(params_abs, caches_abs,
                                 S.serve_token_spec(cfg, shape))
        t_lower = clock.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = clock.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)      # list-vs-dict normalized
    # trip-count-aware analysis (XLA's cost_analysis counts while/scan
    # bodies once — see hlo_cost.py); XLA numbers kept for cross-check
    hc = hlo_analyze(compiled.as_text())
    n_chips = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "kind": shape.kind,
        "variant": variant,
        "n_chips": int(n_chips),
        "flops": hc["flops"],
        "bytes_accessed": hc["bytes_accessed"],
        "bytes_hbm": (hc["bytes_materialized"]
                      + int(mem.argument_size_in_bytes)
                      + int(mem.output_size_in_bytes)),
        "collective_bytes": hc["collective_bytes"],
        "xla_flops_noscan": float(cost.get("flops", 0.0)),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     + mem.output_size_in_bytes),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    rec.update(roofline_terms(rec, cfg, shape))
    return rec


def _default_micro(arch: str) -> int:
    """Microbatch counts so train_4k activations fit 16 GB HBM."""
    return {
        "jamba_v01_52b": 16, "grok_1_314b": 32, "qwen25_32b": 16,
        "deepseek_v2_lite_16b": 8, "yi_6b": 8, "qwen3_4b": 8,
        "internvl2_2b": 4, "whisper_medium": 4,
    }.get(arch, 2)


# per-arch best-known perf flags (EXPERIMENTS.md §Perf); selected with
# ``--variant best``.  Preconditions (batch divisibility, expert
# divisibility) are enforced downstream by effective_variant/spec_for.
BEST_VARIANT = {
    "smollm_135m": "dponly,flashvjp",
    "mamba2_130m": "dponly",
    "whisper_medium": "dponly,flashvjp",
    "deepseek_v2_lite_16b": "ep,micro2",
    "internvl2_2b": "dponly,flashvjp",
    "qwen3_4b": "flashvjp",
    "yi_6b": "flashvjp",
    "qwen25_32b": "flashvjp",
    "jamba_v01_52b": "flashvjp",
    "grok_1_314b": "flashvjp",
}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline", save: bool = True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        rec = lower_cell(arch, shape_name, mesh, variant=variant)
    except Exception as e:  # noqa: BLE001 — record the failure
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "variant": variant, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        tag = "" if variant == "baseline" else f".{variant}"
        out = RESULTS / f"{arch}.{shape_name}.{mesh_kind}{tag}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS + ["all"], default="all")
    ap.add_argument("--shape", default="all",
                    choices=[s.name for s in SHAPES] + ["all"])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                variant = BEST_VARIANT.get(arch, "baseline") \
                    if args.variant == "best" else args.variant
                rec = run_cell(arch, shape, mk, variant=variant)
                if "error" in rec:
                    n_fail += 1
                    status = "FAIL " + rec["error"][:90]
                elif "skipped" in rec:
                    n_skip += 1
                    status = "skip: " + rec["skipped"][:60]
                else:
                    n_ok += 1
                    status = (f"ok   {rec['flops']:.2e} fl "
                              f"{rec['peak_bytes_per_device']/2**30:.2f} "
                              f"GiB/dev  comp {rec['compile_s']}s "
                              f"dom={rec['dominant']}")
                print(f"{arch:22s} {shape:12s} {mk:6s} {status}",
                      flush=True)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

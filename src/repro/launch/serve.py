"""Serving: shared slot/queue runtime + the two services built on it.

Two very different workloads share one continuous-batching shape —
requests queue, free slots admit them, one device call advances every
active slot at once:

* :class:`BatchedServer` — LM token decoding over fixed KV-cache
  slots (the transformer substrate path).
* :class:`RecommendServer` — batched posterior top-K recommendation
  over a saved BMF sample store (the arXiv:1904.02514 serving story):
  each service step scores all admitted requests in ONE fused
  ``kernels.topk_score`` call against the resident posterior cache,
  serving warm users, cold-start feature rows (sampled Macau link),
  and per-request item exclusions.  Batching changes no answer —
  batched results are BITWISE equal to sequential
  ``PredictSession.recommend`` calls (tests/test_serving.py).

The slot/queue/request-id mechanics live in :class:`SlotServer` so the
two servers can't drift: ids come from a monotonic counter (the old
``f"r{len(self.queue)}"`` default collided once the queue drained),
and explicit duplicate ids raise, naming the clash.

Checkpoint I/O is banned from request paths by construction: the
store is loaded ONCE at server construction (``warm_cache``), and the
``checkpoint-load-in-serving-request-path`` invariant rule
(``repro.analysis``) rejects any ``load_pytree``/``load_sample``-class
call that creeps into this module outside ``__init__``/``warm*``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import (forward, init_model, init_serve_cache, serve_step)
from ..models.config import ModelConfig
from ..models.transformer import encode
from ..obs import Recorder, clock, integer_buckets
from . import specs as S


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def step(params, caches, tokens):
        return serve_step(params, cfg, caches, tokens, mesh=mesh)
    return step


def make_sharded_serve_step(cfg: ModelConfig, mesh: Mesh, shape,
                            variant: str = "baseline"):
    step = make_serve_step(cfg, mesh)
    params_abs, _ = S.abstract_train_state(cfg)
    from ..models import sharding as shd
    ps = shd.param_shardings(params_abs, mesh)
    cs = S.serve_cache_shardings(cfg, shape, mesh)
    bs = shd.batch_spec(mesh, shape.global_batch)
    dp = bs[0] if len(bs) else None
    tok_sh = NamedSharding(mesh, P(dp, None))
    # logits (B, 1, V): batch over dp, vocab over the model axis
    v_ok = cfg.vocab_size % mesh.shape[shd.TP] == 0
    lg_sh = NamedSharding(mesh, P(dp, None, shd.TP if v_ok else None))
    return jax.jit(step,
                   in_shardings=(ps, cs, tok_sh),
                   out_shardings=(lg_sh, cs),
                   donate_argnums=(1,)), (ps, cs, tok_sh)


def make_sharded_prefill_step(cfg: ModelConfig, mesh: Mesh, shape,
                              variant: str = "baseline"):
    """Forward-only prefill over the full sequence (inference-prefill).

    Lowers ``forward`` (chunked causal attention, no grads); logits are
    returned sharded (batch x vocab) — a real server would fuse the
    sampling, this is the roofline-relevant compute.
    """

    from ..models import sharding as shd
    variant = S.effective_variant(variant, shape, mesh)

    def step(params, batch):
        with shd.policy(variant):   # perf flags live during tracing
            logits, _ = forward(params, cfg, batch, mesh=mesh,
                                remat=False)
            return logits.astype(jnp.bfloat16)

    params_abs, _ = S.abstract_train_state(cfg)
    with shd.policy(variant):
        ps = shd.param_shardings(params_abs, mesh)
        bsh = S.batch_shardings(cfg, shape, mesh, variant=variant)
        bs = shd.batch_spec(mesh, shape.global_batch)
    dp = bs[0] if len(bs) else None
    v_ok = cfg.vocab_size % mesh.shape[shd.TP] == 0
    lg_sh = NamedSharding(mesh, P(dp, None, shd.TP if v_ok else None))
    return jax.jit(step, in_shardings=(ps, bsh),
                   out_shardings=lg_sh), (ps, bsh)


def generate(cfg: ModelConfig, params, prompts: np.ndarray,
             max_new: int = 32, temperature: float = 0.0,
             seed: int = 0) -> np.ndarray:
    """Greedy/temperature decode for a batch of same-length prompts.

    Prefill runs through ``forward`` (chunked attention); decode uses
    the cache path.  Single-host convenience used by examples/tests.
    """
    B, S0 = prompts.shape
    max_len = S0 + max_new
    enc = None
    batch = {"tokens": jnp.asarray(prompts)}
    logits, _ = forward(params, cfg, batch, remat=False)
    caches = init_serve_cache(params, cfg, B, max_len, enc_out=enc,
                              prefilled=0)
    # replay the prompt through the decode path to fill the cache
    # (simple and correct; a production prefill would batch-write)
    step = jax.jit(lambda p, c, t: serve_step(p, cfg, c, t))
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = None
    for i in range(S0):
        tok = jnp.asarray(prompts[:, i:i + 1])
        lg, caches = step(params, caches, tok)
    for i in range(max_new):
        if temperature > 0:
            key, k2 = jax.random.split(key)
            nxt = jax.random.categorical(
                k2, lg[:, -1].astype(jnp.float32) / temperature,
                axis=-1)[:, None]
        else:
            nxt = jnp.argmax(lg[:, -1], axis=-1)[:, None]
        out.append(np.asarray(nxt, np.int32))
        lg, caches = step(params, caches, nxt.astype(jnp.int32))
    return np.concatenate(out, axis=1)


class SlotServer:
    """Shared slot/queue runtime: admission + request-id management.

    Subclasses implement one service ``step()`` that advances every
    active slot.  Request ids default to a MONOTONIC counter — the
    previous ``f"r{len(self.queue)}"`` default reused ids once the
    queue drained, so two live requests could share one.  Explicit ids
    that clash with a queued or active request raise, naming both.
    Every request carries ``t_submit``/``t_admit``/``t_done``
    monotonic timestamps (benchmarks/serve_latency.py derives its
    p50/p99 from them), and the server's ``obs`` Recorder splits
    request latency into the ``serve.queue_wait_s`` and
    ``serve.execute_s`` histograms plus a per-step
    ``serve.batch_occupancy`` histogram — all exposed through
    :meth:`metrics_snapshot`.  The recorder is enabled by default
    (metrics are the serving product, not a debug artifact); inject a
    disabled one via ``recorder=`` to opt out.
    """

    def __init__(self, slots: int, recorder: Optional[Recorder] = None):
        self.slots = slots
        self.obs = Recorder(enabled=True) if recorder is None else recorder
        self.obs.set_kind("serve")
        self.queue: List[Dict[str, Any]] = []
        self.active: List[Optional[Dict[str, Any]]] = [None] * slots
        self.done: List[Dict[str, Any]] = []
        self._next_id = 0                 # never reused, ever
        self._live_ids: set = set()       # queued + active

    def _enqueue(self, req: Dict[str, Any],
                 req_id: Optional[str]) -> str:
        if req_id is None:
            req_id = f"r{self._next_id}"
            self._next_id += 1
        elif req_id in self._live_ids:
            raise ValueError(
                f"request id {req_id!r} clashes with a live "
                "(queued or active) request of the same id; pass a "
                "unique id or omit req_id to get a server-assigned "
                "one")
        req["id"] = req_id
        req["t_submit"] = clock.monotonic()
        self._live_ids.add(req_id)
        self.queue.append(req)
        self.obs.add("serve.submitted")
        return req_id

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                req["t_admit"] = clock.monotonic()
                self.obs.observe("serve.queue_wait_s",
                                 req["t_admit"] - req["t_submit"])
                self.active[s] = req

    def _observe_batch(self, occupancy: int) -> None:
        """Batch-occupancy histogram, one observation per service
        step — integer buckets so every occupancy level 0..slots has
        its own exact count."""
        self.obs.observe("serve.batch_occupancy", occupancy,
                         bounds=integer_buckets(self.slots))

    def _finish(self, slot: int):
        req = self.active[slot]
        req["t_done"] = clock.monotonic()
        self.obs.observe("serve.execute_s",
                         req["t_done"] - req["t_admit"])
        self.obs.add("serve.completed")
        self._live_ids.discard(req["id"])
        self.done.append(req)
        self.active[slot] = None

    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON metrics snapshot of the server's Recorder: submitted/
        completed counters + queue-wait / execute / batch-occupancy
        histograms (the numbers benchmarks/serve_latency.py reports)."""
        return self.obs.metrics()

    def step(self):                       # pragma: no cover
        raise NotImplementedError

    def run(self, max_steps: int = 10_000) -> List[Dict[str, Any]]:
        """Service steps until all requests finish; returns results."""
        for _ in range(max_steps):
            self._admit()
            if not any(self.active):
                break
            self.step()
        return self.done


class BatchedServer(SlotServer):
    """Minimal continuous-batching LM server over fixed decode slots.

    Requests (prompt arrays) queue up; each free slot runs prefill for
    its request via the decode path, then decodes until EOS/max —
    enough to demonstrate the serving runtime around ``serve_step``.
    """

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256,
                 recorder: Optional[Recorder] = None):
        super().__init__(slots, recorder=recorder)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.caches = init_serve_cache(params, cfg, slots, max_len,
                                       prefilled=0)
        self._step = jax.jit(
            lambda p, c, t: serve_step(p, cfg, c, t))

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               req_id: Optional[str] = None) -> str:
        return self._enqueue(
            {"prompt": list(prompt), "remaining": max_new,
             "generated": [], "fed": 0}, req_id)

    def step(self):
        """One decode step advancing every active slot."""
        self._observe_batch(sum(r is not None for r in self.active))
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if req["fed"] < len(req["prompt"]):
                toks[s, 0] = req["prompt"][req["fed"]]
            elif req["generated"]:
                toks[s, 0] = req["generated"][-1]
        lg, self.caches = self._step(self.params, self.caches,
                                     jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(lg[:, -1], axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req["fed"] += 1
            if req["fed"] >= len(req["prompt"]):
                req["generated"].append(int(nxt[s]))
                req["remaining"] -= 1
                if req["remaining"] <= 0:
                    self._finish(s)


class RecommendServer(SlotServer):
    """Batched posterior top-K recommendation over a saved store.

    The online face of ``PredictSession``: requests (a warm user row
    id OR a cold-start feature vector, plus optional per-request item
    exclusions) queue up, and each service step scores ALL admitted
    requests in one fused ``kernels.topk_score`` call against the
    resident posterior cache — top-K item ids with posterior mean and
    std per score.  Each query runs one identical float program
    regardless of batch size, so batching changes no answer: results
    are bitwise equal to sequential ``PredictSession.recommend`` calls
    (asserted in tests/test_serving.py).

    The sample store is loaded exactly once, at construction
    (``warm_cache``); request paths never touch the checkpoint loader
    (enforced by the ``checkpoint-load-in-serving-request-path``
    invariant rule).  Stores above the session's ``cache_bytes``
    budget are refused here — streaming per request is the reload bug
    this server exists to fix, so it is not silently reintroduced.
    """

    def __init__(self, session, slots: int = 8, k: int = 10,
                 block=0, recorder: Optional[Recorder] = None):
        super().__init__(slots, recorder=recorder)
        self.session = session
        self.k = int(k)
        self.block = block
        if session.warm_cache() is None:
            raise ValueError(
                f"store needs {session.store_nbytes()} bytes resident "
                f"but the session budget is {session.cache_bytes}; "
                "RecommendServer requires the resident cache (raise "
                "cache_bytes / REPRO_PREDICT_CACHE_BYTES, or serve "
                "offline via PredictSession.recommend)")

    def submit(self, user: Optional[int] = None, *,
               features: Optional[np.ndarray] = None,
               k: Optional[int] = None,
               exclude: Optional[Sequence[int]] = None,
               req_id: Optional[str] = None) -> str:
        """Queue one recommendation request; returns its id.

        ``user``: a row id seen in training; ``features``: a (D,)
        side-information vector for an UNSEEN user (cold start) —
        exactly one of the two.  ``exclude``: item ids to leave out of
        this request's ranking (e.g. the user's observed items).
        """
        if (user is None) == (features is None):
            raise ValueError(
                "pass exactly one of user= (warm row id) or "
                "features= (cold-start side info)")
        if features is not None:
            features = np.asarray(features, np.float32)
            if features.ndim != 1:
                raise ValueError(
                    f"features must be one (D,) row, got shape "
                    f"{features.shape}; submit one request per user")
        return self._enqueue(
            {"user": None if user is None else int(user),
             "features": features,
             "k": self.k if k is None else int(k),
             "exclude": None if exclude is None else
             list(map(int, exclude))}, req_id)

    def step(self):
        """Score every active request in one batched kernel call."""
        live = [(s, r) for s, r in enumerate(self.active)
                if r is not None]
        self._observe_batch(len(live))
        t_step = self.obs.now()
        rows = []
        for _, req in live:
            if req["user"] is not None:
                rows.append(self.session.user_rows([req["user"]],
                                                   self.block))
            else:
                rows.append(self.session.cold_rows(req["features"],
                                                   self.block))
        batch = jnp.concatenate(rows, axis=0)        # (B, S, K)
        k_max = max(req["k"] for _, req in live)
        excl = [req["exclude"] or [] for _, req in live]
        res = self.session.recommend_rows(batch, k_max, self.block,
                                          exclude=excl)
        # trim each slot to ITS k: the selection loop picks the same
        # first k entries whatever the total K, so a larger shared
        # batch never changes a request's answer
        for b, (s, req) in enumerate(live):
            kk = min(req["k"], res.ids.shape[1])
            req["ids"] = res.ids[b, :kk].copy()
            req["mean"] = res.mean[b, :kk].copy()
            req["std"] = res.std[b, :kk].copy()
            self._finish(s)
        self.obs.complete("serve/step", t_step, cat="serve",
                          batch=len(live))

"""Serving: batched decode step + a small continuous-batching driver."""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import (forward, init_model, init_serve_cache, serve_step)
from ..models.config import ModelConfig
from ..models.transformer import encode
from . import specs as S


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    def step(params, caches, tokens):
        return serve_step(params, cfg, caches, tokens, mesh=mesh)
    return step


def make_sharded_serve_step(cfg: ModelConfig, mesh: Mesh, shape,
                            variant: str = "baseline"):
    step = make_serve_step(cfg, mesh)
    params_abs, _ = S.abstract_train_state(cfg)
    from ..models import sharding as shd
    ps = shd.param_shardings(params_abs, mesh)
    cs = S.serve_cache_shardings(cfg, shape, mesh)
    bs = shd.batch_spec(mesh, shape.global_batch)
    dp = bs[0] if len(bs) else None
    tok_sh = NamedSharding(mesh, P(dp, None))
    # logits (B, 1, V): batch over dp, vocab over the model axis
    v_ok = cfg.vocab_size % mesh.shape[shd.TP] == 0
    lg_sh = NamedSharding(mesh, P(dp, None, shd.TP if v_ok else None))
    return jax.jit(step,
                   in_shardings=(ps, cs, tok_sh),
                   out_shardings=(lg_sh, cs),
                   donate_argnums=(1,)), (ps, cs, tok_sh)


def make_sharded_prefill_step(cfg: ModelConfig, mesh: Mesh, shape,
                              variant: str = "baseline"):
    """Forward-only prefill over the full sequence (inference-prefill).

    Lowers ``forward`` (chunked causal attention, no grads); logits are
    returned sharded (batch x vocab) — a real server would fuse the
    sampling, this is the roofline-relevant compute.
    """

    from ..models import sharding as shd
    variant = S.effective_variant(variant, shape, mesh)

    def step(params, batch):
        with shd.policy(variant):   # perf flags live during tracing
            logits, _ = forward(params, cfg, batch, mesh=mesh,
                                remat=False)
            return logits.astype(jnp.bfloat16)

    params_abs, _ = S.abstract_train_state(cfg)
    with shd.policy(variant):
        ps = shd.param_shardings(params_abs, mesh)
        bsh = S.batch_shardings(cfg, shape, mesh, variant=variant)
        bs = shd.batch_spec(mesh, shape.global_batch)
    dp = bs[0] if len(bs) else None
    v_ok = cfg.vocab_size % mesh.shape[shd.TP] == 0
    lg_sh = NamedSharding(mesh, P(dp, None, shd.TP if v_ok else None))
    return jax.jit(step, in_shardings=(ps, bsh),
                   out_shardings=lg_sh), (ps, bsh)


def generate(cfg: ModelConfig, params, prompts: np.ndarray,
             max_new: int = 32, temperature: float = 0.0,
             seed: int = 0) -> np.ndarray:
    """Greedy/temperature decode for a batch of same-length prompts.

    Prefill runs through ``forward`` (chunked attention); decode uses
    the cache path.  Single-host convenience used by examples/tests.
    """
    B, S0 = prompts.shape
    max_len = S0 + max_new
    enc = None
    batch = {"tokens": jnp.asarray(prompts)}
    logits, _ = forward(params, cfg, batch, remat=False)
    caches = init_serve_cache(params, cfg, B, max_len, enc_out=enc,
                              prefilled=0)
    # replay the prompt through the decode path to fill the cache
    # (simple and correct; a production prefill would batch-write)
    step = jax.jit(lambda p, c, t: serve_step(p, cfg, c, t))
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = None
    for i in range(S0):
        tok = jnp.asarray(prompts[:, i:i + 1])
        lg, caches = step(params, caches, tok)
    for i in range(max_new):
        if temperature > 0:
            key, k2 = jax.random.split(key)
            nxt = jax.random.categorical(
                k2, lg[:, -1].astype(jnp.float32) / temperature,
                axis=-1)[:, None]
        else:
            nxt = jnp.argmax(lg[:, -1], axis=-1)[:, None]
        out.append(np.asarray(nxt, np.int32))
        lg, caches = step(params, caches, nxt.astype(jnp.int32))
    return np.concatenate(out, axis=1)


class BatchedServer:
    """Minimal continuous-batching server over fixed decode slots.

    Requests (prompt arrays) queue up; each free slot runs prefill for
    its request via the decode path, then decodes until EOS/max —
    enough to demonstrate the serving runtime around ``serve_step``.
    """

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.caches = init_serve_cache(params, cfg, slots, max_len,
                                       prefilled=0)
        self._step = jax.jit(
            lambda p, c, t: serve_step(p, cfg, c, t))
        self.queue: List[Dict[str, Any]] = []
        self.active: List[Optional[Dict[str, Any]]] = [None] * slots
        self.done: List[Dict[str, Any]] = []

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               req_id: Optional[str] = None):
        self.queue.append({"id": req_id or f"r{len(self.queue)}",
                           "prompt": list(prompt), "remaining": max_new,
                           "generated": [], "fed": 0})

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                self.active[s] = self.queue.pop(0)

    def run(self, max_steps: int = 10_000) -> List[Dict[str, Any]]:
        """Decode until all requests finish; returns completions."""
        for _ in range(max_steps):
            self._admit()
            if not any(self.active):
                break
            toks = np.zeros((self.slots, 1), np.int32)
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                if req["fed"] < len(req["prompt"]):
                    toks[s, 0] = req["prompt"][req["fed"]]
                elif req["generated"]:
                    toks[s, 0] = req["generated"][-1]
            lg, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(toks))
            nxt = np.asarray(jnp.argmax(lg[:, -1], axis=-1))
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                req["fed"] += 1
                if req["fed"] >= len(req["prompt"]):
                    req["generated"].append(int(nxt[s]))
                    req["remaining"] -= 1
                    if req["remaining"] <= 0:
                        self.done.append(req)
                        self.active[s] = None
        return self.done

"""AdamW + schedules, pure JAX (no optax dependency).

fp32 moments over fp32 master params; global-norm clipping; decoupled
weight decay; cosine schedule with linear warmup.  The optimizer state
shards exactly like the parameters (same pytree structure), which is
what lets the launcher run fully-sharded (ZeRO-3-style) training: the
dry-run memory analysis counts m/v at 4 bytes each sharded over the
whole mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.zeros_like, zeros),
                    step=jnp.asarray(0, jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: OptState) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        du = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * du).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}

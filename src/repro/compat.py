"""Version-portable JAX shims, feature-detected once at import.

JAX moved several sharding APIs between 0.4.x and 0.5+/0.6+:

* ``jax.make_mesh`` grew an ``axis_types=`` kwarg (and
  ``jax.sharding.AxisType`` only exists where it did);
* ``jax.sharding.AbstractMesh`` changed signature from a tuple of
  ``(name, size)`` pairs to ``(axis_sizes, axis_names)``;
* ``jax.sharding.get_abstract_mesh`` was promoted out of
  ``jax._src.mesh`` (where older versions return an *empty* mesh
  instead of ``None``);
* ``shard_map`` moved from ``jax.experimental.shard_map`` (kwarg
  ``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``);
* ``jax.set_mesh`` replaced the legacy ``with mesh:`` context;
* ``compiled.cost_analysis()`` returned a one-element ``list`` of dicts
  on 0.4.x and returns a plain ``dict`` on newer releases.

Every capability is detected by probing the API surface — never by
comparing version strings — so intermediate releases that carry only
some of the changes still resolve correctly.  All modules under
``repro`` go through these wrappers; nothing else may touch the moved
names directly (enforced by the tier-1 suite staying green on both the
pinned and the latest JAX in CI).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

# --------------------------------------------------------------------------
# axis types
# --------------------------------------------------------------------------

try:  # newer JAX: jax.sharding.AxisType.{Auto,Explicit,Manual}
    from jax.sharding import AxisType as _AxisType
    AXIS_TYPE_AUTO: Any = _AxisType.Auto
except ImportError:  # 0.4.x: no axis types — meshes are implicitly Auto
    AXIS_TYPE_AUTO = None


# --------------------------------------------------------------------------
# mesh construction
# --------------------------------------------------------------------------

def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports it."""
    shape = tuple(shape)
    axes = tuple(axes)
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AXIS_TYPE_AUTO is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AXIS_TYPE_AUTO,) * len(axes),
                                 **kwargs)
        except TypeError:  # AxisType exists but make_mesh predates kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def abstract_mesh(shape: Sequence[int],
                  axes: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Device-free mesh for spec/tracing logic, both constructor eras."""
    from jax.sharding import AbstractMesh
    shape = tuple(shape)
    axes = tuple(axes)
    try:  # newer JAX: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(shape, axes)
    except TypeError:  # 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def get_abstract_mesh():
    """The abstract mesh of the current sharding context, or ``None``.

    Normalizes the empty-mesh sentinel older JAX returns outside any
    mesh context to ``None`` so callers only branch one way.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        try:
            from jax._src.mesh import get_abstract_mesh as fn
        except ImportError:
            return None
    try:
        mesh = fn()
    except Exception:  # pragma: no cover — defensive against API drift
        return None
    if mesh is None or not getattr(mesh, "axis_names", None):
        return None
    return mesh


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager activating ``mesh`` for the enclosed region.

    Prefers the forms that are documented context managers
    (``jax.sharding.use_mesh``, then ``jax.set_mesh``); on 0.4.x falls
    back to the legacy ``with mesh:`` global-mesh context.  Returns a
    nullcontext as last resort — our jit paths pass explicit
    NamedShardings and never rely on the ambient mesh alone.
    """
    for fn in (getattr(jax.sharding, "use_mesh", None),
               getattr(jax, "set_mesh", None)):
        if fn is not None:
            ctx = fn(mesh)
            if hasattr(ctx, "__enter__"):
                return ctx
    if hasattr(mesh, "__enter__"):  # legacy global-mesh context
        return mesh
    return contextlib.nullcontext()


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across its import-location / check-kwarg renames."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        try:
            return fn(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=check)
        except TypeError:  # jax.shard_map exists but still says check_rep
            return fn(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


# --------------------------------------------------------------------------
# compiled-artifact analysis
# --------------------------------------------------------------------------

def cost_analysis(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    JAX 0.4.x returns a one-element list of per-program dicts; newer
    JAX returns the dict itself.  Always returns a (possibly empty)
    dict, never a list.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def mesh_axis_sizes(mesh, axes: Optional[Tuple[str, ...]] = None
                    ) -> Tuple[int, ...]:
    """Sizes of ``axes`` (default: all axes) on a Mesh or AbstractMesh."""
    names = tuple(mesh.axis_names) if axes is None else tuple(axes)
    shape = mesh.shape  # dict-like on every supported version
    return tuple(int(shape[a]) for a in names)

from .fault import ElasticMesh, FailureSim, run_with_restarts
from .straggler import StragglerMonitor

__all__ = ["ElasticMesh", "FailureSim", "run_with_restarts",
           "StragglerMonitor"]

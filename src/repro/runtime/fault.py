"""Fault tolerance + elastic scaling for the distributed runtime.

At 1000+ nodes the failure model is: some pod loses a chip every few
hours.  The strategy here (standard for TPU pods, where a failed chip
takes down the whole slice's ICI ring) is **checkpoint-restart with
elastic re-meshing**:

* the training/sampling loop runs inside ``run_with_restarts``: on any
  device failure (simulated offline by ``FailureSim``) the loop
  restores the latest complete checkpoint, rebuilds the mesh over the
  surviving device set, re-shards the state (``jax.device_put`` with
  the new sharding), and continues;
* ``ElasticMesh`` picks the largest (data, model)-factorization that
  fits the surviving chip count, keeping the model axis fixed when
  possible (re-sharding the model axis would reshuffle every weight;
  shrinking the data axis only re-buckets rows/batch);
* because the MF Gibbs sweep uses counter-based per-row RNG and the LM
  data stream is seekable by step, the restarted chain/run is
  *bit-identical* to an uninterrupted one at the same step count —
  this is asserted in tests/test_runtime.py.

The straggler story lives in runtime/straggler.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..checkpoint import CheckpointManager


def best_mesh_shape(n_devices: int, model_parallel: int,
                    multi_pod: bool = False) -> Tuple[int, ...]:
    """Largest usable (pod, data, model) shape for a device count.

    Keeps the model axis at ``model_parallel`` if divisible (weights
    keep their layout); otherwise falls back to the largest power-of-2
    model axis that divides.
    """
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    dp = n_devices // mp
    if multi_pod and dp % 2 == 0:
        return (2, dp // 2, mp)
    return (dp, mp)


@dataclasses.dataclass
class ElasticMesh:
    """Builds/rebuilds a mesh over a (shrinking) device set."""

    model_parallel: int = 1
    multi_pod: bool = False

    def build(self, devices: Optional[Sequence] = None) -> Mesh:
        devices = list(devices if devices is not None else jax.devices())
        shape = best_mesh_shape(len(devices), self.model_parallel,
                                self.multi_pod)
        n_used = int(np.prod(shape))
        devices = devices[:n_used]          # drop stragglers/odd chips
        names = (("pod", "data", "model") if len(shape) == 3
                 else ("data", "model"))
        dev_arr = np.asarray(devices).reshape(shape)
        return Mesh(dev_arr, names)


class FailureSim:
    """Deterministic failure injector for offline testing.

    ``check(step)`` raises ``DeviceLost`` at the configured steps —
    standing in for the XLA "device lost" error a real pod failure
    produces.
    """

    class DeviceLost(RuntimeError):
        pass

    def __init__(self, fail_at: Sequence[int] = (), lose_devices: int = 0):
        self.fail_at = set(fail_at)
        self.lose = lose_devices
        self.failures = 0

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise FailureSim.DeviceLost(
                f"simulated device loss at step {step}")


def run_with_restarts(
        total_steps: int,
        init_fn: Callable[[], Any],
        step_fn: Callable[[Any, int], Any],
        ckpt: CheckpointManager,
        save_every: int = 10,
        failure_sim: Optional[FailureSim] = None,
        max_restarts: int = 10) -> Tuple[Any, dict]:
    """Generic restartable loop (used by MF chains and LM training).

    ``state`` must be a pytree; ``step_fn(state, step) -> state``.
    On failure: restore latest checkpoint and continue.  Returns
    (final_state, stats).
    """
    restarts = 0
    stats = {"restarts": 0, "resumed_from": []}

    state = init_fn()
    restored = ckpt.restore_latest(state)
    step = 0
    if restored is not None:
        step, state = restored
        stats["resumed_from"].append(step)

    while step < total_steps:
        try:
            if failure_sim is not None:
                failure_sim.check(step)
            state = step_fn(state, step)
            step += 1
            if step % save_every == 0 or step == total_steps:
                ckpt.save(step, state)
        except FailureSim.DeviceLost:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise
            ckpt.wait()
            restored = ckpt.restore_latest(init_fn())
            if restored is None:
                step, state = 0, init_fn()
            else:
                step, state = restored
            stats["resumed_from"].append(step)
    ckpt.wait()
    return state, stats

"""Straggler mitigation.

On a synchronous TPU mesh the SPMD program itself cannot run ahead of a
slow chip — mitigation happens at two levels:

1. **By construction**: the MF padded-bucket layout gives every chip an
   identical instruction stream and identical per-row work (no
   data-dependent imbalance, unlike the CPU original's irregular rows).
   The LM side is standard SPMD — equal shards.

2. **Detection + re-mesh**: a persistently slow chip (thermal, failing
   HBM) is detected by per-step timing watermarks; the runtime treats
   it like a failure (drop the chip, rebuild the mesh via ElasticMesh,
   restore).  ``StragglerMonitor`` implements the detection policy:
   flag when a step exceeds ``threshold`` x the rolling median more
   than ``patience`` times in a row.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Optional


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 patience: int = 3):
        self.times: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.patience = patience
        self._slow_streak = 0

    def record(self, step_time_s: float) -> bool:
        """Record one step; True => persistent straggler, re-mesh."""
        median = self.median()
        self.times.append(step_time_s)
        if median is None:
            return False
        if step_time_s > self.threshold * median:
            self._slow_streak += 1
        else:
            self._slow_streak = 0
        return self._slow_streak >= self.patience

    def median(self) -> Optional[float]:
        if len(self.times) < 5:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes in interpret mode) and
the paper-faithful baseline implementation used when
``use_pallas=False`` (the XLA path — analogous to SMURFF's plain
Eigen/MKL GEMM path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray):
    """Masked batched Gram + rhs — the SMURFF per-row hot loop.

    For each row r (paper Algorithm 1 inner loop):
        gram[r] = sum_t mask[r,t] * vg[r,t,:] vg[r,t,:]^T     (K x K)
        rhs[r]  = sum_t mask[r,t] * val[r,t] * vg[r,t,:]      (K,)

    Args:
      vg:   (R, T, K) gathered latent vectors of the *fixed* factor.
      val:  (R, T) observed ratings (0 where padded).
      mask: (R, T) 1.0 for real entries, 0.0 for padding.

    Returns:
      gram (R, K, K) f32, rhs (R, K) f32.
    """
    if vg.dtype == jnp.bfloat16:
        # bf16 gathered operands (ModelDef.bf16_gather): keep every
        # pre-contraction op in bf16 — an f32 upcast here would let
        # XLA's simplifier fold it into the pre-gather cast and move
        # the (all-)gather back to f32 (measured).  The MXU/dot
        # accumulates in f32 via preferred_element_type.
        m = mask.astype(jnp.bfloat16)
        w = (val * mask).astype(jnp.bfloat16)
        gram = jnp.einsum("rtk,rtl->rkl", vg * m[..., None], vg,
                          preferred_element_type=jnp.float32)
        rhs = jnp.einsum("rtk,rt->rk", vg, w,
                         preferred_element_type=jnp.float32)
        return gram, rhs
    vg = vg.astype(jnp.float32)
    w = (val * mask).astype(jnp.float32)
    m = mask.astype(jnp.float32)
    gram = jnp.einsum("rtk,rtl->rkl", vg * m[..., None], vg)
    rhs = jnp.einsum("rtk,rt->rk", vg, w)
    return gram, rhs


def topk_score_ref(us: jnp.ndarray, v: jnp.ndarray,
                   excl: jnp.ndarray, k: int):
    """Posterior scoring + stable top-K — the serving oracle.

    For each user b (scored against every item across every retained
    posterior sample):
        score[s, n] = us[b, s] . v[s, n]
        mean[n]     = 1/S sum_s score[s, n]
        std[n]      = sqrt(max(E[score^2] - mean^2, 0))
    ranked by mean with excluded items at -inf; ties broken by LOWEST
    item id (stable argsort).

    Users are scored through ``lax.map`` — one identical float program
    per user regardless of batch size — so a batched call is bitwise
    equal to B single-user calls.  This is the contract that lets
    ``RecommendServer`` batch concurrent requests without changing any
    individual answer (asserted in tests/test_serving.py); the Pallas
    kernel preserves it by scoring each user in its own grid row.

    Args:
      us:   (B, S, K) user latent rows, one per posterior sample.
      v:    (S, N, K) item factor stack.
      excl: (B, N) 1.0 = excluded from the ranking.
      k:    static top-K (callers clamp to k <= N).

    Returns:
      ids (B, k) i32, mean (B, k) f32, ex2 (B, k) f32.  The std is
      finalized by ``ops.topk_score`` from (mean, ex2) with one shared
      (B, k) float program for both paths (see kernels/topk_score.py
      on why per-path finalization broke bitwise equality).
    """
    S = v.shape[0]
    bf16 = us.dtype == jnp.bfloat16 and v.dtype == jnp.bfloat16
    if not bf16:
        us = us.astype(jnp.float32)
        v = v.astype(jnp.float32)
    inv_s = jnp.float32(1.0) / jnp.float32(S)

    def one_user(args):
        u, ex = args                              # (S, K), (N,)
        # per-sample scores; bf16 operands keep the pre-contraction
        # ops in bf16 (same discipline as gram_ref), f32 accumulation
        scores = jnp.einsum("snk,sk->sn", v, u,
                            preferred_element_type=jnp.float32)
        mean = jnp.sum(scores, axis=0) * inv_s
        ex2 = jnp.sum(scores * scores, axis=0) * inv_s
        rank = jnp.where(ex > 0, -jnp.inf, mean)
        order = jnp.argsort(-rank)[:k].astype(jnp.int32)  # stable
        return order, mean[order], ex2[order]

    return jax.lax.map(one_user, (us, excl))


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0) -> jnp.ndarray:
    """Plain-softmax attention oracle for ``flash_fwd_pallas``.

    Materializes the full (Sq, Sk) score matrix in f32 — exactly what
    the flash kernel exists to avoid — and applies the same
    position-based masking: query position ``q_offset + row``, causal
    ``kpos <= qpos``, optional sliding window ``kpos > qpos - window``.
    GQA (H a multiple of KVH) repeats each kv head over its G query
    heads.  Rows with every key masked out return 0, matching the
    kernel's ``l == 0`` guard.

    Args:
      q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd).

    Returns:
      (B, Sq, H, hd) in q's dtype.
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / jnp.sqrt(
        jnp.float32(hd))
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        ok = kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok[None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


def sddmm_ref(ug: jnp.ndarray, vg: jnp.ndarray) -> jnp.ndarray:
    """Gathered-operand SDDMM: pred[e] = ug[e] . vg[e].

    Args:
      ug: (E, K) U rows gathered at the observed entries.
      vg: (E, K) V rows gathered at the observed entries.

    Returns:
      (E,) f32 predictions.
    """
    if ug.dtype == jnp.bfloat16 and vg.dtype == jnp.bfloat16:
        return jnp.einsum("ek,ek->e", ug, vg,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(
        "ek,ek->e", ug.astype(jnp.float32), vg.astype(jnp.float32))

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes in interpret mode) and
the paper-faithful baseline implementation used when
``use_pallas=False`` (the XLA path — analogous to SMURFF's plain
Eigen/MKL GEMM path).
"""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray):
    """Masked batched Gram + rhs — the SMURFF per-row hot loop.

    For each row r (paper Algorithm 1 inner loop):
        gram[r] = sum_t mask[r,t] * vg[r,t,:] vg[r,t,:]^T     (K x K)
        rhs[r]  = sum_t mask[r,t] * val[r,t] * vg[r,t,:]      (K,)

    Args:
      vg:   (R, T, K) gathered latent vectors of the *fixed* factor.
      val:  (R, T) observed ratings (0 where padded).
      mask: (R, T) 1.0 for real entries, 0.0 for padding.

    Returns:
      gram (R, K, K) f32, rhs (R, K) f32.
    """
    if vg.dtype == jnp.bfloat16:
        # bf16 gathered operands (ModelDef.bf16_gather): keep every
        # pre-contraction op in bf16 — an f32 upcast here would let
        # XLA's simplifier fold it into the pre-gather cast and move
        # the (all-)gather back to f32 (measured).  The MXU/dot
        # accumulates in f32 via preferred_element_type.
        m = mask.astype(jnp.bfloat16)
        w = (val * mask).astype(jnp.bfloat16)
        gram = jnp.einsum("rtk,rtl->rkl", vg * m[..., None], vg,
                          preferred_element_type=jnp.float32)
        rhs = jnp.einsum("rtk,rt->rk", vg, w,
                         preferred_element_type=jnp.float32)
        return gram, rhs
    vg = vg.astype(jnp.float32)
    w = (val * mask).astype(jnp.float32)
    m = mask.astype(jnp.float32)
    gram = jnp.einsum("rtk,rtl->rkl", vg * m[..., None], vg)
    rhs = jnp.einsum("rtk,rt->rk", vg, w)
    return gram, rhs


def sddmm_ref(ug: jnp.ndarray, vg: jnp.ndarray) -> jnp.ndarray:
    """Gathered-operand SDDMM: pred[e] = ug[e] . vg[e].

    Args:
      ug: (E, K) U rows gathered at the observed entries.
      vg: (E, K) V rows gathered at the observed entries.

    Returns:
      (E,) f32 predictions.
    """
    if ug.dtype == jnp.bfloat16 and vg.dtype == jnp.bfloat16:
        return jnp.einsum("ek,ek->e", ug, vg,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(
        "ek,ek->e", ug.astype(jnp.float32), vg.astype(jnp.float32))

"""Fused masked batched Gram Pallas-TPU kernel.

The compute hot-spot of SMURFF (paper section 3) is the per-row Gibbs
update: for every row of the factor being updated, accumulate

    gram[r] = sum_t mask[r,t] * v[r,t,:] v[r,t,:]^T      (K x K)
    rhs[r]  = sum_t mask[r,t] * val[r,t] * v[r,t,:]      (K,)

over that row's nonzeros.  The CPU original does this with an irregular
OpenMP loop + Eigen rank-1 updates.  On TPU we pad rows to a common
``max_nnz`` (see ``core/sparse.py``) and compute *both* reductions in a
single fused pass, tiled so VMEM only ever holds a
``(row_block, nnz_block, K)`` slab of gathered vectors:

  grid = (rows / BR, nnz / BT); the nnz axis is the *minor* (fastest
  varying) grid dimension so the output block for a given row tile stays
  resident in VMEM while we accumulate over nnz tiles (revisiting
  pattern), giving fp32 accumulation without HBM round-trips.

The MXU does the heavy lifting: the (BR, BT, K) x (BR, BT, K) batched
outer-product reduction lowers to a dot_general with K x K output per
row, which is MXU-shaped when K is a multiple of 128.

Contract-checked: the ``@pl.when(t == 0)`` init / ``t != 0``
accumulate discipline below, the block bounds, fp32 accumulation, and
the VMEM budget are statically verified over the ``ops.KERNELS``
probe envelope by ``repro.analysis.kernelcheck`` (CI ``--kernels``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(vg_ref, val_ref, mask_ref, gram_ref, rhs_ref):
    t = pl.program_id(1)

    vg = vg_ref[...].astype(jnp.float32)      # (BR, BT, K)
    m = mask_ref[...].astype(jnp.float32)     # (BR, BT)
    w = val_ref[...].astype(jnp.float32) * m  # (BR, BT)

    vm = vg * m[..., None]
    # batched rank-BT update: (BR, K, BT) @ (BR, BT, K) -> (BR, K, K)
    g = jax.lax.dot_general(
        vm, vg,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    # (BR, K, BT) @ (BR, BT) -> (BR, K)
    b = jnp.einsum("rtk,rt->rk", vg, w, preferred_element_type=jnp.float32)

    @pl.when(t == 0)
    def _init():
        gram_ref[...] = g
        rhs_ref[...] = b

    @pl.when(t != 0)
    def _acc():
        gram_ref[...] += g
        rhs_ref[...] += b


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_nnz", "interpret"))
def gram_pallas(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray,
                *, block_rows: int = 8, block_nnz: int = 128,
                interpret: bool = False):
    """Fused masked Gram: see module docstring.

    vg (R, T, K), val (R, T), mask (R, T)  ->  gram (R, K, K), rhs (R, K).
    R must be divisible by block_rows and T by block_nnz (callers pad;
    padded entries carry mask 0 so they are exact no-ops).
    """
    R, T, K = vg.shape
    br = min(block_rows, R)
    bt = min(block_nnz, T)
    if R % br or T % bt:
        raise ValueError(f"({R},{T}) not divisible by blocks ({br},{bt})")
    grid = (R // br, T // bt)

    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bt, K), lambda r, t: (r, t, 0)),
            pl.BlockSpec((br, bt), lambda r, t: (r, t)),
            pl.BlockSpec((br, bt), lambda r, t: (r, t)),
        ],
        out_specs=[
            pl.BlockSpec((br, K, K), lambda r, t: (r, 0, 0)),
            pl.BlockSpec((br, K), lambda r, t: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, K, K), jnp.float32),
            jax.ShapeDtypeStruct((R, K), jnp.float32),
        ],
        interpret=interpret,
    )(vg, val, mask)

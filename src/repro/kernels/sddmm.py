"""Gathered-operand SDDMM Pallas-TPU kernel.

pred[e] = ug[e] . vg[e] over the observed/test entries — used by the
RMSE evaluation, the adaptive-noise residual, and the probit latent
augmentation (paper Algorithm 1 "for all test points").

The gather U[i[e]], V[j[e]] happens outside the kernel (XLA gather is
efficient and Pallas-TPU dynamic gathers are not); the kernel fuses the
elementwise product + K-reduction with explicit VMEM tiling so the
(E, K) operand slabs stream through VMEM once.

Contract-checked: the K-axis revisit-accumulate discipline, bounds,
fp32 accumulation, and VMEM budget are statically verified over the
``ops.KERNELS`` probe envelope by ``repro.analysis.kernelcheck``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(ug_ref, vg_ref, out_ref):
    k = pl.program_id(1)
    u = ug_ref[...].astype(jnp.float32)   # (BE, BK)
    v = vg_ref[...].astype(jnp.float32)   # (BE, BK)
    part = jnp.sum(u * v, axis=-1)        # (BE,)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("block_e", "block_k", "interpret"))
def sddmm_pallas(ug: jnp.ndarray, vg: jnp.ndarray, *,
                 block_e: int = 512, block_k: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """pred (E,) = rowwise dot of ug (E, K) and vg (E, K)."""
    E, K = ug.shape
    be = min(block_e, E)
    bk = min(block_k, K)
    if E % be or K % bk:
        raise ValueError(f"({E},{K}) not divisible by blocks ({be},{bk})")
    grid = (E // be, K // bk)

    return pl.pallas_call(
        _sddmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, bk), lambda e, k: (e, k)),
            pl.BlockSpec((be, bk), lambda e, k: (e, k)),
        ],
        out_specs=pl.BlockSpec((be,), lambda e, k: (e,)),
        out_shape=jax.ShapeDtypeStruct((E,), jnp.float32),
        interpret=interpret,
    )(ug, vg)

"""Flash-attention forward Pallas-TPU kernel.

The LM-side compute hot-spot (and, after the §Perf iterations, the
dominant residual HBM traffic) is attention's (Sq x Sk) score matrix.
``models/layers.flash_attention`` removes the *stacked* score
residuals at the XLA level, but XLA still round-trips each chunk's
scores through HBM (two dots cannot fuse).  This kernel is the
TPU-native step: the score tile lives only in VMEM; HBM sees exactly
q, k, v and out — the flash-attention traffic contract.

Layout: GQA folded as (B*KVH, G*Sq, hd) rows against (B*KVH, Sk, hd)
keys/values, so one kernel shape serves MHA and GQA.  Grid =
(batch-head, q-block, k-block), k minor; online-softmax accumulators
(m, l, acc) persist in VMEM scratch across the k sweep (revisiting
grid pattern, same discipline as kernels/gram.py).

Causal masking works on *positions*: qpos = q_offset + (row mod Sq)
(the fold puts G query groups over the same positions), kpos = global
k index; optional sliding window.  Fully-masked k-blocks are skipped
via ``pl.when`` on the block indices.

Validated against ``models/layers._flash_fwd`` / ``ref.py`` maths in
``tests/test_flash.py`` (interpret mode; shape/dtype sweeps) and the
materialized-score oracle ``ref.attention_ref`` in
``tests/test_kernels.py``.  Contract-checked: the ``ki == 0`` scratch
init, the ``ki == n_kb - 1`` single final output write, bounds, fp32
scratch accumulation, and the VMEM budget are statically verified
over the ``ops.KERNELS`` probe envelope by
``repro.analysis.kernelcheck``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sq: int, block_q: int, block_k: int, n_kb: int,
                  causal: bool, window: int, q_offset: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # positions: query rows are (g, s) folded -> position = row % sq
    rows = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    qpos = q_offset + rows % sq
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)          # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            m_ok = kpos <= qpos
            if window > 0:
                m_ok &= kpos > qpos - window
            s = jnp.where(m_ok, s, NEG_INF)

        m_prev = m_ref[...]                        # (BQ,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])            # (BQ, BK)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)     # (BQ, hd)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_cur

    if causal:
        # skip k-blocks fully in the future of every query in the tile
        first_q = q_offset + (qi * block_q) % sq
        pl.when(ki * block_k <= first_q + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_kb - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] /
                    jnp.where(l == 0.0, 1.0, l)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset",
                              "block_q", "block_k", "interpret"))
def flash_fwd_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                     q_offset: int = 0, block_q: int = 128,
                     block_k: int = 128, interpret: bool = False):
    """q (B,Sq,H,hd), k/v (B,Sk,KVH,hd) -> out (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / np.sqrt(hd)

    # fold GQA: (B*KVH, G*Sq, hd) queries vs (B*KVH, Sk, hd) keys
    qf = (q.reshape(B, Sq, KVH, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * KVH, G * Sq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, hd)

    bq = min(block_q, G * Sq)
    while (G * Sq) % bq or Sq % min(bq, Sq):
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    grid = (B * KVH, (G * Sq) // bq, Sk // bk)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, sq=Sq, block_q=bq, block_k=bk,
                          n_kb=grid[2], causal=causal, window=window,
                          q_offset=q_offset, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G * Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denom
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)

    return (out.reshape(B, KVH, G, Sq, hd).transpose(0, 3, 1, 2, 4)
            .reshape(B, Sq, H, hd))

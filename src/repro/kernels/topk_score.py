"""Fused posterior-scoring + top-K Pallas-TPU kernel.

The serving hot path (ROADMAP "recommendations as a service"; the
compound-activity prediction-at-scale story of arXiv:1904.02514 §1)
scores one user row against ALL items across ALL retained posterior
samples and keeps only the K best:

    score[s, n] = u_s . V_s[n]            per sample s, item n
    mean[n]     = 1/S sum_s score[s, n]   posterior mean
    ex2[n]      = 1/S sum_s score[s, n]^2
    std[n]      = sqrt(max(ex2 - mean^2, 0))   posterior uncertainty

A naive implementation materializes the (S, n_items) score matrix per
request — at catalogue scale (millions of items, ~100 samples) that is
hundreds of MB of HBM traffic per user.  This kernel tiles the item
axis and fuses the three stages, so only a (S, BN) score *tile* ever
exists in VMEM:

  grid = (B users, n_items / BN); the item axis is the minor (fastest
  varying) dimension so each user's running top-K state stays resident
  in VMEM while item tiles stream through (revisiting pattern).  Per
  tile the MXU computes the S-batched (BN, K) x (K,) scores, the VPU
  reduces over samples, and a K-step unrolled selection merges the
  tile's means into the running top-K (ids, mean, ex2, masked ranking
  score).  ``ops.topk_score`` converts the selected ex2 to the
  posterior std AFTER the kernel, with the same (B, k)-shaped float
  program the reference path uses — shape-dependent FMA fusion of
  ``ex2 - mean*mean`` is what broke bitwise equality when each path
  finalized its own std (measured: 1-ulp drift).

Tie-breaking contract: equal posterior means rank by LOWEST item id —
``jnp.argmax`` takes the first occurrence and the running top-K stores
candidates in (rank desc, id asc) order, so the merge reproduces the
stable ``jnp.argsort`` reference (``ref.topk_score_ref``) bitwise in
fp32, asserted in tests/test_kernels.py.

Excluded items (already-observed entries a request does not want
re-recommended) enter the ranking at -inf but keep their true
mean/ex2; slots beyond the number of rankable items are masked at the
``ops.topk_score`` level, identically for kernel and reference.

Contract-checked: the item-axis revisit-accumulate discipline (all
four outputs init under ``@pl.when(t == 0)`` before any merge read),
bounds over the shared ``ops.pad_to_blocks`` padding, fp32/i32 state
dtypes, and the VMEM budget of the serving envelope are statically
verified over the ``ops.KERNELS`` probes by
``repro.analysis.kernelcheck``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(us_ref, v_ref, excl_ref, ids_ref, mean_ref, ex2_ref,
                 rank_ref, *, k: int, block_items: int,
                 n_samples: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        ids_ref[...] = jnp.full((1, k), -1, jnp.int32)
        mean_ref[...] = jnp.zeros((1, k), jnp.float32)
        rank_ref[...] = jnp.full((1, k), -jnp.inf, jnp.float32)
        ex2_ref[...] = jnp.zeros((1, k), jnp.float32)

    us = us_ref[0]                         # (S, K)
    v = v_ref[...]                         # (S, BN, K)
    excl = excl_ref[0]                     # (BN,)

    # MXU: per-sample scores for this item tile, f32 accumulation
    scores = jax.lax.dot_general(
        v, us,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (S, BN)
    inv_s = jnp.float32(1.0) / jnp.float32(n_samples)
    mean_t = jnp.sum(scores, axis=0) * inv_s         # (BN,)
    ex2_t = jnp.sum(scores * scores, axis=0) * inv_s
    rank_t = jnp.where(excl > 0, -jnp.inf, mean_t)

    # merge the tile into the running top-K.  Current top entries come
    # FIRST so argmax's first-occurrence tie-break keeps the lowest
    # item id (top entries always carry lower ids than this tile's).
    base = t * block_items
    tile_ids = base + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_items), 1)[0]
    cand_rank = jnp.concatenate([rank_ref[0], rank_t])
    cand_mean = jnp.concatenate([mean_ref[0], mean_t])
    cand_ex2 = jnp.concatenate([ex2_ref[0], ex2_t])
    cand_ids = jnp.concatenate([ids_ref[0], tile_ids])
    n_cand = k + block_items
    pos_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_cand), 1)[0]

    sel_rank, sel_mean, sel_ex2, sel_ids = [], [], [], []
    for _ in range(k):                     # k static: unrolled
        pos = jnp.argmax(cand_rank)        # first occurrence on ties
        hot = pos_iota == pos
        sel_rank.append(jnp.max(cand_rank))
        sel_mean.append(jnp.sum(jnp.where(hot, cand_mean, 0.0)))
        sel_ex2.append(jnp.sum(jnp.where(hot, cand_ex2, 0.0)))
        sel_ids.append(jnp.sum(jnp.where(hot, cand_ids, 0)))
        cand_rank = jnp.where(hot, -jnp.inf, cand_rank)

    rank_ref[...] = jnp.stack(sel_rank)[None, :]
    mean_ref[...] = jnp.stack(sel_mean)[None, :]
    ex2_ref[...] = jnp.stack(sel_ex2)[None, :]
    ids_ref[...] = jnp.stack(sel_ids)[None, :].astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("k", "block_items", "interpret"))
def topk_score_pallas(us: jnp.ndarray, v: jnp.ndarray,
                      excl: jnp.ndarray, *, k: int,
                      block_items: int = 256,
                      interpret: bool = False):
    """Fused scoring + top-K: see module docstring.

    us (B, S, K) user latent rows per sample, v (S, N, K) item factor
    stack, excl (B, N) 1.0 = excluded from ranking  ->
    ids (B, k) i32, mean (B, k) f32, ex2 (B, k) f32, rank (B, k) f32
    (the masked selection scores; callers discard them).  N must be
    divisible by ``block_items`` (callers pad; padded items carry
    excl 1.0).
    """
    B, S, K = us.shape
    S2, N, K2 = v.shape
    if (S, K) != (S2, K2):
        raise ValueError(f"us {us.shape} vs v {v.shape} mismatch")
    bn = min(block_items, N)
    if N % bn:
        raise ValueError(f"n_items {N} not divisible by tile {bn}")
    n_tiles = N // bn
    kern = functools.partial(_topk_kernel, k=k, block_items=bn,
                             n_samples=S)

    return pl.pallas_call(
        kern,
        grid=(B, n_tiles),
        in_specs=[
            pl.BlockSpec((1, S, K), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((S, bn, K), lambda b, t: (0, t, 0)),
            pl.BlockSpec((1, bn), lambda b, t: (b, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(us, v, excl)

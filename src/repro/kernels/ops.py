"""Public kernel entry points.

``gram_and_rhs`` / ``sddmm`` dispatch between the Pallas kernel (TPU
target; ``interpret=True`` on CPU) and the pure-jnp oracle, controlled
by the ``use_pallas`` flag carried in the session config.  On this
container (CPU-only) the default is the XLA path; tests exercise the
Pallas path in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .gram import gram_pallas
from .sddmm import sddmm_pallas
from .topk_score import topk_score_pallas

_ON_TPU = jax.default_backend() == "tpu"

# the serving hot loop calls this per service step; eager lax.map
# dispatch costs more than the scoring itself (measured ~100x on the
# quick latency benchmark)
_topk_ref_jit = functools.partial(jax.jit, static_argnums=(3,))(
    ref.topk_score_ref)


def _pad_to(x: jnp.ndarray, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def gram_and_rhs(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray,
                 *, use_pallas: bool = False, interpret: bool | None = None):
    """Fused masked batched Gram; see kernels/gram.py.

    Pads rows/nnz up to the kernel block multiples (mask-0 padding is an
    exact no-op) and slices the result back.
    """
    if not use_pallas:
        return ref.gram_ref(vg, val, mask)
    interpret = (not _ON_TPU) if interpret is None else interpret
    br, bt = 8, 128
    vg_p, R = _pad_to(vg, 0, br)
    vg_p, _ = _pad_to(vg_p, 1, bt)
    val_p, _ = _pad_to(val, 0, br)
    val_p, _ = _pad_to(val_p, 1, bt)
    mask_p, _ = _pad_to(mask, 0, br)
    mask_p, _ = _pad_to(mask_p, 1, bt)
    gram, rhs = gram_pallas(vg_p, val_p, mask_p, block_rows=br,
                            block_nnz=bt, interpret=interpret)
    return gram[:R], rhs[:R]


def topk_score(us: jnp.ndarray, v: jnp.ndarray, k: int, *,
               exclude: jnp.ndarray | None = None,
               use_pallas: bool = False,
               interpret: bool | None = None):
    """Batched posterior scoring + top-K; see kernels/topk_score.py.

    us (B, S, K) user rows per sample, v (S, N, K) item factor stack,
    ``exclude`` (B, N) truthy = leave out of the ranking ->
    (ids (B, k') i32, mean (B, k') f32, std (B, k') f32) with
    k' = min(k, N).  Slots past the number of rankable (non-excluded)
    items of a row carry id -1 and NaN mean/std — identically on both
    the kernel and the reference path, so K > n_items clamps instead
    of surfacing padding artifacts.

    Both paths see the SAME item-padded operands (pad items carry
    exclude 1.0, an exact ranking no-op) and the std is finalized here
    from the selected (mean, ex2): shape-dependent vectorization would
    otherwise drift the two paths by 1 ulp (measured), and the serving
    contract is fp32 BITWISE kernel == reference.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    B, S, K = us.shape
    N = v.shape[1]
    k_eff = min(int(k), N)
    if exclude is None:
        excl = jnp.zeros((B, N), jnp.float32)
    else:
        excl = jnp.asarray(exclude)
        if excl.shape != (B, N):
            raise ValueError(
                f"exclude shape {excl.shape} != (B, N) = {(B, N)}")
        excl = (excl > 0).astype(jnp.float32)

    bn = 256
    v_p, _ = _pad_to(v, 1, bn)
    pad = v_p.shape[1] - N
    # padded items are excluded so they can never be selected
    excl_p = jnp.pad(excl, ((0, 0), (0, pad)), constant_values=1.0)

    if not use_pallas:
        ids, mean, ex2 = _topk_ref_jit(us, v_p, excl_p, k_eff)
    else:
        interpret = (not _ON_TPU) if interpret is None else interpret
        ids, mean, ex2, _ = topk_score_pallas(
            us, v_p, excl_p, k=k_eff, block_items=bn,
            interpret=interpret)

    std = jnp.sqrt(jnp.maximum(ex2 - mean * mean, 0.0))
    # rows with fewer than k_eff rankable items: invalidate the tail
    n_valid = jnp.sum(excl <= 0, axis=1).astype(jnp.int32)   # (B,)
    slot = jnp.arange(k_eff, dtype=jnp.int32)[None, :]
    bad = slot >= n_valid[:, None]
    ids = jnp.where(bad, -1, ids)
    mean = jnp.where(bad, jnp.nan, mean)
    std = jnp.where(bad, jnp.nan, std)
    return ids, mean, std


def sddmm(ug: jnp.ndarray, vg: jnp.ndarray, *, use_pallas: bool = False,
          interpret: bool | None = None) -> jnp.ndarray:
    """Gathered-operand SDDMM; see kernels/sddmm.py."""
    if not use_pallas:
        return ref.sddmm_ref(ug, vg)
    interpret = (not _ON_TPU) if interpret is None else interpret
    be, bk = 512, 128
    ug_p, E = _pad_to(ug, 0, be)
    ug_p, _ = _pad_to(ug_p, 1, bk)
    vg_p, _ = _pad_to(vg, 0, be)
    vg_p, _ = _pad_to(vg_p, 1, bk)
    out = sddmm_pallas(ug_p, vg_p, block_e=be, block_k=bk,
                       interpret=interpret)
    return out[:E]

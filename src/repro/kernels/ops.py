"""Public kernel entry points.

``gram_and_rhs`` / ``sddmm`` / ``topk_score`` dispatch between the
Pallas kernel (TPU target; ``interpret=True`` on CPU) and the pure-jnp
oracle, controlled by the ``use_pallas`` flag carried in the session
config.  On this container (CPU-only) the default is the XLA path;
tests exercise the Pallas path in interpret mode.

Kernel contracts
----------------
Every kernel shipped from this package is registered in ``KERNELS``
below and statically verified — grid race-freedom, block bounds over
the shared padding path, fp32 accumulation, and a per-grid-step VMEM
budget — by ``repro.analysis.kernelcheck`` (CI: ``python -m
repro.analysis --kernels``; rule catalogue in
``src/repro/analysis/README.md``).  The registry's probes are the
supported shape envelope: the checker concretely enumerates each
kernel's grid over exactly these configurations, so a new kernel, a
new block size, or a bigger serving store belongs in a new
:class:`KernelProbe` **first** — the CPU container only ever runs
kernels in interpret mode, and the checker is what stands between a
grid bug and its first real-TPU execution.  All wrapper padding goes
through :func:`pad_to_blocks` so the bounds checker verifies a single
padding path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash import flash_fwd_pallas
from .gram import gram_pallas
from .sddmm import sddmm_pallas
from .topk_score import topk_score_pallas

_ON_TPU = jax.default_backend() == "tpu"

# the serving hot loop calls this per service step; eager lax.map
# dispatch costs more than the scoring itself (measured ~100x on the
# quick latency benchmark)
_topk_ref_jit = functools.partial(jax.jit, static_argnums=(3,))(
    ref.topk_score_ref)


def pad_to_blocks(x: jnp.ndarray,
                  multiples: Mapping[int, int]) -> jnp.ndarray:
    """Pad the trailing edge of the given axes of ``x`` up to the next
    multiple of each block size (zero fill).

    ``multiples`` maps axis -> block multiple.  Returns ``x`` itself
    when every axis is already aligned, so the aligned fast path adds
    no ops.  This is the ONE padding path every Pallas wrapper uses;
    ``repro.analysis.kernelcheck`` verifies the resulting grids stay
    in bounds for uneven tails, so new wrappers must route their
    padding through here too.
    """
    widths = [(0, 0)] * x.ndim
    need = False
    for ax, mult in multiples.items():
        if mult < 1:
            raise ValueError(
                f"block multiple for axis {ax} must be >= 1, got {mult}")
        pad = (-x.shape[ax]) % mult
        widths[ax] = (0, pad)
        need = need or pad > 0
    return jnp.pad(x, widths) if need else x


def gram_and_rhs(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray,
                 *, use_pallas: bool = False, interpret: bool | None = None):
    """Fused masked batched Gram; see kernels/gram.py.

    Pads rows/nnz up to the kernel block multiples (mask-0 padding is an
    exact no-op) and slices the result back.
    """
    if not use_pallas:
        return ref.gram_ref(vg, val, mask)
    interpret = (not _ON_TPU) if interpret is None else interpret
    br, bt = 8, 128
    R = vg.shape[0]
    vg_p = pad_to_blocks(vg, {0: br, 1: bt})
    val_p = pad_to_blocks(val, {0: br, 1: bt})
    mask_p = pad_to_blocks(mask, {0: br, 1: bt})
    gram, rhs = gram_pallas(vg_p, val_p, mask_p, block_rows=br,
                            block_nnz=bt, interpret=interpret)
    return gram[:R], rhs[:R]


def topk_score(us: jnp.ndarray, v: jnp.ndarray, k: int, *,
               exclude: jnp.ndarray | None = None,
               use_pallas: bool = False,
               interpret: bool | None = None):
    """Batched posterior scoring + top-K; see kernels/topk_score.py.

    us (B, S, K) user rows per sample, v (S, N, K) item factor stack,
    ``exclude`` (B, N) truthy = leave out of the ranking ->
    (ids (B, k') i32, mean (B, k') f32, std (B, k') f32) with
    k' = min(k, N).  Slots past the number of rankable (non-excluded)
    items of a row carry id -1 and NaN mean/std — identically on both
    the kernel and the reference path, so K > n_items clamps instead
    of surfacing padding artifacts.

    Both paths see the SAME item-padded operands (pad items carry
    exclude 1.0, an exact ranking no-op) and the std is finalized here
    from the selected (mean, ex2): shape-dependent vectorization would
    otherwise drift the two paths by 1 ulp (measured), and the serving
    contract is fp32 BITWISE kernel == reference.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    B, S, K = us.shape
    N = v.shape[1]
    k_eff = min(int(k), N)
    if exclude is None:
        excl = jnp.zeros((B, N), jnp.float32)
    else:
        excl = jnp.asarray(exclude)
        if excl.shape != (B, N):
            raise ValueError(
                f"exclude shape {excl.shape} != (B, N) = {(B, N)}")
        excl = (excl > 0).astype(jnp.float32)

    bn = 256
    v_p = pad_to_blocks(v, {1: bn})
    pad = v_p.shape[1] - N
    # padded items are excluded so they can never be selected
    excl_p = jnp.pad(excl, ((0, 0), (0, pad)), constant_values=1.0)

    if not use_pallas:
        ids, mean, ex2 = _topk_ref_jit(us, v_p, excl_p, k_eff)
    else:
        interpret = (not _ON_TPU) if interpret is None else interpret
        ids, mean, ex2, _ = topk_score_pallas(
            us, v_p, excl_p, k=k_eff, block_items=bn,
            interpret=interpret)

    std = jnp.sqrt(jnp.maximum(ex2 - mean * mean, 0.0))
    # rows with fewer than k_eff rankable items: invalidate the tail
    n_valid = jnp.sum(excl <= 0, axis=1).astype(jnp.int32)   # (B,)
    slot = jnp.arange(k_eff, dtype=jnp.int32)[None, :]
    bad = slot >= n_valid[:, None]
    ids = jnp.where(bad, -1, ids)
    mean = jnp.where(bad, jnp.nan, mean)
    std = jnp.where(bad, jnp.nan, std)
    return ids, mean, std


def sddmm(ug: jnp.ndarray, vg: jnp.ndarray, *, use_pallas: bool = False,
          interpret: bool | None = None) -> jnp.ndarray:
    """Gathered-operand SDDMM; see kernels/sddmm.py."""
    if not use_pallas:
        return ref.sddmm_ref(ug, vg)
    interpret = (not _ON_TPU) if interpret is None else interpret
    be, bk = 512, 128
    E = ug.shape[0]
    ug_p = pad_to_blocks(ug, {0: be, 1: bk})
    vg_p = pad_to_blocks(vg, {0: be, 1: bk})
    out = sddmm_pallas(ug_p, vg_p, block_e=be, block_k=bk,
                       interpret=interpret)
    return out[:E]


# ---------------------------------------------------------------------------
# kernel registry: the statically-verified shape envelope
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelProbe:
    """One concrete configuration the checker enumerates.

    ``call(*arrays)`` must drive the public wrapper with the Pallas
    path forced (so the wrapper's padding arithmetic is part of what
    gets verified); ``args`` are ``jax.ShapeDtypeStruct`` operands —
    the probe is traced with ``jax.eval_shape``, never executed.
    """
    label: str
    args: Tuple[Any, ...]
    call: Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry entry for one shipped Pallas kernel."""
    name: str
    probes: Tuple[KernelProbe, ...]
    vmem_budget: int                 # per-grid-step resident bytes
    jit_fns: Tuple[Any, ...] = ()    # jitted entries to cache-clear
    #                                  around capture (see kernelcheck)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _gram_call(vg, val, mask):
    return gram_and_rhs(vg, val, mask, use_pallas=True)


def _sddmm_call(ug, vg):
    return sddmm(ug, vg, use_pallas=True)


def _topk_call(k):
    def call(us, v):
        return topk_score(us, v, k, use_pallas=True)
    return call


def _topk_call_excl(k):
    def call(us, v, ex):
        return topk_score(us, v, k, exclude=ex, use_pallas=True)
    return call


def _flash_call(**kw):
    def call(q, k, v):
        return flash_fwd_pallas(q, k, v, **kw)
    return call


_BF16 = jnp.bfloat16

KERNELS = {
    "gram": KernelSpec(
        "gram",
        probes=(
            KernelProbe("production r64 t256 K128",
                        (_sds((64, 256, 128)), _sds((64, 256)),
                         _sds((64, 256))), _gram_call),
            KernelProbe("uneven tail r13 t257 K33",
                        (_sds((13, 257, 33)), _sds((13, 257)),
                         _sds((13, 257))), _gram_call),
            KernelProbe("bf16 gathered operands",
                        (_sds((16, 130, 32), _BF16),
                         _sds((16, 130), _BF16),
                         _sds((16, 130), _BF16)), _gram_call),
        ),
        vmem_budget=4 << 20,
        jit_fns=(gram_pallas,)),
    "sddmm": KernelSpec(
        "sddmm",
        probes=(
            KernelProbe("production e4096 K128",
                        (_sds((4096, 128)), _sds((4096, 128))),
                        _sddmm_call),
            KernelProbe("uneven tail e1025 K200",
                        (_sds((1025, 200)), _sds((1025, 200))),
                        _sddmm_call),
        ),
        vmem_budget=2 << 20,
        jit_fns=(sddmm_pallas,)),
    "topk_score": KernelSpec(
        "topk_score",
        probes=(
            KernelProbe("serving b8 s32 n4096 K32 k100",
                        (_sds((8, 32, 32)), _sds((32, 4096, 32))),
                        _topk_call(100)),
            KernelProbe("catalogue b4 s64 n2048 K64 k100",
                        (_sds((4, 64, 64)), _sds((64, 2048, 64))),
                        _topk_call(100)),
            KernelProbe("uneven tail + exclusions b3 s8 n130 k7",
                        (_sds((3, 8, 16)), _sds((8, 130, 16)),
                         _sds((3, 130))), _topk_call_excl(7)),
        ),
        vmem_budget=12 << 20,
        jit_fns=(topk_score_pallas,)),
    "flash": KernelSpec(
        "flash",
        probes=(
            KernelProbe("causal GQA b2 s256 h4/2 hd128",
                        (_sds((2, 256, 4, 128)), _sds((2, 256, 2, 128)),
                         _sds((2, 256, 2, 128))),
                        _flash_call(causal=True)),
            KernelProbe("windowed decode offset s64 vs 256",
                        (_sds((1, 64, 4, 16)), _sds((1, 256, 2, 16)),
                         _sds((1, 256, 2, 16))),
                        _flash_call(causal=True, window=128,
                                    q_offset=192)),
            KernelProbe("noncausal bf16 uneven s130",
                        (_sds((1, 130, 2, 8), _BF16),
                         _sds((1, 130, 1, 8), _BF16),
                         _sds((1, 130, 1, 8), _BF16)),
                        _flash_call(causal=False)),
        ),
        vmem_budget=4 << 20,
        jit_fns=(flash_fwd_pallas,)),
}

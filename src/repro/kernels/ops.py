"""Public kernel entry points.

``gram_and_rhs`` / ``sddmm`` dispatch between the Pallas kernel (TPU
target; ``interpret=True`` on CPU) and the pure-jnp oracle, controlled
by the ``use_pallas`` flag carried in the session config.  On this
container (CPU-only) the default is the XLA path; tests exercise the
Pallas path in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .gram import gram_pallas
from .sddmm import sddmm_pallas

_ON_TPU = jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def gram_and_rhs(vg: jnp.ndarray, val: jnp.ndarray, mask: jnp.ndarray,
                 *, use_pallas: bool = False, interpret: bool | None = None):
    """Fused masked batched Gram; see kernels/gram.py.

    Pads rows/nnz up to the kernel block multiples (mask-0 padding is an
    exact no-op) and slices the result back.
    """
    if not use_pallas:
        return ref.gram_ref(vg, val, mask)
    interpret = (not _ON_TPU) if interpret is None else interpret
    br, bt = 8, 128
    vg_p, R = _pad_to(vg, 0, br)
    vg_p, _ = _pad_to(vg_p, 1, bt)
    val_p, _ = _pad_to(val, 0, br)
    val_p, _ = _pad_to(val_p, 1, bt)
    mask_p, _ = _pad_to(mask, 0, br)
    mask_p, _ = _pad_to(mask_p, 1, bt)
    gram, rhs = gram_pallas(vg_p, val_p, mask_p, block_rows=br,
                            block_nnz=bt, interpret=interpret)
    return gram[:R], rhs[:R]


def sddmm(ug: jnp.ndarray, vg: jnp.ndarray, *, use_pallas: bool = False,
          interpret: bool | None = None) -> jnp.ndarray:
    """Gathered-operand SDDMM; see kernels/sddmm.py."""
    if not use_pallas:
        return ref.sddmm_ref(ug, vg)
    interpret = (not _ON_TPU) if interpret is None else interpret
    be, bk = 512, 128
    ug_p, E = _pad_to(ug, 0, be)
    ug_p, _ = _pad_to(ug_p, 1, bk)
    vg_p, _ = _pad_to(vg, 0, be)
    vg_p, _ = _pad_to(vg_p, 1, bk)
    out = sddmm_pallas(ug_p, vg_p, block_e=be, block_k=bk,
                       interpret=interpret)
    return out[:E]

"""Token-choice top-k Mixture-of-Experts (GShard/Switch-style).

Dispatch is the capacity-bounded masked-einsum formulation: tokens are
split into groups of ``router_group``; within a group each expert takes
at most C = ceil(k * group * capacity_factor / E) tokens (overflow
dropped, standard at scale).  The dispatch/combine einsums add
~k*cf*group*D flops per token group — a few percent of the expert
matmuls at the pool's sizes — in exchange for a fully static, MXU- and
pjit-friendly dataflow:

  experts weights (E, D, F) shard (None, DP, TP)    [expert weights FSDP+TP]
  expert inputs   (E, G, C, D) shard dp on G        [token groups stay DP]

Shared experts (DeepSeek-V2) run densely on every token.

Aux losses: load-balancing (Switch) + router z-loss (ST-MoE), returned
for the train loop to add.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import sharding as shd
from .layers import Params, _dense, cdtype


def init_moe(key, cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": {"w": _dense(ks[0], D, D, E)},
        "experts_gate": {"w": _dense(ks[1], D, E, D, F)},
        "experts_in": {"w": _dense(ks[2], D, E, D, F)},
        "experts_down": {"w": _dense(ks[3], F, E, F, D)},
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["shared_gate"] = {"w": _dense(ks[4], D, D, Fs)}
        p["shared_in"] = {"w": _dense(ks[5], D, D, Fs)}
        p["shared_down"] = {"w": _dense(ks[6], Fs, Fs, D)}
    return p


def apply_moe(p: Params, cfg: ModelConfig, x: jnp.ndarray, mesh=None
              ) -> Tuple[jnp.ndarray, Params]:
    """x (B, S, D) -> (out, aux-losses dict)."""
    dtype = cdtype(cfg)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = min(cfg.router_group, T)
    assert T % G == 0, f"tokens {T} not divisible by group {G}"
    n_groups = T // G
    C = int(np.ceil(K * G * cfg.capacity_factor / E))
    C = max(4, min(C, G))

    xt = x.reshape(n_groups, G, D)

    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates per token
    gate_vals, gate_idx = jax.lax.top_k(probs, K)       # (n, G, K)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert via masked cumsum
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (n,G,K,E)
    flat = onehot.reshape(n_groups, G * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                # rank within expert
    pos = pos.reshape(n_groups, G, K, E)
    keep = (pos < C).astype(jnp.float32) * onehot
    # dispatch/combine (n, G, E, C): one-hot over capacity slot
    slot = jax.nn.one_hot(pos, C, dtype=jnp.float32)     # (n,G,K,E,C)
    dispatch = jnp.einsum("ngke,ngkec->ngec", keep, slot)
    combine = jnp.einsum("ngk,ngke,ngkec->ngec",
                         gate_vals.astype(jnp.float32), keep, slot)

    # expert inputs: (n, E, C, D)
    ein = jnp.einsum("ngec,ngd->necd", dispatch,
                     xt.astype(jnp.float32)).astype(dtype)
    ep_on = (shd.flag("ep") and mesh is not None
             and E % shd._axis_size(mesh, shd.TP) == 0)
    if ep_on:
        # expert parallelism: the dispatched tokens move to their
        # expert's shard (all-to-all over the model axis); expert
        # compute and weights stay local to the shard
        ein = shd.constrain(ein, mesh, shd.DP, shd.TP, None, None)
    else:
        ein = shd.constrain(ein, mesh, shd.DP, None, None, None)

    g = jnp.einsum("necd,edf->necf", ein, p["experts_gate"]["w"]
                   .astype(dtype))
    h = jnp.einsum("necd,edf->necf", ein, p["experts_in"]["w"]
                   .astype(dtype))
    h = jax.nn.silu(g) * h
    if ep_on:
        h = shd.constrain(h, mesh, shd.DP, shd.TP, None, None)
    else:
        h = shd.constrain(h, mesh, shd.DP, None, None, shd.TP)
    eout = jnp.einsum("necf,efd->necd", h, p["experts_down"]["w"]
                      .astype(dtype))

    out = jnp.einsum("ngec,necd->ngd", combine.astype(jnp.float32),
                     eout.astype(jnp.float32))
    out = out.reshape(B, S, D).astype(dtype)

    if cfg.n_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, p["shared_gate"]["w"]
                        .astype(dtype))
        sh = jnp.einsum("bsd,df->bsf", x, p["shared_in"]["w"]
                        .astype(dtype))
        sh = jax.nn.silu(sg) * sh
        out = out + jnp.einsum("bsf,fd->bsd", sh,
                               p["shared_down"]["w"].astype(dtype))

    # aux losses
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))     # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * frac_probs) / max(K, 1)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss}
    return out, aux

"""Path-based parameter partition rules (t5x-style).

Mesh axes: ("pod", "data", "model") multi-pod, ("data", "model")
single-pod.  DP/FSDP runs over the ("pod","data") product; TP/EP/SP
over "model".

Rules map parameter *path names* to logical PartitionSpecs; a fitting
pass drops any axis that does not divide the concrete dimension
(e.g. 24 SSD heads on a 16-way model axis -> replicated), so every
architecture in the pool shards without per-arch special cases.

Scanned layer stacks carry a leading ``layers`` dimension that is never
sharded (prepended None).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "__dp__"    # placeholder expanded to the mesh's data axes
TP = "model"

# Trace-time perf policy: a comma-joined flag set (variant string).
#   dponly     — treat the model axis as extra data parallelism and
#                disable every TP activation constraint (small-model
#                regime where TP would replicate attention compute)
#   chunkremat — jax.checkpoint each attention q-chunk so backward
#                recomputes scores instead of stacking them in HBM
#   bf16scores — materialize attention scores/weights in bf16 (f32
#                softmax maths, fused) — the MXU-native layout
_POLICY = contextvars.ContextVar("perf_policy", default=frozenset())


@contextlib.contextmanager
def policy(name: str):
    flags = frozenset(f for f in name.split(",") if f)
    tok = _POLICY.set(flags)
    try:
        yield
    finally:
        _POLICY.reset(tok)


def flag(name: str) -> bool:
    return name in _POLICY.get()


def _extra_dp() -> bool:
    return flag("dponly")

# (regex over "/"-joined path, spec for the *trailing* dims of the param)
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / unembedding: (vocab, d)
    (r"embed", (TP, DP)),
    (r"unembed", (DP, TP)),
    # attention
    (r"wq/bias|wk/bias|wv/bias", (TP,)),
    (r"wq", (DP, TP)),
    (r"wk", (DP, TP)),
    (r"wv", (DP, TP)),
    (r"wo", (TP, DP)),
    # MLA
    (r"q_a|kv_a", (DP, None)),
    (r"q_b|kv_b", (None, TP)),
    # dense mlp
    (r"wi|wg", (DP, TP)),
    (r"wdown", (TP, DP)),
    # moe
    (r"router", (DP, None)),
    (r"experts_in|experts_gate", (None, DP, TP)),
    (r"experts_down", (None, TP, DP)),
    (r"shared_in|shared_gate", (DP, TP)),
    (r"shared_down", (TP, DP)),
    # ssd / mamba2
    (r"ssm_in", (DP, TP)),
    (r"ssm_out", (TP, DP)),
    (r"conv_w", (None, TP)),
    (r"A_log|ssm_D|dt_bias", (TP,)),
    # norms, scalars, everything small
    (r"norm|scale|bias", (None,)),
)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    names = ("pod", "data", "model") if _extra_dp() else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def _expand(spec_entry, mesh: Mesh):
    if spec_entry == DP:
        return dp_axes(mesh)
    if spec_entry == TP and _extra_dp():
        return None          # model axis is data-parallel in dponly
    return spec_entry


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry])) if entry else 1
    return mesh.shape[entry]


# Expert-parallel overrides (the ``ep`` perf flag): expert weights
# (E, D, F) shard their EXPERT dim over the model axis, so expert-grad
# reductions and FSDP gathers move 1/EP of the bytes; tokens reach
# their experts through the dispatch all-to-all instead.
_EP_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"experts_in|experts_gate", (TP, DP, None)),
    (r"experts_down", (TP, None, DP)),
)


def spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh,
             scanned: bool) -> P:
    """Resolve the partition spec for one parameter."""
    trailing = len(shape) - (1 if scanned else 0)
    rules = _RULES
    if flag("ep") and "experts_" in path:
        # EP engages only when the expert count divides the model
        # axis; otherwise fall back to the dense-style rules (with 8
        # experts on a 16-way axis the fit pass would drop the expert
        # axis AND the d_ff sharding -> measured 606 GiB/dev blowup)
        e_dim = shape[1 if scanned else 0]
        if e_dim % _axis_size(mesh, TP) == 0:
            rules = _EP_RULES + _RULES
    for pat, rule in rules:
        if re.search(pat, path):
            rule = rule[-trailing:] if trailing <= len(rule) else \
                (None,) * (trailing - len(rule)) + rule
            entries = [_expand(e, mesh) for e in rule]
            # drop axes that don't divide the concrete dim
            dims = shape[-trailing:] if trailing else ()
            fitted = []
            for dim, e in zip(dims, entries):
                fitted.append(e if dim % _axis_size(mesh, e) == 0 else None)
            if scanned:
                fitted = [None] + fitted
            return P(*fitted)
    return P()  # replicate unknown params


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_shardings(params: Any, mesh: Mesh, scanned_paths=("stack",)):
    """Pytree of NamedShardings matching ``params``.

    Parameters under a path containing any of ``scanned_paths`` are
    treated as scanned stacks (leading layer dim unsharded).
    """

    def f(path, x):
        ps = _path_str(path)
        scanned = any(s in ps for s in scanned_paths)
        return NamedSharding(mesh, spec_for(ps, x.shape, mesh, scanned))

    return jax.tree_util.tree_map_with_path(f, params)


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard the batch over dp axes when divisible, else replicate."""
    axes = dp_axes(mesh)
    if axes and batch % _axis_size(mesh, axes) == 0:
        return P(axes)
    return P()


def constrain(x, mesh: Mesh, *spec_entries):
    """with_sharding_constraint that drops non-dividing axes."""
    if mesh is None:
        return x
    fitted = []
    for dim, e in zip(x.shape, spec_entries):
        e = _expand(e, mesh)
        fitted.append(e if (e and dim % _axis_size(mesh, e) == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fitted)))

"""Unified model configuration for the assigned architecture pool.

One ``ModelConfig`` covers every family in the pool: dense llama-style
decoders, GQA, MoE (token-choice top-k with optional shared experts),
MLA (DeepSeek compressed-KV attention), Mamba2/SSD blocks, hybrid
attn/ssm interleaves (Jamba), encoder-decoder (Whisper), and stub
modality frontends (ViT patches / audio frames as precomputed
embeddings).

Layers are described as a repeating *pattern* of ``LayerSpec``s so the
transformer stack can ``lax.scan`` over pattern repeats (small HLO,
fast compile, remat-friendly) even for heterogeneous interleaves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating pattern."""

    mixer: str = "attn"        # attn | mla | mamba2
    mlp: str = "dense"         # dense | moe | none  (mamba2 has no mlp)
    window: int = 0            # >0: sliding-window attention
    cross: bool = False        # add cross-attention (enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int              # total layer count (pattern * repeats [+ prologue])
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # mlp
    d_ff: int = 0
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_group: int = 1024    # tokens per dispatch group
    # mla (deepseek)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # mamba2 / ssd
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # structure
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prologue: Tuple[LayerSpec, ...] = ()   # unscanned leading layers
    # enc-dec
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_frames: int = 1500   # whisper stub frontend length
    # modality frontend stub: inputs arrive as embeddings of this length
    n_frontend_tokens: int = 0   # e.g. ViT patch tokens prepended
    # numerics / misc
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    mlp_gelu: bool = False      # 2-matmul GELU MLP (whisper) vs SwiGLU
    use_layernorm: bool = False  # LayerNorm (whisper) vs RMSNorm
    use_rope: bool = True        # RoPE vs absolute sinusoidal positions
    max_seq_len: int = 8192

    # ------------------------------------------------------------------
    @property
    def repeats(self) -> int:
        n_scanned = self.n_layers - len(self.prologue)
        assert n_scanned % len(self.pattern) == 0, (
            f"{self.name}: {n_scanned} layers not divisible by pattern "
            f"of {len(self.pattern)}")
        return n_scanned // len(self.pattern)

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def validate(self) -> "ModelConfig":
        _ = self.repeats
        for spec in self.pattern + self.prologue:
            if spec.mixer in ("attn",):
                assert self.n_heads and self.head_dim
            if spec.mixer == "mla":
                assert self.kv_lora_rank > 0
            if spec.mixer == "mamba2":
                assert self.ssm_heads > 0
            if spec.mlp == "moe":
                assert self.n_experts and self.top_k
        return self


def param_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active-per-token) parameter counts, embedding included."""
    D = cfg.d_model
    total = cfg.vocab_size * D  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * D
    active = total

    def attn_params():
        q = D * cfg.n_heads * cfg.head_dim + (
            cfg.n_heads * cfg.head_dim if cfg.qkv_bias else 0)
        kv = 2 * (D * cfg.kv_dim + (cfg.kv_dim if cfg.qkv_bias else 0))
        o = cfg.n_heads * cfg.head_dim * D
        return q + kv + o

    def mla_params():
        # q proj (full), kv down + up, o proj
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        q = D * cfg.n_heads * qd
        kv_down = D * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        kv_up = cfg.kv_lora_rank * cfg.n_heads * (
            cfg.qk_nope_dim + cfg.v_head_dim)
        o = cfg.n_heads * cfg.v_head_dim * D
        return q + kv_down + kv_up + o

    def ssm_params():
        di = cfg.d_inner_ssm
        G = max(1, cfg.ssm_heads // cfg.ssm_heads)  # ngroups=1
        zxbc = D * (2 * di + 2 * G * cfg.ssm_state)
        dt = di // cfg.ssm_head_dim
        out = di * D
        conv = cfg.conv_width * (di + 2 * G * cfg.ssm_state)
        return zxbc + dt + out + conv + 2 * dt  # A_log, D per head

    def mlp_params(kind):
        if kind == "none":
            return 0, 0
        if kind == "dense":
            p = (2 if cfg.mlp_gelu else 3) * D * cfg.d_ff
            return p, p
        # moe: router + experts (+ shared)
        ex = 3 * D * cfg.d_ff_expert
        tot = D * cfg.n_experts + cfg.n_experts * ex \
            + cfg.n_shared_experts * ex
        act = D * cfg.n_experts + cfg.top_k * ex \
            + cfg.n_shared_experts * ex
        return tot, act

    for spec in cfg.prologue + cfg.pattern * cfg.repeats:
        if spec.mixer == "attn":
            p = attn_params()
        elif spec.mixer == "mla":
            p = mla_params()
        else:
            p = ssm_params()
        total += p + 2 * D       # norms
        active += p + 2 * D
        mt, ma = mlp_params(spec.mlp)
        total += mt
        active += ma

    if cfg.is_encoder_decoder:
        # encoder self-attn + GELU mlp; decoder adds cross-attn
        enc = cfg.n_encoder_layers * (attn_params() + 2 * D * cfg.d_ff
                                      + 2 * D)
        cross = cfg.n_layers * attn_params()
        total += enc + cross
        active += enc + cross
    return int(total), int(active)

"""Mamba2 / SSD (state-space duality) block, TPU-adapted.

The SSD chunked algorithm maps naturally onto the MXU: within a chunk
of length L the recurrence is computed as an (L x L) masked matmul
(quadratic-but-tiny, MXU-shaped), and across chunks a small
(H, N, P) state is carried by a ``lax.scan`` — O(S) work, O(1) decode
state.  This is the TPU-native replacement for the CUDA selective-scan
kernel: no warp shuffles needed, the duality *is* the adaptation.

Decode keeps (conv window, SSD state) — constant-size cache, which is
why mamba2/jamba run the 500k-context cell that full-attention archs
skip.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import sharding as shd
from .layers import Params, _dense, cdtype, rms_norm

G = 1  # ssm groups (ngroups=1 for the pool's archs)


def init_mamba2(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    di = cfg.d_inner_ssm
    N = cfg.ssm_state
    H = cfg.ssm_heads
    W = cfg.conv_width
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "ssm_in": {"w": _dense(ks[0], D, D, 2 * di + 2 * G * N + H)},
        "conv_w": jnp.zeros((W, conv_ch), jnp.float32)
        .at[W - 1].set(1.0),                      # identity-ish init
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "ssm_D": jnp.ones((H,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((di,), jnp.float32)},
        "ssm_out": {"w": _dense(ks[3], di, di, D)},
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width W.  xbc (B,S,CH), w (W,CH).

    prev (B,W-1,CH) is the decode carry; returns (out, new_prev).
    """
    Wd = w.shape[0]
    if prev is None:
        pad = jnp.zeros_like(xbc[:, : Wd - 1])
    else:
        pad = prev
    full = jnp.concatenate([pad, xbc], axis=1)           # (B, S+W-1, CH)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(Wd))
    out = jax.nn.silu(out + b)
    new_prev = full[:, -(Wd - 1):]
    return out, new_prev


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD scan.  x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    L = chunk
    xc = x.reshape(Bb, nc, L, H, P)
    dtc = dt.reshape(Bb, nc, L, H)
    Bc = Bm.reshape(Bb, nc, L, N)
    Cc = Cm.reshape(Bb, nc, L, N)

    la = dtc * A[None, None, None, :]                    # log-decay, <=0
    cum = jnp.cumsum(la, axis=2)                         # (B,nc,L,H)

    # intra-chunk: M[t,s] = C_t.B_s * exp(cum_t - cum_s) * dt_s, s<=t
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)           # (B,nc,L,L)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    # mask BEFORE the exp: exp of the (s > t) branch can overflow and a
    # masked inf still poisons the gradient through where().
    decay = jnp.exp(jnp.where(tri, seg, -1e30))
    M = CB[..., None] * decay * dtc[:, :, None, :, :]    # (B,nc,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M, xc)

    # chunk summaries: S_c = sum_s exp(cum_L - cum_s) dt_s B_s x_s^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,L,H)
    Sc = jnp.einsum("bclh,bcln,bclhp->bchnp",
                    dec_end * dtc, Bc, xc)               # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    # inter-chunk recurrence over the nc axis
    def body(s, args):
        sc, cd = args                                    # (B,H,N,P),(B,H)
        y_state = s                                      # state BEFORE chunk
        s_new = cd[:, :, None, None] * s + sc
        return s_new, y_state

    s0 = (jnp.zeros((Bb, H, N, P), x.dtype) if init_state is None
          else init_state)
    final, states = jax.lax.scan(
        body, s0, (Sc.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    states = states.transpose(1, 0, 2, 3, 4)             # (B,nc,H,N,P)

    # y_inter[t] = C_t . (exp(cum_t) * S_chunk_in)
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cc, jnp.exp(cum), states)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final


def apply_mamba2(p: Params, cfg: ModelConfig, xin: jnp.ndarray, *,
                 mesh=None, cache: Optional[Params] = None
                 ) -> Tuple[jnp.ndarray, Optional[Params]]:
    dtype = cdtype(cfg)
    B, S, D = xin.shape
    di, N, H, P = (cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_head_dim)

    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["ssm_in"]["w"].astype(dtype))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * G * N]
    dt_raw = zxbcdt[..., -H:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    prev = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(dtype),
                                 p["conv_b"].astype(dtype), prev)
    x = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di: di + G * N].astype(jnp.float32)
    Cm = xbc[..., di + G * N:].astype(jnp.float32)

    x = shd.constrain(x, mesh, shd.DP, None, shd.TP, None)

    if cache is None:
        y, _ = _ssd_chunked(x.astype(jnp.float32), dt, A, Bm, Cm,
                            min(cfg.ssm_chunk, S))
        new_cache = None
    else:
        # single-step: s' = exp(dt A) s + dt B x^T ; y = C . s'
        s = cache["state"].astype(jnp.float32)           # (B,H,N,P)
        da = jnp.exp(dt[:, 0, :] * A[None, :])           # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0, :], Bm[:, 0],
                         x[:, 0].astype(jnp.float32))
        s = da[:, :, None, None] * s + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], s)[:, None]
        new_cache = {"conv": new_conv, "state": s.astype(dtype)}

    y = y + p["ssm_D"].astype(jnp.float32)[None, None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["ssm_out"]["w"].astype(dtype))
    return out, new_cache


def init_mamba2_cache(cfg: ModelConfig, batch: int) -> Params:
    dtype = cdtype(cfg)
    conv_ch = cfg.d_inner_ssm + 2 * G * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), dtype),
    }

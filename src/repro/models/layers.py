"""Model building blocks: norms, RoPE, attention (GQA / MLA), MLPs, MoE.

Functional style: every block is an ``init_*(key, cfg) -> params`` plus
an ``apply`` that takes the params dict.  Params are stored fp32 and
cast to the compute dtype inside apply (MaxText convention: fp32 master
+ bf16 compute).

Attention supports three modes through one code path:
  * train/prefill: full-sequence causal (or bidirectional/cross),
    q-chunked online-softmax scan so peak memory is
    O(chunk x seq) not O(seq^2) — the XLA-level analogue of flash
    attention, compiles on any backend and keeps the dry-run memory
    analysis honest;
  * decode: single query position against a (possibly windowed) cache,
    masked beyond the current length.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import sharding as shd

Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# norms / embeddings / rope
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x (..., S, H, hd), pos (..., S) int32 -> rotated, same dtype."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., : hd // 2].astype(jnp.float32)
    x2 = x[..., hd // 2:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def _dense(key, fan_in: int, *shape) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(fan_in)
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# core attention math (shared by GQA and MLA)
# ---------------------------------------------------------------------------

def _gqa_scores_softmax_out(q, k, v, mask, scale):
    """q (B,Sq,H,hd), k/v (B,Sk,KVH,hd[v]); grouped-query einsum.

    Under the ``bf16scores`` perf flag the two big materialized
    tensors (scores, weights) stay bf16 — the MXU accumulates in f32
    either way, and the softmax maths runs in f32 inside the fusion —
    halving the attention HBM traffic.
    """
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    q = q.reshape(B, Sq, KVH, G, hd)
    if shd.flag("bf16scores"):
        scores = jnp.einsum("bqkgh,bskh->bkgqs",
                            q.astype(jnp.bfloat16),
                            k.astype(jnp.bfloat16)) * scale
        scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(jnp.bfloat16)
    else:
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      window: int = 0, q_offset: int = 0):
    """Q-chunked attention: scan over query chunks, full KV per chunk.

    Peak intermediate is (B, KVH, G, chunk, Sk) — memory-bounded for
    long sequences, trivially remat-able, compiles on all backends.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    if Sq <= chunk:
        mask = _attn_mask(Sq, Sk, causal, window, q_offset)
        return _gqa_scores_softmax_out(q, k, v, mask, scale)
    assert Sq % chunk == 0
    n = Sq // chunk
    qs = q.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    if shd.flag("flashvjp"):
        # hand-written VJP: backward recomputes scores per chunk and
        # never stacks them (see flash_attention above)
        return flash_attention(q, k, v, causal, window, q_offset, chunk)

    def chunk_out(ci, qc, kk, vv):
        mask = _attn_mask_dyn(chunk, Sk, causal, window,
                              q_offset + ci * chunk)
        return _gqa_scores_softmax_out(qc, kk, vv, mask, scale)

    def body(carry, args):
        ci, qc = args
        return carry, chunk_out(ci, qc, k, v)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# flash attention (custom VJP, XLA-level)
# ---------------------------------------------------------------------------
#
# ``chunked_attention`` under plain autodiff stacks every q-chunk's
# (chunk x Sk) score matrix in HBM as a scan residual — O(Sq x Sk)
# traffic and memory, exactly what chunking was meant to avoid.  The
# hand-written VJP below is the flash-attention recipe at the XLA
# level: forward saves only (out, rowmax m, rowsum l); backward
# recomputes scores chunk-by-chunk and contracts them immediately into
# dq/dk/dv, so no score tensor is ever stacked.  Enabled by the
# ``flashvjp`` perf flag; a Pallas TPU kernel with the same contract
# lives in kernels/flash.py for the hardware path.

def _score_dtype(like):
    """Materialized score dtype: bf16 under the flag (f32 softmax
    maths still happens in-register after the fused upcast)."""
    return jnp.bfloat16 if shd.flag("bf16scores") else jnp.float32


def _flash_chunk_fwd(qc, k, v, mask, scale):
    """One q-chunk: returns (out, m, l); shapes (B,KVH,G,C,*)."""
    f32 = jnp.float32
    sd = _score_dtype(qc)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qc.astype(sd), k.astype(sd),
                   preferred_element_type=sd) * scale
    s = jnp.where(mask, s.astype(f32), -1e30)
    m = jnp.max(s, axis=-1)                          # (B,KVH,G,C)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v)
    out = out / l[..., None].astype(v.dtype)
    return out, m, l


def _flash_args(q, k, v, causal, window, q_offset, chunk):
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    n = max(1, Sq // chunk)
    qs = q.reshape(B, n, Sq // n, KVH, G, hd).transpose(1, 0, 3, 4, 2, 5)
    return qs, n, (B, Sq, H, hd, KVH, G)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool, window: int, q_offset: int,
                    chunk: int):
    """q (B,Sq,H,hd), k/v (B,Sk,KVH,*): chunked, never stacks scores."""
    out, _, _ = _flash_fwd(q, k, v, causal, window, q_offset, chunk)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, chunk):
    scale = 1.0 / np.sqrt(q.shape[-1])
    qs, n, (B, Sq, H, hd, KVH, G) = _flash_args(
        q, k, v, causal, window, q_offset, chunk)
    Sk = k.shape[1]
    C = Sq // n

    def body(_, args):
        ci, qc = args                            # qc (B,KVH,G,C,hd)
        mask = _attn_mask_dyn(C, Sk, causal, window,
                              q_offset + ci * C)[:, :, :, None]
        o, m, l = _flash_chunk_fwd(qc.transpose(0, 3, 1, 2, 4)
                                   .reshape(B, C, H, hd)
                                   .reshape(B, C, KVH, G, hd),
                                   k, v, mask[0], scale)
        return None, (o, m, l)

    _, (outs, ms, ls) = jax.lax.scan(body, None, (jnp.arange(n), qs))
    # outs (n,B,KVH,G,C,hdv) -> (B,Sq,H,hdv)
    hdv = v.shape[-1]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hdv)
    return out, ms, ls


def _flash_vjp_fwd(q, k, v, causal, window, q_offset, chunk):
    out, ms, ls = _flash_fwd(q, k, v, causal, window, q_offset, chunk)
    return out, (q, k, v, out, ms, ls)


def _flash_vjp_bwd(causal, window, q_offset, chunk, res, g):
    q, k, v, out, ms, ls = res
    scale = 1.0 / np.sqrt(q.shape[-1])
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    hdv = v.shape[-1]
    n = ms.shape[0]
    C = Sq // n
    f32 = jnp.float32

    qs = q.reshape(B, n, C, KVH, G, hd).transpose(1, 0, 3, 4, 2, 5)
    gs = g.reshape(B, n, C, KVH, G, hdv).transpose(1, 0, 3, 4, 2, 5)
    os_ = out.reshape(B, n, C, KVH, G, hdv).transpose(1, 0, 3, 4, 2, 5)

    sd = _score_dtype(q)

    def body(carry, args):
        dk, dv = carry
        ci, qc, gc, oc, m, l = args              # (B,KVH,G,C,*)
        mask = _attn_mask_dyn(C, Sk, causal, window,
                              q_offset + ci * C)[0, :, :, None]
        s = jnp.einsum("bkgch,bskh->bkgcs", qc.astype(sd), k.astype(sd),
                       preferred_element_type=sd) * scale
        s = jnp.where(mask, s.astype(f32), -1e30)
        p = jnp.exp(s - m[..., None]) / l[..., None]      # (B,KVH,G,C,Sk)
        dp = jnp.einsum("bkgch,bskh->bkgcs", gc.astype(sd), v.astype(sd),
                        preferred_element_type=sd).astype(f32)
        D = jnp.sum(gc.astype(f32) * oc.astype(f32), axis=-1)  # (B,KVH,G,C)
        ds = p * (dp - D[..., None]) * scale
        dqc = jnp.einsum("bkgcs,bskh->bkgch", ds.astype(q.dtype), k)
        dk = dk + jnp.einsum("bkgcs,bkgch->bskh", ds.astype(q.dtype), qc)
        dv = dv + jnp.einsum("bkgcs,bkgch->bskh",
                             p.astype(v.dtype), gc)
        return (dk, dv), dqc

    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    (dk, dv), dqs = jax.lax.scan(
        body, (dk0, dv0), (jnp.arange(n), qs, gs, os_, ms, ls))
    dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return dq, dk, dv


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _attn_mask(Sq, Sk, causal, window, q_offset):
    if not causal:
        return jnp.ones((1, 1, 1, Sq, Sk), bool)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m[None, None, None]


def _attn_mask_dyn(Sq, Sk, causal, window, q_offset):
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    if not causal:
        return jnp.ones((1, 1, 1, Sq, Sk), bool)
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m[None, None, None]


def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """Single-position decode: q (B,1,H,hd) vs cache (B,Smax,KVH,*).

    Masks cache positions >= cur_len (and outside the window).
    """
    B, _, H, hd = q.shape
    Smax = k_cache.shape[1]
    scale = 1.0 / np.sqrt(hd)
    kpos = jnp.arange(Smax)
    valid = kpos < cur_len
    if window > 0:
        valid &= kpos >= jnp.maximum(cur_len - window, 0)
    mask = valid[None, None, None, None, :]
    return _gqa_scores_softmax_out(q, k_cache, v_cache, mask, scale)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    D, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": {"w": _dense(ks[0], D, D, H * hd)},
        "wk": {"w": _dense(ks[1], D, D, KVH * hd)},
        "wv": {"w": _dense(ks[2], D, D, KVH * hd)},
        "wo": {"w": _dense(ks[3], H * hd, H * hd, D)},
    }
    if cfg.qkv_bias:
        p["wq"]["bias"] = jnp.zeros((H * hd,), jnp.float32)
        p["wk"]["bias"] = jnp.zeros((KVH * hd,), jnp.float32)
        p["wv"]["bias"] = jnp.zeros((KVH * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _proj(p: Params, x, n_heads, hd, dtype):
    w = p["w"].astype(dtype)
    y = jnp.einsum("bsd,dh->bsh", x, w)
    if "bias" in p:
        y = y + p["bias"].astype(dtype)
    return y.reshape(*x.shape[:-1], n_heads, hd)


def apply_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                    mesh=None, causal: bool = True, window: int = 0,
                    positions: Optional[jnp.ndarray] = None,
                    cache: Optional[Params] = None,
                    kv_src: Optional[jnp.ndarray] = None,
                    use_rope: bool = True
                    ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """One attention layer.

    cache: {"k","v" (B,Smax,KVH,hd), "len" ()} — decode mode when given
    and x has seq 1 (self-attn) — or reused cross-attn K/V.
    kv_src: encoder output for cross attention (causal=False).
    """
    dtype = cdtype(cfg)
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(p["wq"], x, H, hd, dtype)
    src = x if kv_src is None else kv_src.astype(dtype)
    k = _proj(p["wk"], src, KVH, hd, dtype)
    v = _proj(p["wv"], src, KVH, hd, dtype)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)

    q = shd.constrain(q, mesh, shd.DP, None, shd.TP, None)
    k = shd.constrain(k, mesh, shd.DP, None, shd.TP, None)
    v = shd.constrain(v, mesh, shd.DP, None, shd.TP, None)

    new_cache = None
    if cache is not None and kv_src is None:
        cur = cache["len"]
        if use_rope:
            pos = jnp.full((B, S), cur, jnp.int32) if positions is None \
                else positions
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        if window > 0:
            slot = jnp.mod(cur, cache["k"].shape[1])
        else:
            slot = cur
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(dtype), slot, 1)
        # windowed ring buffer: the cache itself is window-sized, so
        # masking by effective length suffices (positions wrap).
        eff_len = jnp.minimum(cur + 1, kc.shape[1]) if window > 0 \
            else cur + 1
        out = decode_attention(q, kc, vc, eff_len)
        new_cache = {"k": kc, "v": vc, "len": cur + 1}
    else:
        if use_rope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                   (B, S)) if positions is None \
                else positions
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        out = chunked_attention(q, k, v, causal=causal, window=window)

    out = shd.constrain(out, mesh, shd.DP, None, shd.TP, None)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                   p["wo"]["w"].astype(dtype))
    return y, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    window: int = 0) -> Params:
    size = min(window, max_len) if window > 0 else max_len
    dtype = cdtype(cfg)
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "len": jnp.asarray(0, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_gelu:
        return {"wi": {"w": _dense(ks[0], D, D, F),
                       "bias": jnp.zeros((F,), jnp.float32)},
                "wdown": {"w": _dense(ks[1], F, F, D),
                          "bias": jnp.zeros((D,), jnp.float32)}}
    return {"wi": {"w": _dense(ks[0], D, D, F)},
            "wg": {"w": _dense(ks[1], D, D, F)},
            "wdown": {"w": _dense(ks[2], F, F, D)}}


def apply_mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
    dtype = cdtype(cfg)
    if cfg.mlp_gelu:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"]["w"].astype(dtype))
        h = jax.nn.gelu(h + p["wi"]["bias"].astype(dtype))
        h = shd.constrain(h, mesh, shd.DP, None, shd.TP)
        return jnp.einsum("bsf,fd->bsd", h,
                          p["wdown"]["w"].astype(dtype)) \
            + p["wdown"]["bias"].astype(dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["wg"]["w"].astype(dtype))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"]["w"].astype(dtype))
    h = jax.nn.silu(g) * h
    h = shd.constrain(h, mesh, shd.DP, None, shd.TP)
    return jnp.einsum("bsf,fd->bsd", h, p["wdown"]["w"].astype(dtype))


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p = {"embed": {"w": _dense(ks[0], cfg.d_model,
                               cfg.vocab_size, cfg.d_model)}}
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": _dense(ks[1], cfg.d_model,
                                    cfg.d_model, cfg.vocab_size)}
    return p


def embed_tokens(p: Params, cfg: ModelConfig, tokens: jnp.ndarray):
    return p["embed"]["w"].astype(cdtype(cfg))[tokens]


def unembed(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = p["embed"]["w"].astype(cdtype(cfg)).T
    else:
        w = p["unembed"]["w"].astype(cdtype(cfg))
    return jnp.einsum("bsd,dv->bsv", x, w)

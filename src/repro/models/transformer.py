"""Transformer assembly: scanned layer stacks, loss, and decode step.

The layer stack is organized as (prologue layers) + (pattern x repeats)
where the pattern is a tuple of ``LayerSpec``s.  The repeats are
``lax.scan``-ed over stacked parameters — one trace regardless of depth
(compile time and HLO size stay flat from smollm-30L to grok-64L) — and
the scan body is ``jax.checkpoint``-ed so only repeat boundaries are
saved (activation memory = n_repeats x hidden, sequence-sharded).

Supports decoder-only LMs (with optional modality-frontend embeddings
prepended) and encoder-decoder (whisper) through the same machinery.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import sharding as shd
from .config import LayerSpec, ModelConfig
from .layers import (Params, apply_attention, apply_mlp, cdtype,
                     embed_tokens, init_attention, init_attn_cache,
                     init_embed, init_layernorm, init_mlp, init_rmsnorm,
                     layer_norm, rms_norm, sinusoid_pos, unembed)
from .mla import apply_mla, init_mla, init_mla_cache
from .moe import apply_moe, init_moe
from .ssm import apply_mamba2, init_mamba2, init_mamba2_cache


def _norm(cfg: ModelConfig):
    return (init_layernorm, layer_norm) if cfg.use_layernorm \
        else (init_rmsnorm, rms_norm)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    init_n, _ = _norm(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_n(cfg.d_model)}
    if spec.mixer == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif spec.mixer == "mla":
        p["attn"] = init_mla(ks[0], cfg)
    elif spec.mixer == "mamba2":
        p["mixer"] = init_mamba2(ks[0], cfg)
    if spec.cross:
        p["norm_cross"] = init_n(cfg.d_model)
        p["cross"] = init_attention(ks[2], cfg)
    if spec.mlp != "none":
        p["norm2"] = init_n(cfg.d_model)
        if spec.mlp == "moe":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg)
    return p


def init_decoder(key, cfg: ModelConfig) -> Params:
    init_n, _ = _norm(cfg)
    ks = jax.random.split(key, 4 + len(cfg.prologue))
    params: Params = {"tok": init_embed(ks[0], cfg)}
    for i, spec in enumerate(cfg.prologue):
        params[f"pro{i}"] = _init_layer(ks[1 + i], cfg, spec)

    # stacked pattern repeats: init one repeat per scan index
    def one_repeat(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return {f"l{i}": _init_layer(kk[i], cfg, s)
                for i, s in enumerate(cfg.pattern)}

    rep_keys = jax.random.split(ks[-2], cfg.repeats)
    params["stack"] = jax.vmap(one_repeat)(rep_keys)
    params["final_norm"] = init_n(cfg.d_model)
    return params


def init_encoder(key, cfg: ModelConfig) -> Params:
    """Whisper-style encoder: bidirectional attn + GELU mlp, scanned."""
    init_n, _ = _norm(cfg)
    spec = LayerSpec(mixer="attn", mlp="dense")
    ks = jax.random.split(key, cfg.n_encoder_layers)
    stack = jax.vmap(lambda k: {"l0": _init_layer(k, cfg, spec)})(ks)
    return {"stack": stack, "final_norm": init_n(cfg.d_model)}


def init_model(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = init_decoder(k1, cfg)
    if cfg.is_encoder_decoder:
        p["encoder"] = init_encoder(k2, cfg)
    return p


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer(lp: Params, cfg: ModelConfig, spec: LayerSpec,
                 x: jnp.ndarray, *, mesh, causal: bool,
                 cache: Optional[Params], cross_kv: Optional[Params],
                 positions) -> Tuple[jnp.ndarray, Optional[Params], Any]:
    _, norm = _norm(cfg)
    aux = None
    h = norm(lp["norm1"], x, cfg.norm_eps)
    sub_cache = None if cache is None else cache.get("mixer")
    if spec.mixer == "attn":
        mix, new_sub = apply_attention(
            lp["attn"], cfg, h, mesh=mesh, causal=causal,
            window=spec.window, cache=sub_cache, positions=positions,
            use_rope=cfg.use_rope)
    elif spec.mixer == "mla":
        mix, new_sub = apply_mla(lp["attn"], cfg, h, mesh=mesh,
                                 cache=sub_cache, positions=positions)
    else:
        mix, new_sub = apply_mamba2(lp["mixer"], cfg, h, mesh=mesh,
                                    cache=sub_cache)
    x = x + mix
    new_cache: Optional[Params] = None
    if cache is not None:
        new_cache = {"mixer": new_sub}

    if spec.cross:
        h = norm(lp["norm_cross"], x, cfg.norm_eps)
        # decode: precomputed cross K/V in the cache; train/prefill:
        # fresh projection of the encoder output.
        if cache is not None and "cross" in cache:
            mix, _ = _cross_from_cache(lp["cross"], cfg, h,
                                       cache["cross"])
        else:
            mix, _ = apply_attention(lp["cross"], cfg, h, mesh=mesh,
                                     causal=False, kv_src=cross_kv,
                                     use_rope=False)
        x = x + mix

    if spec.mlp != "none":
        h = norm(lp["norm2"], x, cfg.norm_eps)
        if spec.mlp == "moe":
            out, aux = apply_moe(lp["moe"], cfg, h, mesh=mesh)
        else:
            out = apply_mlp(lp["mlp"], cfg, h, mesh=mesh)
        x = x + out
    return x, new_cache, aux


def _cross_from_cache(p, cfg: ModelConfig, h, ck):
    """Decode-time cross attention against precomputed K/V."""
    from .layers import decode_attention, _proj
    dtype = cdtype(cfg)
    B, S, D = h.shape
    q = _proj(p["wq"], h, cfg.n_heads, cfg.head_dim, dtype)
    out = decode_attention(q, ck["k"], ck["v"], ck["k"].shape[1])
    y = jnp.einsum("bsh,hd->bsd",
                   out.reshape(B, S, cfg.n_heads * cfg.head_dim),
                   p["wo"]["w"].astype(dtype))
    return y, None


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def _stack_scan(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                mesh, causal: bool, caches: Optional[Params],
                cross_kv, positions, remat: bool,
                stack_key: str = "stack",
                pattern: Optional[Tuple[LayerSpec, ...]] = None):
    """Scan the stacked repeats; returns (x, new_caches, aux_sum)."""
    pattern = pattern or cfg.pattern

    def body(carry, xs):
        h, aux_acc = carry
        rep_params, rep_cache = xs
        new_rep_cache = {} if rep_cache is not None else None
        for i, spec in enumerate(pattern):
            sub = None if rep_cache is None else rep_cache[f"l{i}"]
            h, nc, aux = _apply_layer(
                rep_params[f"l{i}"], cfg, spec, h, mesh=mesh,
                causal=causal, cache=sub, cross_kv=cross_kv,
                positions=positions)
            if new_rep_cache is not None:
                new_rep_cache[f"l{i}"] = _keep_cross(nc, sub)
            if aux is not None:
                aux_acc = aux_acc + aux["lb_loss"] + 1e-3 * aux["z_loss"]
        h = shd.constrain(h, mesh, shd.DP, shd.TP, None)
        return (h, aux_acc), new_rep_cache

    if remat:
        body = jax.checkpoint(body)
    xs = (params[stack_key], caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.asarray(0.0)), xs)
    return x, new_caches, aux


def _keep_cross(nc, old):
    """Carry the (static) cross-attn K/V cache through scan steps."""
    if old is not None and "cross" in old:
        nc = dict(nc or {})
        nc["cross"] = old["cross"]
    return nc


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            mesh=None, remat: bool = True) -> Tuple[jnp.ndarray, Any]:
    """Training/prefill forward -> (logits, aux_loss).

    batch: tokens (B,S) [+ frontend (B,Tf,D)] [+ enc_frames (B,Te,D)].
    """
    tokens = batch["tokens"]
    x = embed_tokens(params["tok"], cfg, tokens)
    positions = None
    if cfg.n_frontend_tokens and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    if not cfg.use_rope:
        x = x + sinusoid_pos(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x = shd.constrain(x, mesh, shd.DP, shd.TP, None)

    cross_kv = None
    if cfg.is_encoder_decoder:
        cross_kv = encode(params, cfg, batch["enc_frames"], mesh=mesh,
                          remat=remat)

    aux_total = jnp.asarray(0.0)
    for i, spec in enumerate(cfg.prologue):
        x, _, aux = _apply_layer(params[f"pro{i}"], cfg, spec, x,
                                 mesh=mesh, causal=True, cache=None,
                                 cross_kv=cross_kv, positions=positions)
        if aux is not None:
            aux_total += aux["lb_loss"] + 1e-3 * aux["z_loss"]

    x, _, aux = _stack_scan(params, cfg, x, mesh=mesh, causal=True,
                            caches=None, cross_kv=cross_kv,
                            positions=positions, remat=remat)
    aux_total = aux_total + aux

    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.n_frontend_tokens and "frontend" in batch:
        x = x[:, batch["frontend"].shape[1]:]
    logits = unembed(params["tok"], cfg, x)
    return logits, aux_total


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray, *,
           mesh=None, remat: bool = True) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, Te, D)."""
    x = frames.astype(cdtype(cfg))
    x = x + sinusoid_pos(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    x = shd.constrain(x, mesh, shd.DP, shd.TP, None)
    x, _, _ = _stack_scan(params["encoder"], cfg, x, mesh=mesh,
                          causal=False, caches=None, cross_kv=None,
                          positions=None, remat=remat,
                          pattern=(LayerSpec(mixer="attn", mlp="dense"),))
    _, norm = _norm(cfg)
    return norm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            mesh=None, remat: bool = True):
    logits, aux = forward(params, cfg, batch, mesh=mesh, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.clip(mask.sum(), 1.0)
    loss = nll + 1e-2 * aux
    return loss, {"nll": nll, "aux": aux,
                  "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# decode / serve
# ---------------------------------------------------------------------------

def init_serve_cache(params: Params, cfg: ModelConfig, batch: int,
                     max_len: int, enc_out: Optional[jnp.ndarray] = None,
                     prefilled: int = 0) -> Params:
    """Allocate (optionally 'pre-filled') decode caches for all layers."""

    def one_layer(spec: LayerSpec) -> Params:
        c: Params = {}
        if spec.mixer == "attn":
            c["mixer"] = init_attn_cache(cfg, batch, max_len, spec.window)
        elif spec.mixer == "mla":
            c["mixer"] = init_mla_cache(cfg, batch, max_len)
        else:
            c["mixer"] = init_mamba2_cache(cfg, batch)
        # the position counter lives once in caches["pos"], not per layer
        c["mixer"].pop("len", None)
        return c

    def stack_caches(pattern, n):
        def rep(_):
            return {f"l{i}": one_layer(s) for i, s in enumerate(pattern)}
        return jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[rep(i) for i in range(n)]) if n > 1 else jax.tree.map(
                lambda x: x[None], rep(0))

    caches: Params = {
        "stack": stack_caches(cfg.pattern, cfg.repeats),
        "pro": [one_layer(s) for s in cfg.prologue],
        "pos": jnp.asarray(prefilled, jnp.int32),
    }
    if cfg.is_encoder_decoder and enc_out is not None \
            and any(s.cross for s in cfg.pattern):
        # precompute per-layer cross K/V once (the real serving path)
        from .layers import _proj
        dtype = cdtype(cfg)

        def cross_kv(cp):
            k = _proj(cp["wk"], enc_out.astype(dtype),
                      cfg.n_kv_heads, cfg.head_dim, dtype)
            v = _proj(cp["wv"], enc_out.astype(dtype),
                      cfg.n_kv_heads, cfg.head_dim, dtype)
            return {"k": k, "v": v}

        caches["stack_cross"] = {
            f"l{i}": jax.vmap(cross_kv)(params["stack"][f"l{i}"]["cross"])
            for i, s in enumerate(cfg.pattern) if s.cross}
    return caches


def serve_step(params: Params, cfg: ModelConfig, caches: Params,
               tokens: jnp.ndarray, *, mesh=None
               ) -> Tuple[jnp.ndarray, Params]:
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new caches)."""
    x = embed_tokens(params["tok"], cfg, tokens)
    if not cfg.use_rope:
        pe = sinusoid_pos(cfg.max_seq_len, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(
            pe, caches["pos"], 1, 0)[None].astype(x.dtype)
    pos = caches["pos"]

    new_pro = []
    for i, spec in enumerate(cfg.prologue):
        c = dict(caches["pro"][i])
        c["mixer"] = _with_len(c["mixer"], pos)
        x, nc, _ = _apply_layer(params[f"pro{i}"], cfg, spec, x,
                                mesh=mesh, causal=True, cache=c,
                                cross_kv=None, positions=None)
        new_pro.append(_strip_len(nc))

    def body(carry, xs):
        h = carry
        rep_params, rep_cache, rep_cross = xs
        new_rep = {}
        for i, spec in enumerate(cfg.pattern):
            c = dict(rep_cache[f"l{i}"])
            c["mixer"] = _with_len(c["mixer"], pos)
            if rep_cross is not None and f"l{i}" in rep_cross:
                c["cross"] = rep_cross[f"l{i}"]
            h, nc, _ = _apply_layer(rep_params[f"l{i}"], cfg, spec, h,
                                    mesh=mesh, causal=True, cache=c,
                                    cross_kv=None, positions=None)
            new_rep[f"l{i}"] = _strip_len(nc)
        return h, new_rep

    xs = (params["stack"], caches["stack"], caches.get("stack_cross"))
    x, new_stack = jax.lax.scan(body, x, xs)

    _, norm = _norm(cfg)
    x = norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["tok"], cfg, x)
    new_caches = dict(caches)
    new_caches["stack"] = new_stack
    new_caches["pro"] = new_pro
    new_caches["pos"] = pos + 1
    return logits, new_caches


def _with_len(c: Params, pos) -> Params:
    c = dict(c)
    if "k" in c or "c_kv" in c:
        c["len"] = pos
    return c


def _strip_len(nc: Optional[Params]) -> Params:
    out = dict(nc["mixer"]) if nc else {}
    out.pop("len", None)
    return {"mixer": out}

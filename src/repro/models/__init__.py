from .config import LayerSpec, ModelConfig, param_count
from .transformer import (encode, forward, init_model, init_serve_cache,
                          loss_fn, serve_step)

__all__ = ["LayerSpec", "ModelConfig", "param_count", "encode",
           "forward", "init_model", "init_serve_cache", "loss_fn",
           "serve_step"]

"""Multi-head Latent Attention (DeepSeek-V2), TPU-adapted.

MLA compresses K/V into a ``kv_lora_rank``-dim latent c_kv plus a
shared ``qk_rope_dim`` decoupled-RoPE key.  The decode cache stores
only (c_kv, k_rope) — (rank + rope) floats per position instead of
2 * H * hd — which is the whole point: the 32k-cache decode cell for
deepseek-v2-lite carries 512+64 = 576 f per token vs 16*2*192 = 6144.

Cache-efficient decode uses the "absorbed" formulation: q_nope is
mapped through W_UK into latent space so attention scores are computed
directly against the cached latents, and W_UV is applied after the
weighted sum — no per-step decompression of the whole cache.

Prefill/train uses the naive (decompress) formulation, which is
matmul-dominant and MXU-friendly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import sharding as shd
from .layers import (Params, _dense, apply_rope, cdtype, chunked_attention,
                     rms_norm, init_rmsnorm)


def init_mla(key, cfg: ModelConfig) -> Params:
    D, H = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 5)
    return {
        # queries: full-rank projection to H * (nope + rope)
        "wq": {"w": _dense(ks[0], D, D, H * (dn + dr))},
        # kv down-projection: D -> r latent (+ shared rope key)
        "kv_a": {"w": _dense(ks[1], D, D, r + dr)},
        "kv_norm": init_rmsnorm(r),
        # kv up-projection: r -> H * (nope_k + v)
        "kv_b": {"w": _dense(ks[2], r, r, H * (dn + dv))},
        "wo": {"w": _dense(ks[3], H * dv, H * dv, D)},
    }


def _split_qb(q, H, dn, dr):
    B, S, _ = q.shape
    q = q.reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]


def apply_mla(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
              mesh=None, positions: Optional[jnp.ndarray] = None,
              cache: Optional[Params] = None
              ) -> Tuple[jnp.ndarray, Optional[Params]]:
    dtype = cdtype(cfg)
    B, S, D = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    scale = 1.0 / np.sqrt(dn + dr)

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]["w"].astype(dtype))
    q_nope, q_rope = _split_qb(q, H, dn, dr)
    kv = jnp.einsum("bsd,dh->bsh", x, p["kv_a"]["w"].astype(dtype))
    c_kv, k_rope = kv[..., :r], kv[..., r:]
    c_kv = rms_norm(p["kv_norm"], c_kv, cfg.norm_eps)

    if cache is not None:
        cur = cache["len"]
        pos = jnp.full((B, S), cur, jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                               (B, S)) if positions is None else positions
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], pos,
                        cfg.rope_theta)[..., 0, :]       # shared head

    wkv_b = p["kv_b"]["w"].astype(dtype).reshape(r, H, dn + dv)
    wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]

    if cache is None:
        # naive decompress: k_nope/v from latents, standard GQA-1 attn
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, wk_b)
        v = jnp.einsum("bsr,rhd->bshd", c_kv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qf = shd.constrain(qf, mesh, shd.DP, None, shd.TP, None)
        k = shd.constrain(k, mesh, shd.DP, None, shd.TP, None)
        out = chunked_attention(qf, k, v, causal=True)
        new_cache = None
    else:
        # absorbed decode: score against cached latents directly
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(dtype), cache["len"], 1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(dtype), cache["len"], 1)
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c,
                     "len": cache["len"] + 1}
        # q_nope (B,1,H,dn) @ wk_b (r,H,dn) -> latent-space queries
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)  # (B,1,H,r)
        Smax = ckv_c.shape[1]
        scores = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                             ckv_c.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst",
                               q_rope.astype(jnp.float32),
                               kr_c.astype(jnp.float32))) * scale
        valid = jnp.arange(Smax)[None, None, None, :] < (cache["len"] + 1)
        w = jax.nn.softmax(jnp.where(valid, scores, -1e30), axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", w.astype(jnp.float32),
                         ckv_c.astype(jnp.float32))      # (B,1,H,r)
        out = jnp.einsum("bshr,rhd->bshd", ctx.astype(dtype), wv_b)

    out = out.reshape(B, S, H * dv)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"]["w"].astype(dtype))
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = cdtype(cfg)
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "len": jnp.asarray(0, jnp.int32),
    }

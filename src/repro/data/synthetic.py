"""Data pipeline: synthetic ChEMBL-like MF data + LM token streams.

The paper benchmarks on a ChEMBL IC50 extraction (compounds x proteins,
~1M x thousands, very sparse, ECFP fingerprints as side info).  Offline
we generate a statistically similar planted-low-rank matrix: power-law
row occupancy (compounds tested against few targets), binary sparse
fingerprints correlated with the latent factors so the Macau lift is
actually measurable.

The LM side is an infinite deterministic token stream (seeded,
restartable from any step index — checkpoint/resume does not need to
save data-pipeline state, just the step).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse import SparseMatrix, from_coo


def chembl_like(seed: int, n_compounds: int = 2000, n_proteins: int = 200,
                density: float = 0.02, rank: int = 16,
                noise: float = 0.4, n_features: int = 128,
                feature_noise: float = 0.5,
                ) -> Tuple[SparseMatrix, Tuple, np.ndarray]:
    """Synthetic compound-activity data.

    Returns (train SparseMatrix, (i,j,v) test triplets, fingerprints F).
    Row occupancy is power-law (like real assay data); fingerprints are
    binarized projections of the true compound factors.
    """
    rng = np.random.default_rng(seed)
    U = rng.normal(size=(n_compounds, rank)).astype(np.float32)
    V = rng.normal(size=(n_proteins, rank)).astype(np.float32)

    # power-law tests-per-compound
    w = (1.0 / np.arange(1, n_compounds + 1) ** 0.7)
    w = w[rng.permutation(n_compounds)]
    p_row = w / w.sum()
    nnz = int(density * n_compounds * n_proteins)
    i = rng.choice(n_compounds, size=3 * nnz, p=p_row)
    j = rng.integers(0, n_proteins, size=3 * nnz)
    ij = np.unique(np.stack([i, j], 1), axis=0)
    ij = ij[rng.permutation(len(ij))[:nnz]]
    i, j = ij[:, 0], ij[:, 1]
    v = np.einsum("ek,ek->e", U[i], V[j]) + noise * rng.normal(
        size=len(i)).astype(np.float32)

    # ECFP-like binary fingerprints correlated with the latent factors
    proj = rng.normal(size=(rank, n_features)).astype(np.float32)
    F = (U @ proj + feature_noise * rng.normal(
        size=(n_compounds, n_features)) > 0).astype(np.float32)

    n_test = max(1, nnz // 10)
    test = (i[:n_test], j[:n_test], v[:n_test].astype(np.float32))
    tr = slice(n_test, None)
    mat = from_coo(i[tr], j[tr], v[tr].astype(np.float32),
                   (n_compounds, n_proteins))
    return mat, test, F


class TokenStream:
    """Deterministic, seekable synthetic token stream for LM training.

    Markov-chain-ish tokens so the loss actually decreases (the model
    can learn bigram structure) — a pure-uniform stream would give a
    flat loss and hide training bugs.
    """

    def __init__(self, vocab_size: int, seed: int = 0,
                 n_states: int = 64):
        self.vocab = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse-ish bigram transition structure over a state space
        self._succ = rng.integers(0, vocab_size,
                                  size=(n_states, 8)).astype(np.int32)
        self.n_states = n_states

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        state = rng.integers(0, self.n_states, size=(batch,))
        out = np.empty((batch, seq + 1), np.int32)
        for t in range(seq + 1):
            choice = rng.integers(0, 8, size=(batch,))
            tok = self._succ[state, choice]
            out[:, t] = tok
            state = tok % self.n_states
        return out


def make_lm_batch(stream: TokenStream, step: int, batch: int, seq: int,
                  frontend_tokens: int = 0, d_model: int = 0,
                  enc_frames: int = 0) -> Dict[str, jnp.ndarray]:
    """One training batch: tokens/labels (+ stub modality embeddings)."""
    toks = stream.batch(step, batch, seq)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if frontend_tokens:
        rng = np.random.default_rng((stream.seed, step, 7))
        out["frontend"] = jnp.asarray(
            rng.normal(size=(batch, frontend_tokens, d_model))
            .astype(np.float32))
    if enc_frames:
        rng = np.random.default_rng((stream.seed, step, 11))
        out["enc_frames"] = jnp.asarray(
            rng.normal(size=(batch, enc_frames, d_model))
            .astype(np.float32))
    return out


def lm_batches(stream: TokenStream, start_step: int, batch: int,
               seq: int, **kw) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield make_lm_batch(stream, step, batch, seq, **kw)
        step += 1

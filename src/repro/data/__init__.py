from .synthetic import (chembl_like, lm_batches, make_lm_batch,
                        TokenStream)

__all__ = ["chembl_like", "lm_batches", "make_lm_batch", "TokenStream"]

"""Render the roofline table (EXPERIMENTS.md §Roofline) from the
dry-run records in ``results/dryrun/*.json``.

One row per (arch x shape x mesh x variant): the three roofline terms,
the dominant one, useful-FLOP ratio and roofline fraction — all
derived from the compiled artifact, never measured (CPU container).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

COLS = ("arch", "shape", "mesh", "variant", "compute_s", "memory_s",
        "collective_s", "dominant", "useful_flop_ratio",
        "roofline_fraction")


def load(variant: str | None = None) -> List[Dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def _fmt(r: Dict, col: str) -> str:
    v = r.get(col, "")
    if isinstance(v, float):
        return f"{v:.3e}" if (v and abs(v) < 1e-2) else f"{v:.3f}"
    return str(v)


def markdown(recs: List[Dict]) -> str:
    ok = [r for r in recs if "compute_s" in r]
    skip = [r for r in recs if "skipped" in r]
    fail = [r for r in recs if "error" in r]
    lines = ["| " + " | ".join(COLS) + " |",
             "|" + "---|" * len(COLS)]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("variant", ""))):
        lines.append("| " + " | ".join(_fmt(r, c) for c in COLS) + " |")
    for r in skip:
        lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} "
                     f"| — | skipped: {r['skipped']} |" + " |" * 4)
    for r in fail:
        lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} "
                     f"| — | ERROR {r['error'][:60]} |" + " |" * 4)
    return "\n".join(lines)


def run():
    from .common import emit
    recs = load()
    ok = [r for r in recs if "compute_s" in r]
    if not ok:
        emit("roofline", "records", "0", "cells",
             "run launch/dryrun.py first")
        return
    by_dom: Dict[str, int] = {}
    for r in ok:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    emit("roofline", "cells_compiled", str(len(ok)), "cells",
         f"dominant terms: {by_dom}")
    worst = min(ok, key=lambda r: r.get("roofline_fraction", 1.0))
    emit("roofline", "worst_fraction",
         f"{worst['roofline_fraction']:.4f}", "frac",
         f"{worst['arch']}/{worst['shape']}/{worst['mesh']}")
    best = max(ok, key=lambda r: r.get("roofline_fraction", 0.0))
    emit("roofline", "best_fraction",
         f"{best['roofline_fraction']:.4f}", "frac",
         f"{best['arch']}/{best['shape']}/{best['mesh']}")


if __name__ == "__main__":
    print(markdown(load()))

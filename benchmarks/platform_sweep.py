"""Paper Fig. 4 analogue: BMF / Macau-dense / Macau-sparse data types.

The paper sweeps three algorithms x three CPU platforms (Xeon, Xeon
Phi, ARM) and finds the gap largest for sparse data (cache
hierarchy).  We have one host platform; the axis that survives is the
*data type* one: BMF (sparse R), Macau with dense side info, Macau
with sparse(-style binary) side info — same sweep count, same sizes.
The TPU-platform column is *derived*, not measured: the dry-run
roofline (EXPERIMENTS.md) plays the role of the second platform.
"""
from __future__ import annotations

import numpy as np

from repro.core import (AdaptiveGaussian, FixedGaussian, TrainSession,
                        init_state, gibbs_step)
from repro.data.synthetic import chembl_like

from .common import emit, time_fn


def _session(mat, test, F=None):
    s = TrainSession(num_latent=16, burnin=0, nsamples=1, seed=0)
    s.add_train_and_test(mat, test=test, noise=FixedGaussian(5.0))
    if F is not None:
        s.add_side_info(0, F)
    model, data = s._build()
    return model, data, init_state(model, data, 0)


def run(n_compounds: int = 2000, n_proteins: int = 200):
    mat, test, F = chembl_like(0, n_compounds, n_proteins,
                               n_features=128)
    Fd = F + 0.01 * np.random.default_rng(3).normal(
        size=F.shape).astype(np.float32)        # dense-valued variant

    for name, side, notes in (
            ("bmf_sparse_R", None, "no side info"),
            ("macau_dense_F", Fd, "dense side info 128 feat"),
            ("macau_sparse_F", F, "binary ECFP-like side info")):
        model, data, state = _session(mat, test, side)
        t = time_fn(lambda m=model, d=data, s=state:
                    gibbs_step(m, d, s)[0])
        emit("platform_sweep", name, f"{t:.4f}", "s/sweep", notes)

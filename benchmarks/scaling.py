"""Paper Fig. 3 x-axis analogue: scaling with worker count.

The paper scales OpenMP threads across 36 cores (and GASPI to 2048
cores).  Offline analogue: shard the Gibbs sweep over N XLA host-
platform devices with the production ``shard_map``/pjit path
(``core/distributed.py``) and measure one sweep at N = 1, 2, 4, 8.
Device count is locked at jax init, so every N runs in a fresh
subprocess.  Strong scaling on a fixed CPU is bounded by the shared
physical cores — the figure of merit is that the *distributed step
itself* (the code path the 512-chip dry-run proves) runs and stays
flat-ish rather than degrading with partitioning overhead.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%d")
import jax, numpy as np
sys.path.insert(0, "src")
from repro.core import FixedGaussian, TrainSession, init_state
from repro.core.distributed import make_distributed_step
from repro.data.synthetic import chembl_like

n_dev = %d
mat, test, _ = chembl_like(0, 4096, 256)
s = TrainSession(num_latent=16, burnin=0, nsamples=1, seed=0)
s.add_train_and_test(mat, test=test, noise=FixedGaussian(5.0))
model, data = s._build()
state = init_state(model, data, 0)
mesh = jax.make_mesh((n_dev,), ("data",))
step, ds, ss = make_distributed_step(model, mesh, data, state)
out = step(data, state)
jax.block_until_ready(out)
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    out = step(data, out[0])
    jax.block_until_ready(out)
    ts.append(time.perf_counter() - t0)
print(json.dumps({"n_dev": n_dev, "t": sorted(ts)[1]}))
"""


def run(device_counts=(1, 2, 4, 8)):
    results = {}
    for n in device_counts:
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD % (n, n)],
            capture_output=True, text=True, cwd=os.getcwd(),
            env={**os.environ, "PYTHONPATH": "src"}, timeout=600)
        if proc.returncode != 0:
            emit("scaling", f"devices_{n}", "ERROR", "s/sweep",
                 proc.stderr.strip().splitlines()[-1][:100]
                 if proc.stderr.strip() else "no stderr")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        results[n] = rec["t"]
        base = results.get(device_counts[0], rec["t"])
        emit("scaling", f"devices_{n}", f"{rec['t']:.4f}", "s/sweep",
             f"t1/tN = {base / rec['t']:.2f} (shared phys cores)")
    return results

"""Serving latency/throughput: RecommendServer under offered load.

The paper's payoff is prediction AT SCALE (arXiv:1904.02514 §1) — the
question for the serving layer is not just per-call cost but how
latency degrades as concurrent load grows.  This benchmark drives
``launch.serve.RecommendServer`` open-loop: requests arrive on a fixed
schedule at each offered QPS level (arrival times are set BEFORE the
run, so a slow server cannot throttle its own offered load), mixing
warm-user and cold-start queries with per-request exclusions, and we
record per-request latency from the SCHEDULED arrival to completion —
queueing delay included, the number a client would see.

Reported per QPS level: p50/p99 latency, achieved throughput, and the
batch occupancy the slot runtime reached.  Results land as JSON under
``results/serving/`` next to the dry-run records::

    PYTHONPATH=src python -m benchmarks.serve_latency [--quick]

Container is CPU-only: absolute latencies are CPU-XLA numbers; the
paper-comparable quantity is the SHAPE of the latency/QPS curve
(flat until the knee, then queueing blow-up) and the batching lift
over slots=1.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import (AdaptiveGaussian, ModelBuilder,
                        PredictSession, from_coo)
from repro.launch.serve import RecommendServer
from repro.obs import (Histogram, clock, latency_buckets,
                       percentile_summary)

from .common import emit

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "results", "serving")


def _build_store(save_dir: str, n_users: int, n_items: int,
                 nsamples: int, seed: int = 0):
    """Train a small Macau session streaming samples to ``save_dir``."""
    rng = np.random.default_rng(seed)
    n_feat, rank = 16, 4
    F = rng.normal(size=(n_users, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    T = rng.normal(size=(n_items, rank)).astype(np.float32)
    act = (F @ B @ T.T).astype(np.float32)
    obs = rng.random((n_users, n_items)) < 0.2
    i, j = np.nonzero(obs)
    mat = from_coo(i, j, act[i, j], (n_users, n_items))
    b = ModelBuilder(num_latent=8)
    b.add_entity("user", n_users, side_info=F)
    b.add_entity("item", n_items)
    b.add_block("user", "item", mat, noise=AdaptiveGaussian())
    b.session(burnin=10, nsamples=nsamples, seed=seed, save_freq=1,
              save_dir=save_dir).run()
    return F, obs


def _drive(session: PredictSession, F: np.ndarray, obs: np.ndarray,
           qps: float, n_requests: int, slots: int, seed: int):
    """One offered-QPS level: open-loop arrivals, full drain.

    Returns (client-latency Histogram, achieved qps, the server's
    ``metrics_snapshot()`` for the timed region).
    """
    rng = np.random.default_rng(seed)
    n_users = F.shape[0]
    arrivals = np.arange(n_requests) / qps    # scheduled offsets (s)
    kinds = rng.random(n_requests)            # 10% cold-start
    users = rng.integers(0, n_users, n_requests)

    srv = RecommendServer(session, slots=slots, k=10)
    # warm the jit caches for EVERY batch size the slot runtime can
    # form (the scorer specializes on B) so no timed request pays
    # compilation
    srv.submit(features=F[0])
    srv.run()
    for b in range(1, slots + 1):
        for u in range(b):
            srv.submit(user=u)
        srv.run()
    srv.done.clear()
    srv.obs.reset()     # drop the warm-up's latency observations too

    submitted = 0
    t0 = clock.monotonic()
    while len(srv.done) < n_requests:
        now = clock.monotonic() - t0
        while submitted < n_requests and arrivals[submitted] <= now:
            u = int(users[submitted])
            if kinds[submitted] < 0.1:
                srv.submit(features=F[u],
                           req_id=f"q{submitted}")
            else:
                srv.submit(user=u, exclude=np.nonzero(obs[u])[0],
                           req_id=f"q{submitted}")
            submitted += 1
        srv._admit()
        if any(r is not None for r in srv.active):
            srv.step()
        elif submitted < n_requests:
            time.sleep(min(1e-3, arrivals[submitted] - now))
    t_end = clock.monotonic()

    # client-perceived latency (scheduled arrival -> completion,
    # queueing included) through the shared obs histogram — the same
    # percentile implementation the server's own snapshot uses
    lat = Histogram(latency_buckets(lo=1e-5))
    for d in srv.done:
        lat.observe(d["t_done"] - (t0 + arrivals[int(d["id"][1:])]))
    achieved = n_requests / (t_end - t0)
    return lat, achieved, srv.metrics_snapshot()


def run(quick: bool = False, out: str | None = None,
        store_dir: str | None = None) -> dict:
    n_users, n_items, nsamples = \
        (200, 128, 8) if quick else (2000, 1024, 32)
    n_requests = 40 if quick else 400
    qps_levels = [25.0, 400.0] if quick else [10.0, 40.0, 160.0, 640.0]
    slots = 8

    tmp = store_dir or tempfile.mkdtemp(prefix="serve_latency_")
    F, obs = _build_store(tmp, n_users, n_items, nsamples)
    session = PredictSession(tmp)
    session.warm_cache()

    levels = []
    for qps in qps_levels:
        lat, achieved, snap = _drive(
            session, F, obs, qps, n_requests, slots, seed=int(qps))
        p50 = lat.percentile(0.50)
        p99 = lat.percentile(0.99)
        occ = Histogram.from_dict(
            snap["histograms"]["serve.batch_occupancy"])
        mean_batch = occ.mean()
        levels.append({
            "offered_qps": qps,
            "achieved_qps": round(achieved, 2),
            "p50_latency_s": round(p50, 5),
            "p99_latency_s": round(p99, 5),
            "mean_batch": round(mean_batch, 2),
            "n_requests": n_requests,
            "server_metrics": {
                name.split(".", 1)[1]: {
                    k: round(v, 5) if isinstance(v, float) else v
                    for k, v in percentile_summary(
                        Histogram.from_dict(
                            snap["histograms"][name])).items()}
                for name in ("serve.queue_wait_s", "serve.execute_s")
            } | {"completed": int(snap["counters"]
                                  .get("serve.completed", 0))},
        })
        emit("serving", f"qps_{qps:g}",
             f"{p50 * 1e3:.2f}/{p99 * 1e3:.2f}", "ms p50/p99",
             f"achieved {achieved:.1f} qps, mean batch "
             f"{mean_batch:.1f}")

    rec = {
        "bench": "serve_latency",
        "store": {"n_users": n_users, "n_items": n_items,
                  "num_samples": nsamples, "num_latent": 8},
        "slots": slots,
        "resident_cache_bytes": session.store_nbytes(),
        "load_count": session.load_count,
        "quick": quick,
        "levels": levels,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = out or os.path.join(
        RESULTS_DIR,
        f"serve_latency{'_quick' if quick else ''}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    emit("serving", "results_json", out, "path",
         f"{len(levels)} QPS levels")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller store / fewer QPS levels")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default results/serving/)")
    args = ap.parse_args()
    print("section,name,value,unit,notes", flush=True)
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()

"""Paper §4 Macau: side information improves the factorization.

The paper's Macau run (1M compounds x thousands of proteins, ECFP
side info) showed side information lifts predictive quality —
especially for sparsely-observed compounds (cold start).  Offline
analogue: ChEMBL-like planted data where fingerprints F are noisy
projections of the true compound factors; compare test RMSE of

* BMF  (no side info)
* Macau (F on the compound axis, link matrix beta sampled)

overall and on the cold-start subset (rows with <= 2 train ratings).
"""
from __future__ import annotations

import numpy as np

from repro.core import AdaptiveGaussian, TrainSession

from .common import emit


def run(n_compounds: int = 1500, n_proteins: int = 120,
        burnin: int = 120, nsamples: int = 120):
    from repro.data.synthetic import chembl_like
    mat, test, F = chembl_like(3, n_compounds, n_proteins,
                               density=0.04, rank=8, noise=0.2,
                               n_features=64, feature_noise=0.25)
    ti, tj, tv = test

    # cold-start rows: few observed train entries
    counts = np.bincount(np.asarray(mat.coo_i), minlength=n_compounds)
    cold = counts[ti] <= 2

    def fit(side):
        s = TrainSession(num_latent=8, burnin=burnin,
                         nsamples=nsamples, seed=0)
        s.add_train_and_test(mat, test=test, noise=AdaptiveGaussian())
        if side is not None:
            s.add_side_info(0, side)
        r = s.run()
        err = r.predictions - tv
        rmse_cold = float(np.sqrt(np.mean(err[cold] ** 2))) \
            if cold.any() else float("nan")
        return r, rmse_cold

    r_bmf, cold_bmf = fit(None)
    r_mac, cold_mac = fit(F)
    emit("macau", "bmf_rmse_test", f"{r_bmf.rmse_test:.4f}", "rmse",
         f"cold-start rmse {cold_bmf:.4f} (n={int(cold.sum())})")
    emit("macau", "macau_rmse_test", f"{r_mac.rmse_test:.4f}", "rmse",
         f"cold-start rmse {cold_mac:.4f}")
    emit("macau", "cold_start_lift",
         f"{(cold_bmf - cold_mac) / max(cold_bmf, 1e-9) * 100:.1f}",
         "%", "side-info RMSE reduction on cold rows")
    return r_bmf, r_mac

"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]

Sections (paper analogue):
    bmf_impls      Fig. 3  implementation comparison
    scaling        Fig. 3  worker-count scaling (subprocess devices)
    platform_sweep Fig. 4  data-type sweep (sparse/dense/side-info)
    compile_modes  Fig. 5  dispatch/compile modes
    gfa            §4      GFA simulated-study reproduction
    macau          §4      Macau side-info lift (incl. cold start)
    roofline       §5      roofline summary from the dry-run records
    serving        §1      RecommendServer latency/QPS under load

Output: CSV rows ``section,name,value,unit,notes``.
"""
from __future__ import annotations

import argparse
import time

from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer sweeps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    q = args.quick

    print("section,name,value,unit,notes", flush=True)
    t0 = time.perf_counter()

    def want(name: str) -> bool:
        return args.only is None or args.only == name

    if want("bmf_impls"):
        from . import bmf_impls
        bmf_impls.run(*((600, 96) if q else (2000, 200)))
    if want("platform_sweep"):
        from . import platform_sweep
        platform_sweep.run(*((600, 96) if q else (2000, 200)))
    if want("compile_modes"):
        from . import compile_modes
        compile_modes.run(*((400, 64) if q else (1000, 128)))
    if want("gfa"):
        from . import gfa_repro
        gfa_repro.run(quick=q)
    if want("macau"):
        from . import macau_lift
        macau_lift.run(*((500, 64, 60, 60) if q else (1500, 120, 120, 120)))
    if want("scaling"):
        from . import scaling
        scaling.run((1, 2, 4) if q else (1, 2, 4, 8))
    if want("roofline"):
        from . import roofline_table
        roofline_table.run()
    if want("serving"):
        from . import serve_latency
        serve_latency.run(quick=q)

    emit("meta", "total_runtime", f"{time.perf_counter() - t0:.1f}",
         "s", "benchmarks.run wall time")


if __name__ == "__main__":
    main()

"""Paper Fig. 3 analogue: runtime of different BMF implementations.

The paper compares PyMC3 (interpreted, generic PPL), GraphChi
(graph-engine), SMURFF (batched C++/Eigen) and BMF-with-GASPI
(multi-node).  Offline analogues on the same data and sampler maths:

* ``loop``    — per-row Python/NumPy Gibbs (the PyMC3/R-style
                interpreted baseline; same conditionals, no batching)
* ``xla``     — SMURFF-JAX batched sweep, one ``gibbs_step`` per call
* ``xla_scan``— batched sweep under ``lax.scan`` (dispatch amortized;
                the "optimized native" point)
* ``pallas``  — Pallas kernel path in interpret mode (correctness
                surrogate; interpret-mode time is NOT a TPU estimate,
                reported for completeness only)

Headline: speedup of xla/xla_scan over loop (paper: 15x over GraphChi,
1400x over PyMC3).
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core import (FixedGaussian, TrainSession, init_state,
                        run_sweeps)
from repro.data.synthetic import chembl_like

from .common import emit, time_fn


def loop_gibbs_sweep(R_coo, shape, U, V, alpha, rng):
    """Per-row Python Gibbs half-sweeps — the interpreted baseline."""
    i, j, v = R_coo
    n, m = shape
    K = U.shape[1]
    eye = np.eye(K, dtype=np.float32)
    for r in range(n):
        sel = i == r
        vs = V[j[sel]]
        lam = alpha * (vs.T @ vs) + eye
        b = alpha * (v[sel] @ vs)
        L = np.linalg.cholesky(lam)
        mean = np.linalg.solve(lam, b)
        z = rng.normal(size=K).astype(np.float32)
        U[r] = mean + np.linalg.solve(L.T, z)
    for c in range(m):
        sel = j == c
        us = U[i[sel]]
        lam = alpha * (us.T @ us) + eye
        b = alpha * (v[sel] @ us)
        L = np.linalg.cholesky(lam)
        mean = np.linalg.solve(lam, b)
        z = rng.normal(size=K).astype(np.float32)
        V[c] = mean + np.linalg.solve(L.T, z)
    return U, V


def run(n_compounds: int = 2000, n_proteins: int = 200, K: int = 8):
    mat, test, _ = chembl_like(0, n_compounds, n_proteins,
                               density=0.05, rank=8, noise=0.3)
    i = np.asarray(mat.coo_i)
    j = np.asarray(mat.coo_j)
    v = np.asarray(mat.coo_v)
    rng = np.random.default_rng(0)
    U = rng.normal(size=(n_compounds, K)).astype(np.float32)
    V = rng.normal(size=(n_proteins, K)).astype(np.float32)

    # interpreted per-row baseline (1 sweep is enough to time)
    t_loop = time_fn(
        lambda: loop_gibbs_sweep((i, j, v), mat.shape, U.copy(),
                                 V.copy(), 5.0, rng),
        reps=3, warmup=0)
    emit("bmf_impls", "loop_python", f"{t_loop:.4f}", "s/sweep",
         "per-row interpreted baseline (PyMC3/R analogue)")

    def make(use_pallas: bool):
        s = TrainSession(num_latent=K, burnin=0, nsamples=1, seed=0,
                         use_pallas=use_pallas)
        s.add_train_and_test(mat, test=test, noise=FixedGaussian(5.0))
        model, data = s._build()
        state = init_state(model, data, 0)
        return model, data, state

    from repro.core import gibbs_step
    model, data, state = make(False)
    t_xla = time_fn(lambda: gibbs_step(model, data, state)[0])
    emit("bmf_impls", "smurff_jax_xla", f"{t_xla:.4f}", "s/sweep",
         f"batched sweep; speedup vs loop = {t_loop / t_xla:.0f}x")

    t_scan = time_fn(
        lambda: run_sweeps(model, data, state, 8)[0]) / 8.0
    emit("bmf_impls", "smurff_jax_scan", f"{t_scan:.4f}", "s/sweep",
         f"lax.scan x8; speedup vs loop = {t_loop / t_scan:.0f}x")

    model_p, data_p, state_p = make(True)
    t_pal = time_fn(lambda: gibbs_step(model_p, data_p, state_p)[0],
                    reps=1, warmup=1)
    emit("bmf_impls", "pallas_interpret", f"{t_pal:.4f}", "s/sweep",
         "interpret-mode (correctness path, not a TPU time)")

    # paper's check: all implementations reach the same predictive perf
    res = TrainSession(num_latent=K, burnin=40, nsamples=40, seed=0) \
        .add_train_and_test(mat, test=test, noise=FixedGaussian(5.0)) \
        .run()
    emit("bmf_impls", "rmse_test_80sweeps", f"{res.rmse_test:.4f}",
         "rmse", "predictive-equivalence check target")
    return {"loop": t_loop, "xla": t_xla, "scan": t_scan}

"""Shared benchmark helpers: timing, CSV emission, sizes.

Every benchmark prints rows ``section,name,value,unit,notes`` so
``benchmarks.run`` output is machine-readable (bench_output.txt).
Container is CPU-only: absolute times are CPU-XLA numbers; cross-
implementation *ratios* are the paper-comparable quantity (Fig. 3/4/5
report ratios between implementations on shared hardware too).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, str, str, str, str]] = []


def emit(section: str, name: str, value, unit: str, notes: str = ""):
    row = (section, name, f"{value}", unit, notes)
    ROWS.append(row)
    print(",".join(row), flush=True)


def time_fn(fn: Callable[[], object], *, reps: int = 3,
            warmup: int = 1) -> float:
    """Median wall seconds of ``fn`` (block_until_ready on jax output)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]

"""Paper Fig. 5 analogue: compilation/dispatch modes.

The paper compares GCC/ICC x MKL/OpenBLAS x native/Conda builds and
finds the BLAS library (not the compiler) dominates.  The JAX
analogues of "how you build/dispatch the same maths":

* ``eager``     — op-by-op dispatch, no jit (the un-tuned build)
* ``jit``       — one compiled sweep per call
* ``jit_scan``  — sweeps fused under ``lax.scan`` (amortized dispatch;
                  the MKL-native point)
* ``jit_x64``   — f64 maths (precision/bandwidth trade, OpenBLAS-ish)

Same sampler, same data; ratios are the deliverable.
"""
from __future__ import annotations

import jax

from repro.core import FixedGaussian, TrainSession, init_state, run_sweeps
from repro.core.gibbs import gibbs_step
from repro.data.synthetic import chembl_like

from .common import emit, time_fn


def run(n_compounds: int = 1000, n_proteins: int = 128):
    mat, test, _ = chembl_like(0, n_compounds, n_proteins)
    s = TrainSession(num_latent=16, burnin=0, nsamples=1, seed=0)
    s.add_train_and_test(mat, test=test, noise=FixedGaussian(5.0))
    model, data = s._build()
    state = init_state(model, data, 0)

    with jax.disable_jit():
        t_eager = time_fn(lambda: gibbs_step(model, data, state)[0],
                          reps=1, warmup=0)
    emit("compile_modes", "eager", f"{t_eager:.4f}", "s/sweep",
         "op-by-op dispatch")

    t_jit = time_fn(lambda: gibbs_step(model, data, state)[0])
    emit("compile_modes", "jit", f"{t_jit:.4f}", "s/sweep",
         f"{t_eager / t_jit:.1f}x over eager")

    t_scan = time_fn(lambda: run_sweeps(model, data, state, 8)[0]) / 8
    emit("compile_modes", "jit_scan", f"{t_scan:.4f}", "s/sweep",
         f"{t_eager / t_scan:.1f}x over eager")

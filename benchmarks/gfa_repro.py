"""Paper §4 GFA: reproduce the *simulated study* design of
Bunte et al. 2015 (the reference SMURFF validates against).

Planted data: N samples, M=3 views; some latent factors are shared
across all views, some are view-specific (their loadings are zero in
the other views).  GFA = Normal prior on the shared sample factor Z,
spike-and-slab on each view's loading matrix W_m — run with
``GFASession`` and check

  1. reconstruction: per-view train RMSE approaches the noise floor,
  2. structure: the recovered factor-activity pattern (||W_m[:,k]||
     per view) separates shared from view-specific factors,
  3. runtime vs a per-column interpreted loop (the "R is 100x slower"
     claim's analogue).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import GFASession

from .common import emit, time_fn


def planted_views(seed=0, N=150, dims=(40, 30, 20), k_shared=2,
                  k_spec=1, noise=0.1):
    """Views sharing ``k_shared`` factors + ``k_spec`` private each."""
    rng = np.random.default_rng(seed)
    M = len(dims)
    K = k_shared + k_spec * M
    Z = rng.normal(size=(N, K)).astype(np.float32)
    Ws, activity = [], np.zeros((M, K), bool)
    for m, D in enumerate(dims):
        W = np.zeros((D, K), np.float32)
        cols = list(range(k_shared)) + [k_shared + k_spec * m + t
                                        for t in range(k_spec)]
        W[:, cols] = rng.normal(size=(D, len(cols)))
        activity[m, cols] = True
        Ws.append(W)
    views = [Z @ W.T + noise * rng.normal(size=(N, W.shape[0]))
             .astype(np.float32) for W in Ws]
    return views, activity, K


def run(quick: bool = False):
    """``quick`` is the per-PR CI smoke: half the sweeps, one run
    (no separate timing rep), and a HARD recovery check — the GFA
    composition must reconstruct the planted views, not just finish."""
    views, activity, K_true = planted_views()
    sweeps = 75 if quick else 150
    sess = GFASession(views, num_latent=K_true + 3, burnin=sweeps,
                      nsamples=sweeps, seed=0)
    if quick:
        t0 = time.perf_counter()
        out = sess.run()
        t = time.perf_counter() - t0
    else:
        t = time_fn(lambda: sess.run(), reps=1, warmup=0)
        out = sess.run()

    for m, tr in enumerate(out["rmse_train"]):
        emit("gfa", f"view{m}_rmse_final", f"{tr[-1]:.4f}", "rmse",
             "planted noise floor = 0.1")
        if quick:   # the CI gate; full benchmark runs keep emitting
            assert np.isfinite(tr[-1]) and tr[-1] < 0.3, \
                f"view {m} failed to reconstruct: rmse {tr[-1]}"

    # factor-activity recovery: norm of each recovered component per
    # view, thresholded, must reproduce the shared/specific pattern up
    # to factor permutation -> greedy-match planted to recovered
    norms = np.stack([np.linalg.norm(W, axis=0) for W in out["W"]])
    norms = norms / (norms.max(axis=0, keepdims=True) + 1e-9)
    rec_act = norms > 0.3
    matched = 0
    used = set()
    for k in range(activity.shape[1]):
        best, best_j = -1, None
        for jj in range(rec_act.shape[1]):
            if jj in used:
                continue
            score = (rec_act[:, jj] == activity[:, k]).sum()
            if score > best:
                best, best_j = score, jj
        used.add(best_j)
        matched += (best == activity.shape[0])
    emit("gfa", "factor_pattern_recovered",
         f"{matched}/{activity.shape[1]}", "factors",
         "shared/specific activity pattern (greedy matched)")
    emit("gfa", f"runtime_{2 * sweeps}_sweeps", f"{t:.2f}", "s",
         "GFASession 3 views, K=9")

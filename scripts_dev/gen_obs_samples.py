"""Regenerate the committed observability samples in results/obs/.

CI schema-audits every JSON under ``results/obs`` with
``python -m repro.analysis --obs results/obs`` (see
``analysis.obsschema``), so the committed files must stay in lockstep
with what ``repro.obs`` actually exports.  After changing the
recorder's trace/metrics formats, span names, or the serve histogram
set, rerun::

    PYTHONPATH=src python scripts_dev/gen_obs_samples.py

Three samples are written:

- ``train_trace.json``   — Chrome-trace export of an instrumented
  TrainSession run (sweep spans with bytes_on_wire, session/compile)
- ``train_metrics.json`` — the matching metrics snapshot
- ``serve_metrics.json`` — a RecommendServer ``metrics_snapshot()``
  after a short driven load (queue-wait/execute/occupancy histograms)

Wall-clock values in these files differ per run by design; the audit
only pins structure.
"""
from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (AdaptiveGaussian, ModelBuilder,  # noqa: E402
                        PredictSession, from_coo)
from repro.launch.serve import RecommendServer  # noqa: E402
from repro.obs import Recorder, write_json_atomic  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "obs")


def _toy_matrix(rng, n_users=48, n_items=32, rank=3):
    U = rng.normal(size=(n_users, rank)).astype(np.float32)
    V = rng.normal(size=(n_items, rank)).astype(np.float32)
    act = (U @ V.T).astype(np.float32)
    obs = rng.random((n_users, n_items)) < 0.35
    i, j = np.nonzero(obs)
    return from_coo(i, j, act[i, j], (n_users, n_items)), obs


def gen_session(out_dir: str, save_dir: str) -> None:
    rng = np.random.default_rng(0)
    mat, _ = _toy_matrix(rng)
    rec = Recorder(enabled=True)
    b = ModelBuilder(num_latent=4)
    b.add_entity("user", mat.shape[0])
    b.add_entity("item", mat.shape[1])
    b.add_block("user", "item", mat, noise=AdaptiveGaussian())
    b.session(burnin=3, nsamples=4, seed=7, save_freq=2,
              save_dir=save_dir, recorder=rec).run()
    rec.write_trace(os.path.join(out_dir, "train_trace.json"))
    rec.write_metrics(os.path.join(out_dir, "train_metrics.json"))


def gen_serve(out_dir: str, store_dir: str) -> None:
    rng = np.random.default_rng(1)
    n_users, n_items, n_feat, rank = 64, 40, 8, 3
    F = rng.normal(size=(n_users, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    T = rng.normal(size=(n_items, rank)).astype(np.float32)
    act = (F @ B @ T.T).astype(np.float32)
    obs = rng.random((n_users, n_items)) < 0.25
    i, j = np.nonzero(obs)
    mat = from_coo(i, j, act[i, j], (n_users, n_items))
    mb = ModelBuilder(num_latent=4)
    mb.add_entity("user", n_users, side_info=F)
    mb.add_entity("item", n_items)
    mb.add_block("user", "item", mat, noise=AdaptiveGaussian())
    mb.session(burnin=4, nsamples=4, seed=1, save_freq=1,
               save_dir=store_dir).run()

    session = PredictSession(store_dir)
    session.warm_cache()
    srv = RecommendServer(session, slots=4, k=5)
    for r in range(12):
        u = int(rng.integers(0, n_users))
        if r % 6 == 0:
            srv.submit(features=F[u])
        else:
            srv.submit(user=u, exclude=np.nonzero(obs[u])[0])
    srv.run()
    write_json_atomic(os.path.join(out_dir, "serve_metrics.json"),
                      srv.metrics_snapshot())


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with tempfile.TemporaryDirectory(prefix="gen_obs_") as tmp:
        gen_session(OUT_DIR, os.path.join(tmp, "session"))
        gen_serve(OUT_DIR, os.path.join(tmp, "store"))
    for f in sorted(os.listdir(OUT_DIR)):
        print(os.path.join(OUT_DIR, f))


if __name__ == "__main__":
    main()

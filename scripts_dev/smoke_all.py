"""Dev script: forward+loss+grad+serve for every smoke config."""
import sys
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke, get_config
from repro.models import (forward, init_model, init_serve_cache, loss_fn,
                          param_count, serve_step)
from repro.models.transformer import encode

only = sys.argv[1:] or ARCHS
for arch in only:
    cfg = get_smoke(arch)
    full = get_config(arch)
    tot, act = param_count(full)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 64
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
    }
    if cfg.n_frontend_tokens:
        batch["frontend"] = jnp.asarray(rng.normal(
            size=(B, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32))
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_frames, cfg.d_model)).astype(np.float32))

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn), "non-finite grads"
    # forward only for shapes
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    # serve one step
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["enc_frames"])
    caches = init_serve_cache(params, cfg, B, 128, enc_out=enc_out,
                              prefilled=5)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)))
    lg, caches2 = serve_step(params, cfg, caches, tok)
    assert lg.shape == (B, 1, cfg.vocab_size), lg.shape
    assert not bool(jnp.isnan(lg).any()), "NaN decode logits"
    print(f"{arch:24s} OK  loss={float(loss):.3f}  "
          f"full={tot/1e9:.1f}B params (active {act/1e9:.1f}B)")
print("ALL OK")

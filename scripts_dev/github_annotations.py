"""Turn `python -m repro.analysis --json` output into GitHub
workflow annotations.

Reads the JSON findings payload on stdin, prints one
``::error file=...,line=...`` command per finding (GitHub renders
these inline on the PR diff), and exits 1 if there were any — so
piping through this script preserves the lint job's failure status:

    python -m repro.analysis --json | python scripts_dev/github_annotations.py
"""
from __future__ import annotations

import json
import sys


def main() -> int:
    payload = json.load(sys.stdin)
    findings = payload.get("findings", [])
    for f in findings:
        # annotation text must be single-line; %0A would be literal
        msg = " ".join(str(f.get("message", "")).split())
        hint = " ".join(str(f.get("hint", "")).split())
        if hint:
            msg = f"{msg} (fix: {hint})"
        print(f"::error file={f.get('path', '')},"
              f"line={f.get('line', 0)},"
              f"title={f.get('rule', 'finding')}::{msg}")
    n = payload.get("count", len(findings))
    print(f"{len(findings)} finding(s) annotated", file=sys.stderr)
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())

"""End-to-end LM training driver on an assigned architecture.

Runs the full production path — config registry, data pipeline,
grad-accumulated train step, checkpointing with auto-resume,
straggler monitor — on CPU-sized settings by default.

    PYTHONPATH=src python examples/train_lm.py                  # quick
    PYTHONPATH=src python examples/train_lm.py --arch smollm_135m \
        --full --steps 300 --batch 8 --seq 256                  # ~135M

``--arch`` accepts any of the 10 assigned architectures; ``--full``
uses the exact published config (CPU: expect minutes/step for the
big ones — the multi-pod path is exercised by launch/dryrun.py).
"""
import argparse

from repro.configs import ARCHS, get_config, get_smoke
from repro.launch.train import train
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm_135m")
    ap.add_argument("--full", action="store_true",
                    help="exact published config (default: smoke size)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="enable checkpoint/auto-resume")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d={cfg.d_model}  "
          f"steps={args.steps}  batch={args.batch}x{args.seq}")

    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps),
                ckpt_dir=args.ckpt_dir, n_micro=args.n_micro,
                log_every=max(1, args.steps // 10))

    first = sum(out["losses"][:5]) / max(1, len(out["losses"][:5]))
    last = sum(out["losses"][-5:]) / max(1, len(out["losses"][-5:]))
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({out['runtime_s']:.1f}s, "
          f"{out['runtime_s'] / max(1, args.steps):.2f}s/step)")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()

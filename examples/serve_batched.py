"""Batched serving demo: continuous batching over decode slots.

Initializes a small model, submits a handful of prompt requests, and
drives the ``BatchedServer`` runtime (prefill-through-decode path with
a KV cache per slot) until all complete.

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs import get_smoke
from repro.launch.serve import BatchedServer
from repro.models import init_model


def main():
    cfg = get_smoke("qwen3_4b")
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model}")
    params = init_model(jax.random.PRNGKey(0), cfg)

    server = BatchedServer(cfg, params, slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for r in range(6):       # more requests than slots -> queueing
        plen = int(rng.integers(4, 12))
        server.submit(rng.integers(0, cfg.vocab_size, size=plen),
                      max_new=12, req_id=f"req{r}")

    done = server.run()
    for req in done:
        print(f"  {req['id']}: prompt[{len(req['prompt'])}] -> "
              f"{req['generated']}")
    print(f"{len(done)} requests completed")


if __name__ == "__main__":
    main()

"""Quickstart: Bayesian Matrix Factorization on compound-activity data.

Mirrors the SMURFF Jupyter quickstart: build a sparse train/test
split of a ChEMBL-like activity matrix, run BMF with Gibbs sampling,
report test RMSE.

    PYTHONPATH=src python examples/quickstart.py [--num-latent 16]
"""
import argparse

from repro.core import AdaptiveGaussian, TrainSession
from repro.data.synthetic import chembl_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-latent", type=int, default=8)
    ap.add_argument("--burnin", type=int, default=100)
    ap.add_argument("--nsamples", type=int, default=100)
    ap.add_argument("--compounds", type=int, default=2000)
    ap.add_argument("--proteins", type=int, default=200)
    ap.add_argument("--density", type=float, default=0.05)
    args = ap.parse_args()

    print("generating ChEMBL-like activity matrix "
          f"({args.compounds} compounds x {args.proteins} proteins)...")
    R_train, test, _ = chembl_like(0, args.compounds, args.proteins,
                                   density=args.density, rank=8,
                                   noise=0.3)

    session = TrainSession(num_latent=args.num_latent,
                           burnin=args.burnin, nsamples=args.nsamples,
                           seed=0, verbose=1)
    session.add_train_and_test(R_train, test=test,
                               noise=AdaptiveGaussian())
    result = session.run()

    print(f"\ntest RMSE  : {result.rmse_test:.4f}")
    print(f"sweeps     : {args.burnin} burn-in + {args.nsamples} samples")
    print(f"runtime    : {result.runtime_s:.1f}s "
          f"({result.runtime_s / (args.burnin + args.nsamples) * 1e3:.1f}"
          " ms/sweep)")


if __name__ == "__main__":
    main()

"""Quickstart: Bayesian Matrix Factorization on compound-activity data.

Mirrors the SMURFF Jupyter quickstart on the builder API: declare the
entity/block graph with ``ModelBuilder`` (here the simplest one — two
entities, one sparse ChEMBL-like activity matrix), run BMF with Gibbs
sampling, report test RMSE.  The classic ``TrainSession`` remains as a
thin wrapper over the same builder for the single-matrix case; pass
``save_freq=``/``save_dir=`` to either to stream posterior samples for
``PredictSession`` (see examples/compose_multi_matrix.py).

    PYTHONPATH=src python examples/quickstart.py [--num-latent 16]
"""
import argparse

from repro.core import AdaptiveGaussian, ModelBuilder
from repro.data.synthetic import chembl_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-latent", type=int, default=8)
    ap.add_argument("--burnin", type=int, default=100)
    ap.add_argument("--nsamples", type=int, default=100)
    ap.add_argument("--compounds", type=int, default=2000)
    ap.add_argument("--proteins", type=int, default=200)
    ap.add_argument("--density", type=float, default=0.05)
    args = ap.parse_args()

    print("generating ChEMBL-like activity matrix "
          f"({args.compounds} compounds x {args.proteins} proteins)...")
    R_train, test, _ = chembl_like(0, args.compounds, args.proteins,
                                   density=args.density, rank=8,
                                   noise=0.3)

    builder = ModelBuilder(num_latent=args.num_latent)
    builder.add_entity("compound", args.compounds)
    builder.add_entity("protein", args.proteins)
    builder.add_block("compound", "protein", R_train, test=test,
                      noise=AdaptiveGaussian())
    session = builder.session(burnin=args.burnin,
                              nsamples=args.nsamples, seed=0, verbose=1)
    result = session.run()

    print(f"\ntest RMSE  : {result.rmse_test:.4f}")
    print(f"sweeps     : {args.burnin} burn-in + {args.nsamples} samples")
    print(f"runtime    : {result.runtime_s:.1f}s "
          f"({result.runtime_s / (args.burnin + args.nsamples) * 1e3:.1f}"
          " ms/sweep)")


if __name__ == "__main__":
    main()

"""Macau: side information lifts cold-start predictions (paper §4).

Attaches ECFP-like compound fingerprints through the Macau link
matrix and compares against plain BMF — overall and on compounds with
very few training observations.

    PYTHONPATH=src python examples/macau_side_info.py
"""
import numpy as np

from repro.core import AdaptiveGaussian, TrainSession
from repro.data.synthetic import chembl_like


def fit(R, test, F, tag):
    s = TrainSession(num_latent=8, burnin=120, nsamples=120, seed=0)
    s.add_train_and_test(R, test=test, noise=AdaptiveGaussian())
    if F is not None:
        s.add_side_info(axis=0, F=F)     # compounds get fingerprints
    r = s.run()
    print(f"{tag:18s} test RMSE {r.rmse_test:.4f}   "
          f"({r.runtime_s:.1f}s)")
    return r


def main():
    R, test, F = chembl_like(3, 1500, 120, density=0.04, rank=8,
                             noise=0.2, n_features=64,
                             feature_noise=0.25)
    ti, tj, tv = test
    counts = np.bincount(np.asarray(R.coo_i), minlength=R.shape[0])
    cold = counts[ti] <= 2
    print(f"{int(cold.sum())} of {len(ti)} test points are cold-start "
          "(compound has <=2 train ratings)\n")

    r_bmf = fit(R, test, None, "BMF (no side)")
    r_macau = fit(R, test, F, "Macau (+ECFP)")

    for name, r in (("BMF", r_bmf), ("Macau", r_macau)):
        err = r.predictions - tv
        print(f"{name:6s} cold-start RMSE: "
              f"{np.sqrt(np.mean(err[cold] ** 2)):.4f}")


if __name__ == "__main__":
    main()

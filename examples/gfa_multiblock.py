"""Group Factor Analysis over multiple data views (paper §4 GFA).

Three views share a sample axis; some latent factors are common to
all views, some are view-specific.  GFA (Normal prior on the shared
factor, spike-and-slab on the loadings) recovers which factor drives
which view.

    PYTHONPATH=src python examples/gfa_multiblock.py [--burnin 150]
"""
import argparse

import numpy as np

from repro.core import GFASession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--burnin", type=int, default=150)
    ap.add_argument("--nsamples", type=int, default=150)
    ap.add_argument("--n", type=int, default=200,
                    help="shared sample count")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    N = args.n
    dims = (50, 40, 30)
    # 2 shared factors + 1 specific factor per view
    K_true = 2 + len(dims)
    Z = rng.normal(size=(N, K_true)).astype(np.float32)
    views, active = [], []
    for m, D in enumerate(dims):
        cols = [0, 1, 2 + m]
        W = np.zeros((D, K_true), np.float32)
        W[:, cols] = rng.normal(size=(D, len(cols)))
        views.append((Z @ W.T + 0.1 * rng.normal(size=(N, D)))
                     .astype(np.float32))
        active.append(cols)

    sess = GFASession(views, num_latent=K_true + 2, burnin=args.burnin,
                      nsamples=args.nsamples, seed=0)
    out = sess.run()

    print(f"GFA over {len(views)} views, {out['runtime_s']:.1f}s")
    for m in range(len(views)):
        print(f"  view{m}: final train RMSE "
              f"{out['rmse_train'][m][-1]:.4f} (noise floor 0.1), "
              f"planted active factors {active[m]}")
    print("\nrecovered |W_m| column norms (rows=views, cols=latent):")
    norms = np.stack([np.linalg.norm(W, axis=0) for W in out["W"]])
    with np.printoptions(precision=1, suppress=True):
        print(norms)
    print("\nzero-ish columns mark factors a view does not use; "
          "shared factors are active in every row.")


if __name__ == "__main__":
    main()

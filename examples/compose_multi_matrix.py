"""Compose a two-relation model sharing an entity + reload its samples.

The paper's framework claim, end to end: one ``ModelBuilder`` graph
relates compounds to BOTH protein targets (sparse IC50 activity, with
ECFP-like compound features through the Macau prior) and cell lines
(dense viability) — the two blocks share the compound latent factor,
so evidence flows between the relations.  ``save_freq`` streams every
posterior sample to disk; ``PredictSession`` then reloads them with no
training data in sight and serves

* in-matrix predictions at the held-out test cells (reproducing the
  in-session RMSE), and
* OUT-of-matrix predictions for compounds never present in training,
  mapped through the sampled link matrices beta_s (cold-start, the
  compound-activity workflow of arXiv:1904.02514).

    PYTHONPATH=src python examples/compose_multi_matrix.py [--quick]
"""
import argparse
import tempfile

import numpy as np

from repro.core import AdaptiveGaussian, ModelBuilder, PredictSession, \
    from_coo


def make_data(seed, n_compounds, n_targets, n_cells, n_features, rank,
              noise, hold_out):
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(n_compounds, n_features)).astype(np.float32)
    B = (rng.normal(size=(n_features, rank)) / np.sqrt(n_features)) \
        .astype(np.float32)
    U = F @ B                                   # features drive latents
    T = rng.normal(size=(n_targets, rank)).astype(np.float32)
    L = rng.normal(size=(n_cells, rank)).astype(np.float32)
    activity = (U @ T.T + noise * rng.normal(
        size=(n_compounds, n_targets))).astype(np.float32)
    viability = (U @ L.T + noise * rng.normal(
        size=(n_compounds, n_cells))).astype(np.float32)

    n_warm = n_compounds - hold_out             # cold rows held out
    obs = rng.random((n_warm, n_targets)) < 0.3
    i, j = np.nonzero(obs)
    perm = rng.permutation(len(i))
    i, j = i[perm], j[perm]
    v = activity[i, j]
    n_test = len(i) // 5
    train = from_coo(i[n_test:], j[n_test:], v[n_test:],
                     (n_warm, n_targets))
    test = (i[:n_test], j[:n_test], v[:n_test])
    return F, train, test, viability[:n_warm], activity, n_warm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / few sweeps (CI smoke)")
    ap.add_argument("--compounds", type=int, default=400)
    ap.add_argument("--targets", type=int, default=64)
    ap.add_argument("--cells", type=int, default=24)
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--burnin", type=int, default=60)
    ap.add_argument("--nsamples", type=int, default=60)
    ap.add_argument("--save-dir", default=None,
                    help="posterior-sample store (default: a tempdir)")
    args = ap.parse_args()
    if args.quick:
        args.compounds, args.targets, args.cells = 96, 24, 12
        args.features, args.burnin, args.nsamples = 12, 15, 15

    rank, hold_out = 4, 4
    F, train, test, viability, activity, n_warm = make_data(
        0, args.compounds, args.targets, args.cells, args.features,
        rank=rank, noise=0.1, hold_out=hold_out)
    save_dir = args.save_dir or tempfile.mkdtemp(prefix="smurff_run_")

    print(f"two relations sharing {n_warm} compounds "
          f"({args.targets} targets sparse + {args.cells} cell lines "
          f"dense), {hold_out} cold compounds held out")

    b = ModelBuilder(num_latent=rank + 2)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])  # -> Macau
    b.add_entity("target", args.targets)
    b.add_entity("cellline", args.cells)
    b.add_block("compound", "target", train,
                noise=AdaptiveGaussian(), test=test)
    b.add_block("compound", "cellline", viability,
                noise=AdaptiveGaussian())
    session = b.session(burnin=args.burnin, nsamples=args.nsamples,
                        seed=0, save_freq=1, save_dir=save_dir)
    result = session.run()

    print(f"\nin-session  test RMSE : {result.rmse_test:.4f} "
          "(noise floor 0.1)")
    for blk in result.blocks:
        print(f"  {blk.entities[0]:>9s} x {blk.entities[1]:<9s}"
              f" train RMSE {blk.rmse_train_trace[-1]:.4f}")

    # --- reload the posterior from disk: no training data needed -----
    p = PredictSession(save_dir)
    pred = p.predict(test[0], test[1], block=("compound", "target"))
    rmse_disk = float(np.sqrt(np.mean((pred - test[2]) ** 2)))
    print(f"\nPredictSession({p.num_samples} samples from {save_dir})")
    print(f"reloaded    test RMSE : {rmse_disk:.4f}  (same chain)")

    cold = p.predict_new("compound", F[n_warm:],
                         block=("compound", "target"))
    truth = activity[n_warm:]
    rmse_cold = float(np.sqrt(np.mean((cold - truth) ** 2)))
    rmse_zero = float(np.sqrt(np.mean(truth ** 2)))
    print(f"out-of-matrix RMSE    : {rmse_cold:.4f} over {hold_out} "
          f"cold compounds (predict-zero baseline {rmse_zero:.4f})")
    assert abs(rmse_disk - result.rmse_test) < 1e-4, \
        "reload must reproduce the in-session posterior mean"
    assert rmse_cold < rmse_zero, \
        "the sampled Macau link must beat the zero baseline"


if __name__ == "__main__":
    main()

"""Posterior serving walkthrough: save_freq -> RecommendServer.

The compound-activity serving story of arXiv:1904.02514 end to end:

1. train a Macau session (compound side information) streaming every
   retained posterior sample to disk (``save_freq=1``);
2. reopen the store with ``PredictSession`` — the first request loads
   it ONCE into the resident posterior cache, after which serving does
   zero checkpoint I/O (watch ``load_count``);
3. stand up a ``RecommendServer`` and submit concurrent requests:
   warm users (excluding their already-observed targets) and a
   COLD-START compound known only by its feature vector, mapped
   through the sampled Macau link;
4. read back top-K targets with posterior mean AND std per score —
   the uncertainty the retained Gibbs samples carry for free.

    PYTHONPATH=src python examples/recommend_topk.py [--quick]
"""
import argparse
import tempfile

import numpy as np

from repro.core import (AdaptiveGaussian, ModelBuilder, PredictSession)
from repro.core.sparse import from_coo
from repro.launch.serve import RecommendServer


def make_activity_data(rng, n_compounds, n_targets, n_feat=12, rank=4):
    """Planted linear feature->latent activity matrix (ChEMBL-like)."""
    F = rng.normal(size=(n_compounds, n_feat)).astype(np.float32)
    B = (rng.normal(size=(n_feat, rank)) / np.sqrt(n_feat)) \
        .astype(np.float32)
    T = rng.normal(size=(n_targets, rank)).astype(np.float32)
    act = (F @ B @ T.T).astype(np.float32)
    return F, act


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problem / fewer samples")
    args = ap.parse_args()
    n_c, n_t, burnin, nsamples = \
        (60, 24, 8, 8) if args.quick else (300, 64, 40, 40)

    rng = np.random.default_rng(0)
    F, act = make_activity_data(rng, n_c, n_t)
    n_warm = n_c - 2              # hold the last two compounds out
    obs = rng.random((n_warm, n_t)) < 0.4
    i, j = np.nonzero(obs)
    mat = from_coo(i, j, act[i, j], (n_warm, n_t))

    # 1. train, streaming every retained sample to the store
    store = tempfile.mkdtemp(prefix="recommend_topk_")
    b = ModelBuilder(num_latent=8)
    b.add_entity("compound", n_warm, side_info=F[:n_warm])
    b.add_entity("target", n_t)
    b.add_block("compound", "target", mat, noise=AdaptiveGaussian())
    res = b.session(burnin=burnin, nsamples=nsamples, seed=0,
                    save_freq=1, save_dir=store).run()
    print(f"trained: final train rmse={res.rmse_train_trace[-1]:.3f},"
          f" {nsamples} samples -> {store}")

    # 2. reopen; the first request warms the resident cache
    session = PredictSession(store)
    print(f"store: {session.num_samples} samples, "
          f"{session.store_nbytes()} bytes resident")

    # 3. serve concurrent requests through the batching runtime
    server = RecommendServer(session, slots=4, k=5)
    print(f"cache warm: load_count={session.load_count} "
          f"(one per sample, never again)")
    req_user = {}
    for u in (0, 1, 2, 3, 4):
        rid = server.submit(user=u, exclude=np.nonzero(obs[u])[0])
        req_user[rid] = f"compound {u}"
    # cold start: a compound the chain never saw, features only
    rid = server.submit(features=F[n_warm], k=5)
    req_user[rid] = "COLD compound (features only)"
    done = server.run()
    assert session.load_count == session.num_samples  # zero while serving

    # 4. top-K with uncertainty
    for req in done:
        who = req_user[req["id"]]
        top = ", ".join(
            f"t{tid}: {m:+.2f}±{s:.2f}"
            for tid, m, s in zip(req["ids"], req["mean"], req["std"])
            if tid >= 0)
        print(f"  {who:>30}: {top}")

    # the cold-start ranking agrees with out-of-matrix prediction
    dense = session.predict_new("compound", F[n_warm:n_warm + 1])
    cold = [r for r in done if "COLD" in req_user[r["id"]]][0]
    assert cold["ids"][0] == int(np.argmax(dense[0]))
    print(f"cold-start top target == predict_new argmax "
          f"(t{cold['ids'][0]}); served {len(done)} requests with "
          f"{session.load_count} total checkpoint loads")


if __name__ == "__main__":
    main()
